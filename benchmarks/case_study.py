"""Paper §4.2 case study (Table 2 / Fig. 4): two GEMM implementations
compared through counters, with call-count event multiplexing.

LINPACK's dominant kernel is DGEMM; the paper instruments ATLAS's
``ATL_dgemm`` vs GotoBLAS's ``dgemm_`` and cycles through 5 event sets every
100 calls, showing (a) the sampled counters match 5 exhaustive runs within
marginal error, and (b) the counters explain WHY one implementation is
faster (Goto: more TLB misses, but 65% fewer L2 misses / 75% fewer stalls).

TPU adaptation: the implementations are the two Pallas GEMM schedules
(cache_blocked ≙ ATLAS default, cache_blocked@256 ≙ ATLAS full-search,
panel_streaming ≙ GotoBLAS) and the counters are the schedule cost events:
  VMEM_TILE_REFILLS ≙ DTLB_MISSES     HBM_BYTES ≙ L2_LINES_IN
  MXU_PASSES        ≙ SIMD_INST_RETIRED  FLOPS  ≙ INST_RETIRED
  EST_STALL_CYCLES  ≙ RESOURCE_STALLS
plus data-dependent events (ACT_RMS / L2NORM of C) that genuinely need the
live tensors.  The multiplex period is the paper's 100 calls.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams
from repro.kernels import ops

from .common import bench, fmt_table, save_json

# the five multiplexed event sets (paper: five sets, one per exhaustive run)
EVENT_SETS = [
    ["VMEM_TILE_REFILLS:refills", "HBM_BYTES:hbm"],
    ["MXU_PASSES:mxu", "FLOPS:flops"],
    ["EST_STALL_CYCLES:stalls"],
    ["ACT_RMS:out", "L2NORM:out"],
    ["NUMEL:out"],
]

IMPLS = {
    "atlas_default": dict(schedule="cache_blocked", bm=128, bn=128, bk=128),
    "atlas_full": dict(schedule="cache_blocked", bm=256, bn=256, bk=256),
    "goto": dict(schedule="panel_streaming", bm=128, bn=256),
}


def _spec(multiplexed: bool, period: int = 100) -> MonitorSpec:
    sets = [[EventSpec.parse(s) for s in group] for group in EVENT_SETS]
    if multiplexed:
        ctx = ScopeContext.multiplexed("dgemm", sets, period=period)
    else:
        ctx = ScopeContext.exhaustive("dgemm", [e for g in sets for e in g])
    return MonitorSpec.of([ctx])


def _dgemm_step(impl_cfg: dict, m: int, n: int, k: int, spec: MonitorSpec):
    """One instrumented DGEMM call: counters threaded through the carry."""
    cost = ops.matmul_cost(
        impl_cfg["schedule"], m, n, k,
        bm=impl_cfg.get("bm", 256), bn=impl_cfg.get("bn", 256),
        bk=impl_cfg.get("bk", 256),
    )
    kw = {kk: vv for kk, vv in impl_cfg.items() if kk != "schedule"}

    def step(a, b, state, mp):
        with scalpel.collecting(spec, mp, state) as col:
            with scalpel.function("dgemm"):
                c = ops.matmul(a, b, impl_cfg["schedule"], **kw)
                scalpel.probe(
                    out=c,
                    refills=jnp.float32(cost["VMEM_TILE_REFILLS"]),
                    hbm=jnp.float32(cost["HBM_BYTES"]),
                    mxu=jnp.float32(cost["MXU_PASSES"]),
                    flops=jnp.float32(cost["FLOPS"]),
                    stalls=jnp.float32(cost["EST_STALL_CYCLES"]),
                )
        return c, state.add(col.delta)

    return jax.jit(step), cost


def run_impl(impl: str, n_calls: int, m: int, n: int, k: int,
             multiplexed: bool, period: int = 100) -> dict:
    spec = _spec(multiplexed, period)
    step, cost = _dgemm_step(IMPLS[impl], m, n, k, spec)
    mp = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    # per-call input drift (LINPACK's DGEMM calls see varying panels):
    # deterministic scale so the sampled subset differs from the full set —
    # the data-dependent events then exercise the Fig. 4 error claim.
    import time

    t0 = time.perf_counter()
    for i in range(n_calls):
        scale = 1.0 + 0.1 * np.sin(0.37 * i)
        c, state = step(a * np.float32(scale), b, state, mp)
    jax.block_until_ready(c)
    wall = time.perf_counter() - t0
    est = scalpel.estimates(spec, state)["dgemm"]
    return {
        "impl": impl,
        "mode": "sampling" if multiplexed else "exhaustive",
        "calls": n_calls,
        "wall_s": round(wall, 3),
        "estimates": est,
        "analytic": cost,
    }


def main(fast: bool = False):
    m = n = k = 256
    n_calls = 200 if fast else 500
    period = 20 if fast else 100  # >= 2 full cycles over 5 sets
    results = []
    for impl in IMPLS:
        results.append(run_impl(impl, n_calls, m, n, k, multiplexed=False))
        results.append(run_impl(impl, n_calls, m, n, k, multiplexed=True,
                                period=period))
    save_json("case_study.json", results, sub="bench")

    # ---- Table 2: counter values per impl (sampling run) -----------------
    slot_ids = [s for g in EVENT_SETS for s in g]
    rows = []
    for sid in slot_ids:
        row = {"event": sid}
        for impl in IMPLS:
            samp = next(r for r in results
                        if r["impl"] == impl and r["mode"] == "sampling")
            row[impl] = f"{samp['estimates'][sid]:.3e}"
        rows.append(row)
    print(fmt_table(rows, ["event"] + list(IMPLS),
                    title="Table 2 analogue: per-call counters, "
                          f"multiplexed sampling run (period={period})"))

    # ---- Fig. 4: sampling vs exhaustive error + impl ratios ---------------
    err_rows = []
    for impl in IMPLS:
        ex = next(r for r in results
                  if r["impl"] == impl and r["mode"] == "exhaustive")
        sa = next(r for r in results
                  if r["impl"] == impl and r["mode"] == "sampling")
        for sid in slot_ids:
            e, s = ex["estimates"][sid], sa["estimates"][sid]
            if not np.isfinite(e) or e == 0:
                continue
            err_rows.append({
                "impl": impl, "event": sid,
                "exhaustive": f"{e:.4e}", "sampled": f"{s:.4e}",
                "err_pct": round(100 * abs(s - e) / abs(e), 3),
            })
    print()
    print(fmt_table(err_rows,
                    ["impl", "event", "exhaustive", "sampled", "err_pct"],
                    title="Fig. 4 analogue: multiplexed sampling vs "
                          "exhaustive (error should be marginal)"))
    max_err = max(r["err_pct"] for r in err_rows)
    print(f"\nmax sampling error: {max_err:.3f}% "
          f"(paper: 'the error introduced by sampling is marginal')")

    # ---- the case-study argument: counters explain the trade-off ----------
    g = next(r for r in results if r["impl"] == "goto"
             and r["mode"] == "sampling")["estimates"]
    a0 = next(r for r in results if r["impl"] == "atlas_default"
              and r["mode"] == "sampling")["estimates"]
    print("\ncase-study conclusion (goto vs atlas_default):")
    print(f"  HBM_BYTES        (≙L2_LINES_IN):   "
          f"{100 * (g['HBM_BYTES:hbm'] / a0['HBM_BYTES:hbm'] - 1):+.1f}%")
    print(f"  VMEM_TILE_REFILLS(≙DTLB_MISSES):   "
          f"{100 * (g['VMEM_TILE_REFILLS:refills'] / a0['VMEM_TILE_REFILLS:refills'] - 1):+.1f}%")
    print(f"  EST_STALL_CYCLES (≙RESOURCE_STALLS): "
          f"{100 * (g['EST_STALL_CYCLES:stalls'] / max(a0['EST_STALL_CYCLES:stalls'], 1e-9) - 1):+.1f}%")
    print(f"  FLOPS identical: "
          f"{g['FLOPS:flops'] == a0['FLOPS:flops']}")
    return results


if __name__ == "__main__":
    import sys

    main(fast="--fast" in sys.argv)
