"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms per cell, all PER-CHIP (the HLO is the SPMD per-device program;
hlo_graph scales while-loop bodies by their trip counts, which
``cost_analysis()`` does not):

    compute    = flops / PEAK_FLOPS
    memory     = hbm_bytes / HBM_BW
    collective = collective_link_bytes / ICI_BW

plus MODEL_FLOPS (6·N·D train, 2·N·D inference; N_active for MoE) and the
useful-compute ratio MODEL_FLOPS / (flops × chips).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCH_IDS, model_config
from repro.models import SHAPES
from repro.models.params import is_spec
from repro.models.registry import Arch

from .common import fmt_table, out_dir

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(arch_id: str) -> tuple[int, int]:
    """(total, active-per-token) parameter counts; MoE uses top_k/E experts."""
    import jax
    import numpy as np

    cfg = model_config(arch_id)
    arch = Arch(cfg)
    specs = arch.param_specs()
    total = active = 0
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec
    )[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        # expert-stacked leaves (axes carry "experts", possibly behind the
        # "layers" stacking axis) are active at top_k/E per token
        if cfg.moe.n_experts and leaf.axes and "experts" in leaf.axes \
                and "router" not in keys:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return total, int(active)


def model_flops(arch_id: str, shape_name: str) -> float:
    """6·N_active·D for training, 2·N_active·D for inference steps."""
    sh = SHAPES[shape_name]
    _, act = active_params(arch_id)
    # embedding lookups are not matmul flops; subtract the embed table for
    # the forward constant (standard 6ND convention keeps unembed only)
    cfg = model_config(arch_id)
    act_eff = act - cfg.vocab * cfg.d_model  # input embed is a gather
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * act_eff * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * act_eff * tokens
    # decode: one token per sequence
    return 2.0 * act_eff * sh.global_batch


def load_records(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyze_record(rec: dict) -> dict | None:
    if "skipped" in rec:
        return {
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "skipped": rec["skipped"],
        }
    g = rec.get("hlo_graph") or {}
    flops = g.get("flops") or rec["flops"]
    hbm = g.get("hbm_bytes") or rec["bytes_accessed"]
    coll = g.get("collective_link_bytes", rec["collective_link_bytes"])
    chips = rec["n_devices"]
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops * chips, 1.0)
    # roofline fraction: useful work at peak over the bound term
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_global": flops * chips,
        "useful_ratio": useful,
        "roofline_frac": frac,
        "coll_by_kind": g.get("collectives_by_kind",
                              rec.get("collectives_by_kind", {})),
        "temp_gib": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
        "args_gib": rec.get("memory", {}).get(
            "argument_size_in_bytes", 0) / 2**30,
        "unscaled_whiles": g.get("unscaled_whiles", -1),
    }


def note_for(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound but <50% useful FLOPs: cut remat/"
                    "recompute or masked-attention waste")
        return "compute-bound: raise arithmetic intensity only via bigger batch"
    if d == "memory":
        return ("HBM-bound: fuse/keep activations resident, widen "
                "microbatch, or shard stored tensors further")
    return ("collective-bound: reshard to cut all-gather volume or overlap "
            "collectives with compute")


def fmt_seconds(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def main(mesh: str = "16x16"):
    recs = [analyze_record(r) for r in load_records()]
    recs = [r for r in recs if r is not None]
    if not recs:
        print(f"roofline {mesh}: no dry-run artifacts under "
              "experiments/dryrun — run `python -m repro.launch.dryrun` "
              "first; skipping")
        return []
    rows = []
    for r in recs:
        if r.get("mesh") != mesh and "skipped" not in r:
            continue
        if "skipped" in r:
            if r.get("mesh", mesh) == mesh:
                rows.append({
                    "arch": r["arch"], "shape": r["shape"],
                    "dominant": "SKIP (" + r["skipped"][:32] + "...)",
                })
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute": fmt_seconds(r["compute_s"]),
            "memory": fmt_seconds(r["memory_s"]),
            "collective": fmt_seconds(r["collective_s"]),
            "dominant": r["dominant"],
            "useful": f"{r['useful_ratio']:.2f}",
            "roofline": f"{r['roofline_frac']:.2%}",
        })
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    sh_order = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda r: (order.get(r["arch"], 99),
                             sh_order.get(r["shape"], 9)))
    print(fmt_table(
        rows,
        ["arch", "shape", "compute", "memory", "collective", "dominant",
         "useful", "roofline"],
        title=f"Roofline terms per chip — mesh {mesh} "
              "(from dry-run compiled HLO)",
    ))
    full = [r for r in recs if "skipped" not in r]
    with open(os.path.join(out_dir("bench"), "roofline.json"), "w") as f:
        json.dump(full, f, indent=1, default=float)
    return full


if __name__ == "__main__":
    import sys

    main(mesh=sys.argv[1] if len(sys.argv) > 1 else "16x16")
