"""Paper Figs. 2-3: monitoring overhead of {vanilla, perfmon, all, selective}.

Reproduction mapping (DESIGN.md §2):
  vanilla    — the uninstrumented program (scopes exist, no collector)
  perfmon    — breakpoint_mode: an ordered io_callback host round-trip on
               every monitored-scope entry+exit (the ptrace analogue)
  all        — collector over the FULL compile-time scope set; only one
               scope's events are unmasked (paper: intercept all functions,
               monitor one)
  selective  — collector whose compile-time set contains ONLY the monitored
               scope

Workloads mirror the paper's two axes:
  * real apps (reduced NAS stand-ins): smoke configs of a dense, an SSM and
    an MoE arch, one training step each;
  * a synthetic call-count sweep (Fig. 3's tens .. tens-of-thousands of
    calls): a tiny function called k times per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.configs import model_config
from repro.core.backends import host_callback as hc
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams
from repro.models.registry import Arch
from repro.train.step import build_monitor_spec

from .common import bench, fmt_table, save_json


# ---------------------------------------------------------------------------
# builders for the four test cases
# ---------------------------------------------------------------------------

def _arch_loss(arch):
    def loss(params, batch):
        return arch.loss_fn(params, batch)
    return loss


def build_cases(loss_fn, params, batch, spec_all: MonitorSpec,
                monitored_scope: str):
    """Returns {case: jitted fn(state_or_none) -> loss} + per-case state."""
    grad = jax.grad(lambda p, b: loss_fn(p, b))

    def vanilla():
        f = jax.jit(lambda p, b: (loss_fn(p, b), grad(p, b)))
        return lambda: f(params, batch), None

    def perfmon():
        mon = hc.global_monitor()

        def step(p, b):
            return loss_fn(p, b), grad(p, b)

        with scalpel.breakpoint_mode(mon, scopes=[monitored_scope.split("/")[-1]]):
            f = jax.jit(step)
            f.lower(params, batch)  # trace inside the ctx so bps are planted
            # keep ctx open through first real call:
            return (lambda: f(params, batch)), mon

    def all_case():
        mp = MonitorParams.selective(spec_all, [monitored_scope])

        def step(p, b, state, mp):
            with scalpel.collecting(spec_all, mp, state) as col:
                l = loss_fn(p, b)
                g = jax.grad(lambda pp: loss_fn(pp, b))(p)
            return l, g, state.add(col.delta)

        f = jax.jit(step)
        s0 = CounterState.zeros(spec_all)
        return (lambda: f(params, batch, s0, mp)), None

    def selective():
        ctx = spec_all.context(monitored_scope)
        spec_sel = MonitorSpec.of([ctx])
        mp = MonitorParams.all_on(spec_sel)

        def step(p, b, state, mp):
            with scalpel.collecting(spec_sel, mp, state) as col:
                l = loss_fn(p, b)
                g = jax.grad(lambda pp: loss_fn(pp, b))(p)
            return l, g, state.add(col.delta)

        f = jax.jit(step)
        s0 = CounterState.zeros(spec_sel)
        return (lambda: f(params, batch, s0, mp)), None

    return {
        "vanilla": vanilla,
        "perfmon": perfmon,
        "all": all_case,
        "selective": selective,
    }


def run_arch_workloads(arch_ids=("qwen3_14b", "xlstm_125m", "dbrx_132b"),
                       iters: int = 5, seq: int = 64, batch_size: int = 4):
    rows = []
    for aid in arch_ids:
        cfg = model_config(aid, smoke=True)
        arch = Arch(cfg)
        params = arch.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (batch_size, seq), 0, cfg.vocab
        )
        batch = {"tokens": toks,
                 "targets": jax.random.randint(
                     jax.random.PRNGKey(2), (batch_size, seq), 0, cfg.vocab)}
        spec_all = build_monitor_spec(arch, batch)
        # monitor the mlp/ffn-ish scope (called n_layers times per step)
        cand = [s for s in spec_all.scopes
                if s.endswith(("mlp", "moe", "ssm", "mlstm", "ffn"))]
        scope = cand[0] if cand else spec_all.scopes[0]
        loss_fn = _arch_loss(arch)
        case_builders = build_cases(loss_fn, params, batch, spec_all, scope)
        base = None
        for case in ("vanilla", "selective", "all", "perfmon"):
            fn, mon = case_builders[case]()
            if case == "perfmon":
                hc.global_monitor().reset()
            r = bench(fn, iters=iters)
            t = r["min_s"]
            if case == "vanilla":
                base = t
            rows.append({
                "workload": aid, "case": case, "scope": scope,
                "n_scopes": spec_all.n_scopes,
                "median_ms": round(r["median_s"] * 1e3, 2),
                "min_ms": round(t * 1e3, 3),
                "overhead_pct": round(100 * (t - base) / base, 1),
                "bp_calls": sum(hc.global_monitor().calls.values())
                if case == "perfmon" else 0,
            })
    return rows


def run_callcount_sweep(counts=(16, 256, 1024), iters: int = 5):
    """Fig. 3's axis: overhead vs number of function calls per run."""
    rows = []
    for k in counts:
        spec = MonitorSpec.of([
            ScopeContext.exhaustive("hot", [EventSpec("ACT_RMS", "x")]),
            ScopeContext.exhaustive("cold", [EventSpec("ACT_RMS", "x")]),
        ])

        def work(x):
            # a cheap body so the instrumentation cost is visible
            for _ in range(k):
                with scalpel.function("hot"):
                    x = x * 1.0001 + 0.1
                    scalpel.probe(x=x)
            with scalpel.function("cold"):
                scalpel.probe(x=x)
            return x

        x0 = jnp.ones((128,))
        base = None
        for case in ("vanilla", "selective", "all", "perfmon"):
            if case == "vanilla":
                f = jax.jit(work)
                fn = lambda: f(x0)
            elif case == "perfmon":
                mon = hc.global_monitor()
                mon.reset()
                with scalpel.breakpoint_mode(mon, scopes=["hot"]):
                    f = jax.jit(work)
                    f.lower(x0)
                fn = lambda: f(x0)
            else:
                sp = spec if case == "all" else MonitorSpec.of(
                    [spec.context("hot")]
                )
                mp = MonitorParams.selective(sp, ["hot"])
                s0 = CounterState.zeros(sp)

                def step(x, s, mp, sp=sp):
                    with scalpel.collecting(sp, mp, s) as col:
                        y = work(x)
                    return y, s.add(col.delta)

                f = jax.jit(step)
                fn = lambda f=f, s0=s0, mp=mp: f(x0, s0, mp)
            r = bench(fn, iters=iters)
            t = r["min_s"]
            if case == "vanilla":
                base = t
            rows.append({
                "workload": f"calls={k}", "case": case,
                "median_ms": round(r["median_s"] * 1e3, 3),
                "min_ms": round(t * 1e3, 3),
                "overhead_pct": round(100 * (t - base) / base, 1),
                "per_call_us": round(1e6 * (t - base) / max(k, 1), 3),
            })
    return rows


def main(fast: bool = False):
    iters = 3 if fast else 5
    rows = run_arch_workloads(iters=iters)
    rows += run_callcount_sweep(
        counts=(16, 256) if fast else (16, 256, 1024), iters=iters
    )
    save_json("overhead.json", rows, sub="bench")
    print(fmt_table(
        rows,
        ["workload", "case", "min_ms", "overhead_pct", "per_call_us",
         "bp_calls"],
        title="ScALPEL overhead: vanilla / selective / all / perfmon "
              "(paper Figs. 2-3)",
    ))
    # the paper's hierarchy, asserted softly
    by = {}
    for r in rows:
        by.setdefault(r["workload"], {})[r["case"]] = r["min_ms"]
    ok = sum(
        1 for w, c in by.items()
        if c["perfmon"] >= max(c["selective"], c["all"]) * 0.9
    )
    print(f"\nhierarchy check: perfmon slowest in {ok}/{len(by)} workloads")
    return rows


if __name__ == "__main__":
    import sys

    main(fast="--fast" in sys.argv)
