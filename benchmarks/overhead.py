"""Paper Figs. 2-3: monitoring overhead of {vanilla, perfmon, all, selective}.

Reproduction mapping (DESIGN.md §2):
  vanilla    — the uninstrumented program (scopes exist, no collector)
  perfmon    — breakpoint_mode: an ordered io_callback host round-trip on
               every monitored-scope entry+exit (the ptrace analogue)
  all        — collector over the FULL compile-time scope set; only one
               scope's events are unmasked (paper: intercept all functions,
               monitor one)
  selective  — collector whose compile-time set contains ONLY the monitored
               scope

Probe evaluation is plan-driven (core/plan.py): every (scope, event set)
executes its compiled MomentPlan — exactly the channels that set finalizes
from, swept once per probed tensor.  A dedicated sparse-active-set sweep
(``run_plan_sweep``) measures the point of the plan layer: a multiplexed
scope whose every set needs a strict SUBSET of the union of channels, run
once with per-set plans and once with the ``plan_mode="union"`` baseline
(the pre-plan behaviour: each branch sweeps the cross-set union), with an
allclose check that both accumulate identical counters.

Workloads mirror the paper's two axes:
  * real apps (reduced NAS stand-ins): smoke configs of a dense, an SSM and
    an MoE arch, one training step each;
  * a synthetic call-count sweep (Fig. 3's axis; tens of calls in fast/CI
    mode, up to 1024 in full mode — the unrolled 6-event graphs there cost
    minutes of XLA CPU compile): a small function called k times per step,
    probing the motivation's six activation statistics.

``run_monitor_sweep`` measures the functional API redesign: a
``Monitor.wrap``-ped step threading ONE compact MonitorState pytree vs the
manual deprecated ``collecting()`` + ``state.add(col.delta)`` path on the
same workload (counters asserted allclose), and ``run_monitor_psum_check``
(a 2-forced-host-device subprocess) asserts that a ``shard_wrap``-ped step's
psum-reduced counters EXACTLY equal the sum of per-shard manual runs.

Additionally, a readback-stall sweep (``run_readback_sweep``) measures the
cost of CONSUMING counters: a synchronous full-CounterState ``device_get``
every ``hook_every`` steps (the pre-telemetry runtime) vs the telemetry
plane's device-side snapshot ring drained incrementally (cursor-based slot
copies) by a background thread, across ``hook_every`` and ring-depth
settings, with an allclose check that drained counters equal synchronous
snapshots.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.configs import model_config
from repro.core import plan as plan_lib
from repro.core import telemetry as telemetry_lib
from repro.core.backends import host_callback as hc
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams
from repro.models.registry import Arch
from repro.train.step import build_monitor_spec

from .common import bench, fmt_table, save_json

# The motivation's six per-tensor statistics — all moment-derived, so the
# planned path reads each probed tensor exactly once for all of them.
PROBE_EVENTS = (
    "ACT_RMS", "ACT_MEAN_ABS", "ACT_MAX_ABS", "ACT_ZERO_FRAC",
    "NAN_COUNT", "INF_COUNT",
)

CASE_ORDER = ("vanilla", "selective", "all", "perfmon")


# ---------------------------------------------------------------------------
# builders for the test cases
# ---------------------------------------------------------------------------

def _arch_loss(arch):
    def loss(params, batch):
        return arch.loss_fn(params, batch)
    return loss


def build_cases(loss_fn, params, batch, spec_all: MonitorSpec,
                monitored_scope: str):
    """Returns {case: builder}; builder() -> (fn, monitor).  Monitored-case
    ``fn`` returns a tuple whose LAST element is the accumulated
    CounterState."""
    grad = jax.grad(lambda p, b: loss_fn(p, b))

    def vanilla():
        f = jax.jit(lambda p, b: (loss_fn(p, b), grad(p, b)))
        return lambda: f(params, batch), None

    def perfmon():
        mon = hc.global_monitor()

        def step(p, b):
            return loss_fn(p, b), grad(p, b)

        with scalpel.breakpoint_mode(mon, scopes=[monitored_scope.split("/")[-1]]):
            f = jax.jit(step)
            f.lower(params, batch)  # trace inside the ctx so bps are planted
            # keep ctx open through first real call:
            return (lambda: f(params, batch)), mon

    def collector_case(spec_case, mp):
        def step(p, b, state, mp):
            with scalpel.collecting(spec_case, mp, state) as col:
                l = loss_fn(p, b)
                g = jax.grad(lambda pp: loss_fn(pp, b))(p)
            return l, g, state.add(col.delta)

        f = jax.jit(step)
        s0 = CounterState.zeros(spec_case)
        return (lambda: f(params, batch, s0, mp)), None

    def all_case():
        mp = MonitorParams.selective(spec_all, [monitored_scope])
        return collector_case(spec_all, mp)

    def selective():
        ctx = spec_all.context(monitored_scope)
        spec_sel = MonitorSpec.of([ctx])
        return collector_case(spec_sel, MonitorParams.all_on(spec_sel))

    return {
        "vanilla": vanilla,
        "perfmon": perfmon,
        "all": all_case,
        "selective": selective,
    }


def run_arch_workloads(arch_ids=("qwen3_14b", "xlstm_125m", "dbrx_132b"),
                       iters: int = 5, seq: int = 64, batch_size: int = 4):
    rows = []
    for aid in arch_ids:
        cfg = model_config(aid, smoke=True)
        arch = Arch(cfg)
        params = arch.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (batch_size, seq), 0, cfg.vocab
        )
        batch = {"tokens": toks,
                 "targets": jax.random.randint(
                     jax.random.PRNGKey(2), (batch_size, seq), 0, cfg.vocab)}
        spec_all = build_monitor_spec(arch, batch, tensor_events=PROBE_EVENTS)
        # monitor the mlp/ffn-ish scope (called n_layers times per step)
        cand = [s for s in spec_all.scopes
                if s.endswith(("mlp", "moe", "ssm", "mlstm", "ffn"))]
        scope = cand[0] if cand else spec_all.scopes[0]
        loss_fn = _arch_loss(arch)
        case_builders = build_cases(loss_fn, params, batch, spec_all, scope)
        built = {}
        for case in CASE_ORDER:
            fn, mon = case_builders[case]()
            built[case] = fn
            if case == "perfmon":
                hc.global_monitor().reset()
        # two round-robin measurement passes (min taken): a host load spike
        # then skews every case equally instead of poisoning one row.
        results = {c: [] for c in CASE_ORDER}
        for rnd in range(2):
            for case in CASE_ORDER:
                if case == "perfmon" and rnd == 1:
                    # reset so bp_calls reflects ONE bench (2 warmups +
                    # iters), keeping the count comparable across PRs.
                    hc.global_monitor().reset()
                results[case].append(bench(built[case], iters=iters))
        base = None
        for case in CASE_ORDER:
            t = min(r["min_s"] for r in results[case])
            med = min(r["median_s"] for r in results[case])
            if case == "vanilla":
                base = t
            rows.append({
                "workload": aid, "case": case, "scope": scope,
                "n_scopes": spec_all.n_scopes,
                "median_ms": round(med * 1e3, 2),
                "min_ms": round(t * 1e3, 3),
                "overhead_pct": round(100 * (t - base) / base, 1),
                "bp_calls": sum(hc.global_monitor().calls.values())
                if case == "perfmon" else 0,
            })
    return rows


def run_callcount_sweep(counts=(64, 256, 512), iters: int = 7,
                        probe_size: int = 4096, rounds: int = 2):
    """Fig. 3's axis: overhead vs number of function calls per run.

    Every case is measured ``rounds`` times round-robin (min taken) so a
    transient load spike on the host doesn't poison one case's timing.
    """
    rows = []
    for k in counts:
        slots = [EventSpec(e, "x") for e in PROBE_EVENTS]
        spec = MonitorSpec.of([
            ScopeContext.exhaustive("hot", slots),
            ScopeContext.exhaustive("cold", slots),
        ])

        def fresh_work():
            # one function object PER CASE: jax.jit's global cache keys on
            # the function identity, so sharing `work` across cases would
            # let the breakpoint-instrumented perfmon trace alias the
            # vanilla one (and vice versa), corrupting both measurements.
            def work(x):
                # a cheap body so the instrumentation cost is visible
                for _ in range(k):
                    with scalpel.function("hot"):
                        x = x * 1.0001 + 0.1
                        scalpel.probe(x=x)
                with scalpel.function("cold"):
                    scalpel.probe(x=x)
                return x

            return work

        x0 = jnp.ones((probe_size,))

        def monitored(sp):
            mp = MonitorParams.selective(sp, ["hot"])
            s0 = CounterState.zeros(sp)

            work = fresh_work()

            def step(x, s, mp, sp=sp, work=work):
                with scalpel.collecting(sp, mp, s) as col:
                    y = work(x)
                return y, s.add(col.delta)

            f = jax.jit(step)
            return lambda f=f, s0=s0, mp=mp: f(x0, s0, mp)

        spec_sel = MonitorSpec.of([spec.context("hot")])
        built = {}
        for case in CASE_ORDER:
            if case == "vanilla":
                f = jax.jit(fresh_work())
                fn = lambda f=f: f(x0)
            elif case == "perfmon":
                mon = hc.global_monitor()
                mon.reset()
                with scalpel.breakpoint_mode(mon, scopes=["hot"]):
                    f = jax.jit(fresh_work())
                    f.lower(x0)
                fn = lambda f=f: f(x0)
            else:
                fn = monitored(spec if case == "all" else spec_sel)
            built[case] = fn
        results = {c: [] for c in CASE_ORDER}
        for _ in range(rounds):
            for case in CASE_ORDER:
                results[case].append(bench(built[case], iters=iters))
        base = None
        for case in CASE_ORDER:
            t = min(r["min_s"] for r in results[case])
            med = min(r["median_s"] for r in results[case])
            if case == "vanilla":
                base = t
            rows.append({
                "workload": f"calls={k}", "case": case,
                "median_ms": round(med * 1e3, 3),
                "min_ms": round(t * 1e3, 3),
                "overhead_pct": round(100 * (t - base) / base, 1),
                "per_call_us": round(1e6 * (t - base) / max(k, 1), 3),
            })
    return rows


# ---------------------------------------------------------------------------
# sparse-active-set plan sweep: per-set MomentPlans vs the union baseline
# ---------------------------------------------------------------------------

# Every multiplexed set needs a strict SUBSET of the union of channels —
# the configuration the probe-plan compiler exists for.  Union sweep: 6
# data channels per branch; per-set sweeps: 1 / 1 / 1 / 3 channels.
PLAN_SETS = (
    ("ACT_MAX_ABS:x",),
    ("ACT_ZERO_FRAC:x",),
    ("NAN_COUNT:x",),
    ("ACT_RMS:x", "ACT_MEAN_ABS:x", "MEAN:x"),
)


def _plan_spec(period: int = 1) -> MonitorSpec:
    sets = [[EventSpec.parse(s) for s in grp] for grp in PLAN_SETS]
    return MonitorSpec.of([
        ScopeContext.multiplexed("hot", sets, period=period)
    ])


def run_plan_sweep(probe_sizes=(1 << 14, 1 << 16), k: int = 24,
                   iters: int = 7, rounds: int = 3):
    """Per-set plans vs the union baseline on a sparse-active-set workload.

    A scope multiplexed over PLAN_SETS is called ``k`` times per jitted
    step; each call's active set sweeps only its own channels under
    ``plan_mode="per_set"`` and the full cross-set union under
    ``plan_mode="union"`` (the pre-plan hot path).  Identical schedules,
    identical counters (asserted allclose) — only the per-branch sweep
    width differs, which is exactly the cost the plan layer removes.
    """
    spec = _plan_spec()
    ctx = spec.context("hot")
    plans = plan_lib.compile_scope_plans(ctx, frozenset({"x"}))
    union_plans = plan_lib.compile_scope_plans(ctx, frozenset({"x"}), True)
    per_set_chans = [p.sweep_channel_count for p in plans.plans]
    union_chans = [p.sweep_channel_count for p in union_plans.plans]

    rows = []
    for n in probe_sizes:
        x0 = jnp.ones((n,)) * 1.5
        mp = MonitorParams.all_on(spec)

        def make(plan_mode):
            def work(x):
                for _ in range(k):
                    with scalpel.function("hot"):
                        x = x * 1.0001 + 0.1
                        scalpel.probe(x=x)
                return x

            def step(x, s, mp, plan_mode=plan_mode, work=work):
                with scalpel.collecting(spec, mp, s,
                                        plan_mode=plan_mode) as col:
                    y = work(x)
                return y, s.add(col.delta)

            f = jax.jit(step)
            s0 = CounterState.zeros(spec)
            return lambda f=f, s0=s0: f(x0, s0, mp)

        built = {m: make(m) for m in ("per_set", "union")}
        sa = built["per_set"]()[-1]
        sb = built["union"]()[-1]
        allclose = bool(
            np.allclose(np.asarray(sa.values), np.asarray(sb.values),
                        rtol=1e-4, atol=1e-6, equal_nan=True)
            and np.array_equal(np.asarray(sa.samples),
                               np.asarray(sb.samples))
        )
        results = {m: [] for m in built}
        for _ in range(rounds):
            for m in built:
                results[m].append(bench(built[m], iters=iters))
        mins = {m: min(r["min_s"] for r in results[m]) for m in built}
        workload = f"plan n={n}"
        rows.append({
            "workload": workload, "case": "plan_union",
            "min_ms": round(mins["union"] * 1e3, 3),
            "calls": k, "probe_size": n,
            "sweep_channels": union_chans,
        })
        rows.append({
            "workload": workload, "case": "plan_per_set",
            "min_ms": round(mins["per_set"] * 1e3, 3),
            "calls": k, "probe_size": n,
            "sweep_channels": per_set_chans,
            "union_min_ms": round(mins["union"] * 1e3, 3),
            "plan_gain_pct": round(
                100.0 * (mins["union"] - mins["per_set"]) / mins["union"], 1
            ),
            "plan_allclose": allclose,
        })
    return rows


def _plan_summary(rows: list[dict]) -> dict:
    """Aggregate per-set-plan vs union verdicts for the trajectory JSON."""
    per_set = [r for r in rows if r.get("case") == "plan_per_set"]
    return {
        "compared": len(per_set),
        "per_set_faster": sum(
            1 for r in per_set if r["min_ms"] < r["union_min_ms"]
        ),
        "strictly_faster": bool(per_set) and all(
            r["min_ms"] < r["union_min_ms"] for r in per_set
        ),
        "allclose_all": all(
            r.get("plan_allclose", False) for r in per_set
        ),
        "max_gain_pct": max(
            (r["plan_gain_pct"] for r in per_set), default=None
        ),
    }


# ---------------------------------------------------------------------------
# Monitor.wrap vs the manual collecting() path (functional API redesign)
# ---------------------------------------------------------------------------

def _monitor_spec() -> MonitorSpec:
    """One hot scope probing the six statistics + many narrow scopes: the
    padded [n_scopes, max_slots] block (96 lanes) is ~4.5x the compact
    dense footprint (21 lanes) — the per-step padded build/add the Monitor
    path deletes."""
    ctxs = [ScopeContext.exhaustive("hot",
                                    [EventSpec(e, "x") for e in PROBE_EVENTS])]
    ctxs += [
        ScopeContext.exhaustive(f"aux{i}", [EventSpec("MEAN", "x")])
        for i in range(15)
    ]
    return MonitorSpec.of(ctxs)


def run_monitor_sweep(probe_sizes=(1 << 12, 1 << 14), k: int = 16,
                      iters: int = 7, rounds: int = 3):
    """Functional ``Monitor.jit`` (one MonitorState pytree, compact
    counters end-to-end) vs the manual ``collecting()`` + ``state.add``
    baseline, on identical workloads at 16-64 KiB probes.

    The workload stacks ``k`` monitored layers inside
    ``scan_with_counters`` (the production shape) plus 15 narrow scopes:
    the wrapped step keeps the scan's compact carry compact through
    finalization and outputs only the dense footprint, while the manual
    path expands to — and accumulates in — the padded
    ``[n_scopes, max_slots]`` block every step.  Counters are asserted
    allclose after expanding the compact lanes back to the padded view.
    """
    import warnings

    spec = _monitor_spec()
    lay = plan_lib.spec_layout(spec)

    rows = []
    for n in probe_sizes:
        x0 = jnp.ones((n,)) * 1.5
        mp = MonitorParams.all_on(spec)

        def work(x):
            def layer(c, _):
                with scalpel.function("hot"):
                    c = c * 1.0001 + 0.1
                    scalpel.probe(x=c)
                return c, None

            x, _ = scalpel.scan_with_counters(layer, x, None, length=k)
            for i in range(15):
                with scalpel.function(f"aux{i}"):
                    scalpel.probe(x=x)
            return x

        # manual baseline: the deprecated hand-threaded path, threaded and
        # donated exactly like the pre-Monitor train loop donated its
        # counter-carrying TrainState
        def man_step(x, s, mp):
            with scalpel.collecting(spec, mp, s) as col:
                y = work(x)
            return y, s.add(col.delta)

        f_man = jax.jit(man_step, donate_argnums=(1,))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            f_man(x0, CounterState.zeros(spec), mp)  # trace quietly

        # wrapped path: one MonitorState pytree, leaf-wise jit boundary,
        # state donated the same way (no telemetry ring here — the manual
        # baseline carries none)
        mon = scalpel.Monitor(spec, mp, counter_axes=())
        f_wrap = mon.jit(work, donate_state=True)

        def manual(s):
            return f_man(x0, s, mp)[-1]

        def wrapped(ms):
            return f_wrap(ms, x0)[-1]

        s_man = manual(CounterState.zeros(spec))
        ms1 = wrapped(mon.init())
        s_wrap = mon.counter_state(ms1)
        allclose = bool(
            np.allclose(np.asarray(s_wrap.values), np.asarray(s_man.values),
                        rtol=1e-4, atol=1e-6, equal_nan=True)
            and np.array_equal(np.asarray(s_wrap.samples),
                               np.asarray(s_man.samples))
            and np.array_equal(np.asarray(s_wrap.calls),
                               np.asarray(s_man.calls))
        )
        # Single steps are ~0.3-1 ms here and a shared CPU host jitters
        # per-dispatch by ±25%: time BLOCKS of back-to-back THREADED steps
        # (state carried call to call, one block_until_ready at the end,
        # donation live on both sides — the production steady state),
        # alternate the order every round, and judge on the median of
        # per-round block times — the long windows amortize scheduler
        # noise below the effect size.
        import statistics
        import time as time_lib

        def block_time(step, fresh, calls):
            s = fresh()
            for _ in range(3):
                s = step(s)
            jax.block_until_ready(s)
            t0 = time_lib.perf_counter()
            for _ in range(calls):
                s = step(s)
            jax.block_until_ready(s)
            return (time_lib.perf_counter() - t0) / calls

        built = {
            "monitor_manual": (manual, lambda: CounterState.zeros(spec)),
            "monitor_wrap": (wrapped, mon.init),
        }
        results = {m: [] for m in built}
        order = list(built)
        # steps here are sub-millisecond, so generous windows are cheap:
        # ~40-step blocks x 2-3x the requested rounds keeps the median
        # stable against minute-scale drift on a shared host
        block = max(40, iters * 6)
        for rnd in range(max(10, rounds * 2)):
            for m in (order if rnd % 2 == 0 else reversed(order)):
                step, fresh = built[m]
                results[m].append(block_time(step, fresh, block))
        med = {m: statistics.median(results[m]) for m in built}
        best = {m: min(results[m]) for m in built}
        # The VERDICT is the median of per-round PAIRED ratios: the two
        # blocks of a round run back-to-back, so minute-scale host drift
        # (which moves absolute medians by ±15% between trials) hits both
        # sides of each ratio almost equally and cancels.
        ratios = [w / m for w, m in zip(results["monitor_wrap"],
                                        results["monitor_manual"])]
        med_ratio = statistics.median(ratios)
        workload = f"monitor n={n}"
        kib = n * 4 // 1024
        rows.append({
            "workload": workload, "case": "monitor_manual",
            "min_ms": round(best["monitor_manual"] * 1e3, 3),
            "med_ms": round(med["monitor_manual"] * 1e3, 3),
            "calls": k, "probe_size": n, "probe_kib": kib,
            "steps_per_commit": 1,
            "state_lanes": spec.n_scopes * spec.max_slots,
        })
        rows.append({
            "workload": workload, "case": "monitor_wrap",
            "min_ms": round(best["monitor_wrap"] * 1e3, 3),
            "med_ms": round(med["monitor_wrap"] * 1e3, 3),
            "calls": k, "probe_size": n, "probe_kib": kib,
            "steps_per_commit": 1,
            "state_lanes": lay.total,
            "manual_med_ms": round(med["monitor_manual"] * 1e3, 3),
            "wrap_over_manual_ratio": round(med_ratio, 4),
            "wrap_gain_pct": round(100.0 * (1.0 - med_ratio), 1),
            "wrap_allclose": allclose,
        })
    return rows


def run_megastep_sweep(probe_size: int = 1 << 10, ks=(1, 4, 16),
                       steps_per_round: int = 64, rounds: int = 3):
    """Steps-per-commit sweep: ``mon.jit(work, steps_per_commit=K)`` — the
    K-step ``Monitor.scan`` megastep — against K=1, per-step, on a
    SHORT-step workload (single hot scope, 4 KiB probe, ~100µs steps).

    Short steps are where the per-call fixed cost — host dispatch, open a
    collector, commit, rebuild the state wrapper — dominates; the megastep
    amortizes all of it over K steps inside one ``lax.scan``.  Every case
    runs the same TOTAL number of monitored steps per timed block (a K=16
    block makes 16x fewer host dispatches, not less work), and the K>1
    counters are asserted exactly against K unrolled K=1 steps from the
    same init — fused and unrolled megasteps are the same program.
    """
    import statistics
    import time as time_lib

    spec = MonitorSpec.of([
        ScopeContext.exhaustive("hot",
                                [EventSpec(e, "x") for e in PROBE_EVENTS]),
    ])
    x0 = jnp.ones((probe_size,)) * 1.5
    mon = scalpel.Monitor(spec, counter_axes=())

    def work(x):
        with scalpel.function("hot"):
            x = x * 1.0001 + 0.1
            scalpel.probe(x=x)
        return x

    ks = tuple(sorted(set(ks)))
    assert 1 in ks and all(steps_per_round % K == 0 for K in ks)
    built = {K: mon.jit(work, steps_per_commit=K, donate_state=True)
             for K in ks}

    # exactness first: one K-step megastep == K unrolled commits
    plain = mon.jit(work)   # un-donated K=1 reference
    allclose = {}
    for K in ks:
        ms_a = mon.init()
        _, ms_a = built[K](ms_a, x0)
        ms_b, xb = mon.init(), x0
        for _ in range(K):
            xb, ms_b = plain(ms_b, xb)
        allclose[K] = bool(
            np.allclose(np.asarray(ms_a.values), np.asarray(ms_b.values),
                        rtol=1e-5, atol=1e-7)
            and np.array_equal(np.asarray(ms_a.samples),
                               np.asarray(ms_b.samples))
            and np.array_equal(np.asarray(ms_a.calls),
                               np.asarray(ms_b.calls))
            and int(ms_a.step) == int(ms_b.step) == K
        )

    def block_time(K) -> float:
        """Seconds per MONITORED STEP over a block of steps_per_round."""
        f, ms, x = built[K], mon.init(), x0
        for _ in range(2):
            x, ms = f(ms, x)
        jax.block_until_ready((x, ms.step))
        t0 = time_lib.perf_counter()
        for _ in range(steps_per_round // K):
            x, ms = f(ms, x)
        jax.block_until_ready((x, ms.step))
        return (time_lib.perf_counter() - t0) / steps_per_round

    results = {K: [] for K in ks}
    order = list(ks)
    for rnd in range(max(6, rounds * 2)):
        for K in (order if rnd % 2 == 0 else reversed(order)):
            results[K].append(block_time(K))
    med = {K: statistics.median(results[K]) for K in ks}

    rows = []
    for K in ks:
        row = {
            "workload": f"megastep n={probe_size}", "case": "monitor_scan",
            "steps_per_commit": K, "probe_size": probe_size,
            "per_step_us": round(med[K] * 1e6, 2),
            "min_per_step_us": round(min(results[K]) * 1e6, 2),
            "scan_allclose": allclose[K],
        }
        if K != 1:
            # paired per-round ratios: both block times of a round run
            # close together, so host drift cancels (same verdict rule as
            # the wrap-vs-manual sweep)
            ratios = [a / b for a, b in zip(results[K], results[1])]
            med_ratio = statistics.median(ratios)
            row["k1_per_step_us"] = round(med[1] * 1e6, 2)
            row["scan_over_k1_ratio"] = round(med_ratio, 4)
            row["scan_gain_pct"] = round(100.0 * (1.0 - med_ratio), 1)
        rows.append(row)
    return rows


def run_train_boundary_check(k: int = 4) -> list[dict]:
    """The leaf-wise TRAIN jit boundary: the compiled megastep takes the
    read-only ``MonitorParams``/``TelemetryParams`` as inputs but never
    outputs them (the host wrapper reattaches the caller's objects), and
    the ``TrainState`` is donated — checked on the smoke xlstm via object
    identity, compiled output-leaf accounting, and the HLO's
    input_output_alias table.
    """
    from repro.configs import model_config
    from repro.models.registry import Arch
    from repro.optim import OptConfig
    from repro.train.step import (TrainState, build_monitor_spec,
                                  make_train_megastep)

    cfg = model_config("xlstm_125m", smoke=True)
    arch = Arch(cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                               jnp.int32),
    }
    spec = build_monitor_spec(arch, batch)
    mon = scalpel.Monitor(spec, counter_axes=())
    step = make_train_megastep(arch, OptConfig(), spec, monitor=mon)
    jit_step = mon.jit_wrapped(step, donate_argnums=(1,))  # donate tstate

    tstate = TrainState.create(arch, OptConfig(), jax.random.PRNGKey(0))
    ms = mon.init()
    batches = jax.tree.map(lambda v: jnp.stack([v] * k), batch)
    core_args = (ms.calls, ms.values, ms.samples, ms.sched_calls, ms.step,
                 ms.ring, ms.params, ms.tparams, batches, tstate)
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), a.dtype), core_args)
    n_out_leaves = len(jax.tree.leaves(
        jax.eval_shape(jit_step._cjit, *abstract)))
    n_param_leaves = len(jax.tree.leaves((ms.params, ms.tparams)))
    hlo = jit_step._cjit.lower(*abstract).compile().as_text()
    tstate_donated = "input_output_alias" in hlo

    (tstate2, outs), ms2 = jit_step(ms, batches, tstate)
    return [{
        "workload": "train xlstm_125m smoke",
        "case": "train_megastep_boundary", "steps_per_commit": k,
        # the boundary claim: the SAME host objects come back — params
        # never leave (or re-enter through) the compiled program
        "params_reattached": bool(ms2.params is ms.params
                                  and ms2.tparams is ms.tparams),
        "compiled_out_leaves": n_out_leaves,
        "param_leaves_excluded": n_param_leaves,
        "tstate_donated": bool(tstate_donated),
        "loss_finite": bool(np.isfinite(np.asarray(outs["loss"])).all()),
        "steps_taken": int(ms2.step),
    }]


_PSUM_2DEV_SCRIPT = r"""
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.dist.partition import sharding_ctx

assert jax.device_count() == 2, jax.devices()
EVENTS = %r
spec = MonitorSpec.of([
    ScopeContext.exhaustive("hot", [EventSpec(e, "x") for e in EVENTS]),
])


def work(x):
    with scalpel.function("hot"):
        x = x * 1.0001 + 0.1
        scalpel.probe(x=x)
    return x


from jax.sharding import PartitionSpec as P

mon = scalpel.Monitor(spec)
mesh = jax.make_mesh((2,), ("data",))
with sharding_ctx(mesh):
    step = jax.jit(mon.shard_wrap(work, mesh, in_specs=P("data"),
                                  out_specs=P("data")))
    x = jnp.arange(8192.0) / 8192.0
    out, ms = step(mon.init(), x)

# per-shard manual baseline, summed on the host
mon1 = scalpel.Monitor(spec, counter_axes=())
w1 = mon1.wrap(work)
a = mon1.init()
b = mon1.init()
_, a = w1(a, x[:4096])
_, b = w1(b, x[4096:])
calls = np.asarray(a.calls) + np.asarray(b.calls)
values = np.asarray(a.values) + np.asarray(b.values)
samples = np.asarray(a.samples) + np.asarray(b.samples)
print(json.dumps({
    "devices": jax.device_count(),
    "counters_equal": bool(
        np.array_equal(np.asarray(ms.calls), calls)
        and np.array_equal(np.asarray(ms.values), values)
        and np.array_equal(np.asarray(ms.samples), samples)
    ),
    "psum_calls": np.asarray(ms.calls).tolist(),
    "shard_sum_calls": calls.tolist(),
}))
"""


def run_monitor_psum_check() -> list[dict]:
    """The 2-device forced-host acceptance check: a ``shard_wrap``-ped step
    on a (2,) data mesh must produce counters EXACTLY equal to the sum of
    two per-shard manual runs — ScALPEL reports become cluster-wide sums.

    Runs in a subprocess because the forced device count must be set
    before JAX initializes.
    """
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in sys.path if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", _PSUM_2DEV_SCRIPT % (PROBE_EVENTS,)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    row = {"workload": "monitor 2dev", "case": "monitor_psum_2dev"}
    if proc.returncode != 0:
        row.update(error=proc.stderr[-1000:], counters_equal=False)
        return [row]
    row.update(json.loads(proc.stdout.strip().splitlines()[-1]))
    return [row]


def _monitor_summary(rows: list[dict]) -> dict:
    """Aggregate Monitor.wrap vs manual verdicts for the trajectory JSON."""
    wrap = [r for r in rows if r.get("case") == "monitor_wrap"]
    psum = [r for r in rows if r.get("case") == "monitor_psum_2dev"]
    scan = [r for r in rows if r.get("case") == "monitor_scan"]
    k16 = [r for r in scan if r.get("steps_per_commit") == 16]
    train = [r for r in rows if r.get("case") == "train_megastep_boundary"]
    return {
        # megastep (steps-per-commit) verdicts
        "megastep_k16_gain_pct": max(
            (r["scan_gain_pct"] for r in k16), default=None
        ),
        "megastep_speedup_15pct": bool(k16) and all(
            r["scan_over_k1_ratio"] <= 0.85 for r in k16
        ),
        "megastep_allclose": bool(scan) and all(
            r.get("scan_allclose", False) for r in scan
        ),
        "train_params_not_output": bool(train) and all(
            r.get("params_reattached", False) for r in train
        ),
        "train_tstate_donated": bool(train) and all(
            r.get("tstate_donated", False) for r in train
        ),
        "compared": len(wrap),
        "wrap_not_slower": sum(
            1 for r in wrap if r["wrap_over_manual_ratio"] <= 1.0
        ),
        # the honest verdict on a noisy shared host: the paired-ratio
        # medians repeatedly land within ~±3% of 1.0 (the wrapped step's
        # compiled module is strictly SMALLER — ~14% fewer HLO ops — but
        # both are dominated by the identical probe sweeps)
        "wrap_parity_3pct": all(
            r["wrap_over_manual_ratio"] <= 1.03 for r in wrap
        ),
        "allclose_all": all(r.get("wrap_allclose", False) for r in wrap),
        "max_gain_pct": max(
            (r["wrap_gain_pct"] for r in wrap), default=None
        ),
        "psum_2dev_equal": bool(psum) and all(
            r.get("counters_equal", False) for r in psum
        ),
    }


def run_readback_sweep(hook_everys=(1, 4), depths=(4, 16), steps: int = 32,
                       rounds: int = 3, k: int = 16, probe_size: int = 4096):
    """Readback-stall sweep (telemetry plane): synchronous full-CounterState
    ``device_get`` every ``hook_every`` steps vs an in-graph snapshot-ring
    append drained by the background telemetry thread.

    ``readback_sync`` is what the pre-telemetry runtime paid per report/adapt
    decision; ``readback_ring`` is the async plane.  The ring rows also check
    that the drained cumulative counters are allclose to the synchronous
    snapshot at the same step, and record how many ring slots the
    incremental (cursor-based) drain actually copied.
    """
    slots = [EventSpec(e, "x") for e in PROBE_EVENTS]
    spec = MonitorSpec.of([ScopeContext.exhaustive("hot", slots)])
    mp = MonitorParams.all_on(spec)
    x0 = jnp.ones((probe_size,))

    def work(x):
        for _ in range(k):
            with scalpel.function("hot"):
                x = x * 1.0001 + 0.1
                scalpel.probe(x=x)
        return x

    def step_sync(x, s, mp):
        with scalpel.collecting(spec, mp, s) as col:
            y = work(x)
        return y, s.add(col.delta)

    def step_ring(x, s, ring, step, mp, tp):
        with scalpel.collecting(spec, mp, s) as col:
            y = work(x)
        s2 = s.add(col.delta)
        step = step + 1  # stamp carried on device: no per-step host traffic
        return y, s2, telemetry_lib.ring_append(ring, s2, tp, step), step

    f_sync = jax.jit(step_sync)
    f_ring = jax.jit(step_ring)

    rows = []
    for he in hook_everys:

        def run_sync():
            x, s = x0, CounterState.zeros(spec)
            for i in range(1, steps + 1):
                x, s = f_sync(x, s, mp)
                if i % he == 0:
                    s_host = jax.tree.map(jax.device_get, s)  # the stall
            jax.block_until_ready(x)
            return s_host

        sync_state = run_sync()  # warmup (compile) + reference counters
        ts = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            run_sync()
            ts.append(time.perf_counter() - t0)
        sync_ms = min(ts) * 1e3
        rows.append({
            "workload": f"readback he={he}", "case": "readback_sync",
            "hook_every": he, "ring_depth": 0, "steps": steps,
            "min_ms": round(sync_ms, 3),
            "per_step_us": round(1e3 * sync_ms / steps, 3),
        })

        for depth in depths:
            plane = telemetry_lib.TelemetryPlane(
                spec, depth=depth, cadence=he, interval_s=0.002,
            )
            drained = []
            plane.add_sink(
                telemetry_lib.CallbackSink(lambda s: drained.append(s.step))
            )

            def run_ring():
                x, s = x0, CounterState.zeros(spec)
                ring = plane.make_ring()
                i = jnp.zeros((), jnp.int32)
                for _ in range(steps):
                    x, s, ring, i = f_ring(x, s, ring, i, mp, plane.params)
                    plane.publish(ring)  # ref swap; drain is off-thread
                jax.block_until_ready(x)
                return s

            run_ring()  # warmup (compile per ring depth)
            ts = []
            for _ in range(rounds):
                t0 = time.perf_counter()
                run_ring()
                ts.append(time.perf_counter() - t0)
            ring_ms = min(ts) * 1e3
            plane.flush()
            last = plane.last_state
            ok = last is not None and bool(
                np.allclose(np.asarray(last.values),
                            np.asarray(sync_state.values),
                            rtol=1e-5, atol=1e-7)
                and np.array_equal(np.asarray(last.calls),
                                   np.asarray(sync_state.calls))
            )
            slots_copied = plane.slots_copied
            plane.close()
            rows.append({
                "workload": f"readback he={he}", "case": "readback_ring",
                "hook_every": he, "ring_depth": depth, "steps": steps,
                "min_ms": round(ring_ms, 3),
                "per_step_us": round(1e3 * ring_ms / steps, 3),
                "sync_min_ms": round(sync_ms, 3),
                "readback_gain_pct": round(
                    100.0 * (sync_ms - ring_ms) / sync_ms, 1),
                "readback_allclose": ok,
                "snapshots_drained": len(drained),
                "snapshots_dropped": plane.dropped_snapshots,
                "ring_slots_copied": slots_copied,
            })
    return rows


def _readback_summary(rows: list[dict]) -> dict:
    """Aggregate sync-vs-ring verdicts for the trajectory JSON."""
    ring = [r for r in rows if r.get("case") == "readback_ring"]
    at1 = [r for r in ring if r.get("hook_every") == 1]
    return {
        "compared": len(ring),
        "ring_faster": sum(
            1 for r in ring if r["min_ms"] < r["sync_min_ms"]
        ),
        "ring_faster_at_hook1": bool(at1) and all(
            r["min_ms"] < r["sync_min_ms"] for r in at1
        ),
        "allclose_all": all(r.get("readback_allclose", False) for r in ring),
        "max_gain_pct": max(
            (r["readback_gain_pct"] for r in ring), default=None
        ),
    }


# ---------------------------------------------------------------------------
# adaptive-controller sweep: the closed loop's steady-state overhead
# ---------------------------------------------------------------------------

ADAPTIVE_EVENTS = ("ACT_RMS", "ACT_ZERO_FRAC", "NAN_COUNT", "INF_COUNT")


def _adaptive_spec(n_aux: int = 4) -> MonitorSpec:
    scopes = ("layer/attn", "layer/mlp") + tuple(
        f"aux{i}" for i in range(n_aux))
    return MonitorSpec.of([
        ScopeContext.exhaustive(s, [EventSpec(e, "x")
                                    for e in ADAPTIVE_EVENTS])
        for s in scopes
    ])


def run_adaptive_sweep(probe_size: int = 1 << 15, settle_steps: int = 48,
                       block: int = 32, rounds: int = 6,
                       nan_step: int = 2) -> list[dict]:
    """The closed adaptive loop (core/adaptive.py), three ways on one
    monitored workload with CONSTANT probed tensors:

      adaptive_off   MonitorParams.all_off + cadence 0 — the interception-
                     only floor the controller's sentinel rung approaches
      adaptive_ctl   AdaptiveController on; a NaN injected into ONE scope
                     at a known step during a deterministic settle phase
                     (escalate → wide → decay back to sentinel), then the
                     steady state is timed
      adaptive_wide  everything all-on at cadence 1, controller off — the
                     ceiling, and the counter-exactness reference

    Timed paired round-robin (blocks of back-to-back steps, median of
    per-round ratios) like the monitor sweep.  The row records the
    acceptance criteria: NaN localized to the right scope within K=5
    drained snapshots, steady-state ctl overhead vs off, and anomaly-free
    scopes' estimates allclose (+ calls equal) vs the always-wide run —
    constant probed tensors make the estimates invariant to WHICH calls
    each schedule sampled.
    """
    import statistics

    from repro.core.adaptive import AdaptiveConfig
    from repro.testing.faults import FaultInjector, TensorFault

    spec = _adaptive_spec()
    fault_scope = "layer/attn"
    k_drains = 5
    # the NaN must land while scopes still monitor: quiet scopes hibernate
    # at drain quiet_drains (sentinel scopes are blind to tensor anomalies
    # by design), so the fault fires early in the settle phase
    quiet_drains = 4
    assert nan_step + 1 < quiet_drains, (nan_step, quiet_drains)
    # a workload body heavy enough (~0.5ms on CPU) that per-dispatch host
    # jitter doesn't dominate the steady-state ratio being measured
    w_mix = jax.random.normal(jax.random.PRNGKey(3), (256, 256)) * 0.05

    def build(kind: str):
        runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
        ctl = None
        injector = None
        if kind == "ctl":
            ctl = runtime.attach_controller(AdaptiveConfig(
                quiet_drains=quiet_drains, cooldown_drains=2,
                warmup_drains=2,
                # budget parked through the settle phase: its flush-per-
                # step drains are synchronous by construction, so the
                # measured drain fraction there is an artifact; the budget
                # is enabled for the steady state below
                escalated_cadence=1, overhead_budget=1e9,
                # the wake path is not under test here, and the timed
                # blocks run much faster than the flushing settle steps —
                # an honest step-time detector would read that as outliers
                step_time_sigma=1e9,
            ))
            injector = FaultInjector(
                [TensorFault(fault_scope, "x", step=nan_step)])
        elif kind == "off":
            runtime.set_params(MonitorParams.all_off(spec))
            runtime.telemetry.set_cadence(0)
        mon = scalpel.Monitor(spec, telemetry=runtime.telemetry,
                              counter_axes=())
        const = jnp.full((probe_size,), 1.5)

        def work(x, step):
            for _ in range(2):
                x = jnp.tanh(x @ w_mix)
            for s in spec.scopes:
                v = const
                if injector is not None:
                    v = injector.corrupt(s, "x", step, v)
                with scalpel.function(s):
                    scalpel.probe(x=v)
            return x, step + 1

        fn = mon.jit(work)
        st = {"m": mon.init(), "x": jnp.ones((128, 256)),
              "s": jnp.zeros((), jnp.int32)}

        def step(flush: bool = False):
            st["m"] = mon.sync(st["m"], runtime=runtime)
            (st["x"], st["s"]), st["m"] = fn(st["m"], st["x"], st["s"])
            runtime.on_step(st["m"].counters, ring=st["m"].ring)
            if flush:
                runtime.flush()

        return {"step": step, "state": st, "mon": mon, "runtime": runtime,
                "ctl": ctl}

    cases = {kind: build(kind) for kind in ("off", "ctl", "wide")}
    # settle: deterministic controller ticks (flush per step) — the fault
    # fires, the ladder runs its full cycle, quiet scopes hibernate
    for kind, c in cases.items():
        for _ in range(settle_steps):
            c["step"](flush=True)
        jax.block_until_ready(c["state"]["x"])

    # steady-state warm-in, every case (equal step totals keep the calls
    # comparison exact): the controller's budget loop is enabled here, fed
    # by the REAL background-drain overhead — it ramps the cadence while
    # the settle-phase EWMA drains off, then halves back to the floor
    import dataclasses as _dc

    ctl_obj = cases["ctl"]["ctl"]
    ctl_obj.cfg = _dc.replace(ctl_obj.cfg, overhead_budget=0.05)
    for c in cases.values():
        for _ in range(4 * block):
            c["step"]()
        jax.block_until_ready(c["state"]["x"])

    def block_time(c) -> float:
        t0 = time.perf_counter()
        for _ in range(block):
            c["step"]()
        jax.block_until_ready(c["state"]["x"])
        return (time.perf_counter() - t0) / block

    order = list(cases)
    times = {kind: [] for kind in cases}
    for rnd in range(rounds):
        for kind in (order if rnd % 2 == 0 else reversed(order)):
            times[kind].append(block_time(cases[kind]))
    med = {kind: statistics.median(ts) for kind, ts in times.items()}
    ratio_ctl = statistics.median(
        [c / o for c, o in zip(times["ctl"], times["off"])])
    ratio_wide = statistics.median(
        [w / o for w, o in zip(times["wide"], times["off"])])

    ctl = cases["ctl"]["ctl"]
    wide_t = [t for t in ctl.transitions if t.to == "wide"]
    localized = bool(
        wide_t and all(t.scope == fault_scope for t in wide_t)
        and wide_t[0].step - nan_step <= k_drains
    )
    levels = ctl.levels
    steady_sentinel = all(lv == "sentinel" for lv in levels.values())

    # counter exactness: anomaly-free scopes, ctl run vs always-wide run
    est_ctl = cases["ctl"]["mon"].estimates(cases["ctl"]["state"]["m"])
    est_wide = cases["wide"]["mon"].estimates(cases["wide"]["state"]["m"])
    counters_ok = True
    for scope in spec.scopes:
        if scope == fault_scope:
            continue
        for slot_id, vw in est_wide[scope].items():
            vc = est_ctl[scope][slot_id]
            if np.isfinite(vw) != np.isfinite(vc) or (
                    np.isfinite(vw)
                    and not np.isclose(vc, vw, rtol=1e-6)):
                counters_ok = False
    calls_equal = bool(np.array_equal(
        np.asarray(cases["ctl"]["state"]["m"].calls),
        np.asarray(cases["wide"]["state"]["m"].calls),
    ))

    rows = [{
        "workload": f"adaptive n={probe_size}", "case": "adaptive_off",
        "per_step_us": round(med["off"] * 1e6, 2),
        "min_ms": round(min(times["off"]) * 1e3 * block, 3),
        "steps": settle_steps + rounds * block,
    }, {
        "workload": f"adaptive n={probe_size}", "case": "adaptive_ctl",
        "per_step_us": round(med["ctl"] * 1e6, 2),
        "min_ms": round(min(times["ctl"]) * 1e3 * block, 3),
        "steps": settle_steps + rounds * block,
        "ctl_over_off_ratio": round(ratio_ctl, 4),
        "ctl_within_5pct": bool(ratio_ctl <= 1.05),
        "nan_localized_k5": localized,
        "steady_levels_sentinel": steady_sentinel,
        "final_cadence": cases["ctl"]["runtime"].telemetry.cadence,
        "escalations": ctl.stats["escalations"],
        "deescalations": ctl.stats["deescalations"],
        "plan_swaps": ctl.stats["plan_swaps"],
        "overhead_frac": round(ctl.overhead_frac, 4),
        "counters_allclose_vs_wide": counters_ok,
        "calls_equal_vs_wide": calls_equal,
    }, {
        "workload": f"adaptive n={probe_size}", "case": "adaptive_wide",
        "per_step_us": round(med["wide"] * 1e6, 2),
        "min_ms": round(min(times["wide"]) * 1e3 * block, 3),
        "steps": settle_steps + rounds * block,
        "wide_over_off_ratio": round(ratio_wide, 4),
    }]
    for c in cases.values():
        c["runtime"].close()
    return rows


def _adaptive_summary(rows: list[dict]) -> dict:
    """Aggregate adaptive-loop verdicts for the trajectory JSON."""
    ctl = [r for r in rows if r.get("case") == "adaptive_ctl"]
    return {
        "compared": len(ctl),
        "nan_localized_k5": bool(ctl) and all(
            r.get("nan_localized_k5", False) for r in ctl),
        "ctl_within_5pct": bool(ctl) and all(
            r.get("ctl_within_5pct", False) for r in ctl),
        "counters_allclose": bool(ctl) and all(
            r.get("counters_allclose_vs_wide", False)
            and r.get("calls_equal_vs_wide", False) for r in ctl),
        "steady_levels_sentinel": bool(ctl) and all(
            r.get("steady_levels_sentinel", False) for r in ctl),
        "max_ctl_over_off_ratio": max(
            (r["ctl_over_off_ratio"] for r in ctl), default=None),
    }


# ---------------------------------------------------------------------------
# plan-dedup compile sweep: identical multiplexed sets share one branch body
# ---------------------------------------------------------------------------

def run_plan_dedup_sweep(m: int = 6, k: int = 8, probe_size: int = 4096,
                         rounds: int = 2) -> list[dict]:
    """Compile-time cost of the deduplicated branch table: a scope
    multiplexed over ``m`` IDENTICAL event sets traces ONE shared branch
    body (``ScopePlans.bodies``), while ``m`` DISTINCT sets trace ``m``.
    Duplicate (event, tensor) slots across sets are legal — event_sets only
    partition slot indices — so the dup spec is a real configuration (the
    same probe at every multiplex phase), not a degenerate one.

    Measured: jit trace (``lower``) + XLA compile wall time of an identical
    monitored step over each spec, fresh function objects per round (the
    jit cache keys on identity, so every round re-traces).
    """
    def spec_of(kind: str) -> MonitorSpec:
        if kind == "dup":
            sets = [[EventSpec("ACT_RMS", "x")] for _ in range(m)]
        else:
            sets = [[EventSpec(e, "x")] for e in PROBE_EVENTS[:m]]
        return MonitorSpec.of(
            [ScopeContext.multiplexed("hot", sets, period=1)])

    x0 = jnp.ones((probe_size,))
    rows = []
    for kind in ("dup", "distinct"):
        spec = spec_of(kind)
        plans = plan_lib.compile_scope_plans(spec.context("hot"),
                                             frozenset({"x"}))
        mon = scalpel.Monitor(spec, counter_axes=())
        lowers, compiles = [], []
        for _ in range(rounds):
            def work(x):
                for _ in range(k):
                    with scalpel.function("hot"):
                        x = x * 1.0001 + 0.1
                        scalpel.probe(x=x)
                return x

            t0 = time.perf_counter()
            lowered = jax.jit(mon.wrap(work)).lower(mon.init(), x0)
            t1 = time.perf_counter()
            lowered.compile()
            t2 = time.perf_counter()
            lowers.append(t1 - t0)
            compiles.append(t2 - t1)
        rows.append({
            "workload": f"plan_dedup m={m}", "case": f"plan_dedup_{kind}",
            "n_sets": plans.n_sets, "n_branches": plans.n_branches,
            "plans_deduped": plans.plans_deduped,
            "lower_ms": round(min(lowers) * 1e3, 1),
            "compile_ms": round(min(compiles) * 1e3, 1),
            "min_ms": round((min(lowers) + min(compiles)) * 1e3, 1),
        })
    dup, dis = rows
    dup["distinct_min_ms"] = dis["min_ms"]
    dup["dedup_gain_pct"] = round(
        100.0 * (dis["min_ms"] - dup["min_ms"]) / max(dis["min_ms"], 1e-9),
        1)
    return rows


# ---------------------------------------------------------------------------
# continuous-batching serve sweep: lane-packed megastep engine vs serial
# ---------------------------------------------------------------------------

def run_serve_throughput_sweep(streams=(1, 4, 16), prompt_len: int = 16,
                               max_new: int = 32, n_lanes: int = 16,
                               steps_per_commit: int = 8) -> list[dict]:
    """Continuous-batching serve engine (serve/driver.py) vs the serial
    per-request oracle, at increasing concurrent-stream counts.

    serve_serial      one static Engine, requests generated back to back —
                      one dispatch + host sample per token (the pre-lane
                      engine; per-request wall times summed, the counter
                      harvest between requests untimed).
    serve_continuous  ContinuousEngine: all streams submitted up front,
                      lane-packed K-token megasteps with on-device
                      sampling, tokens egressing through the telemetry
                      token ring a megastep behind.

    Exactness is asserted IN-SWEEP, not just reported: greedy tokens must
    be bitwise equal to the serial oracle per stream, and each request's
    per-lane counter attribution must match the serial engine's
    before/after counter delta for the same request.
    """
    from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

    cfg = model_config("xlstm_125m", smoke=True)
    arch = Arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(cache_len=prompt_len + max_new + 16,
                       max_new_tokens=max_new, temperature=0.0,
                       n_lanes=n_lanes, steps_per_commit=steps_per_commit)
    serial = Engine(arch, params, scfg)
    cont = ContinuousEngine(arch, params, scfg, spec=serial.spec)
    n_max = max(streams)
    prompts = [
        jax.random.randint(jax.random.PRNGKey(100 + i), (1, prompt_len),
                           0, cfg.vocab)
        for i in range(n_max)
    ]

    def counters_np(eng):
        c = eng.counters
        return (np.asarray(c.calls).copy(), np.asarray(c.values).copy(),
                np.asarray(c.samples).copy())

    # warmup both paths (compile prefill/decode/admit/megastep once; the
    # engines persist across sweep points, so nothing recompiles below)
    serial.generate({"tokens": prompts[0]})
    cont.submit(prompts[0])
    cont.run()

    rows = []
    for n in streams:
        want, serial_ctrs, serial_s = [], [], 0.0
        for p in prompts[:n]:
            before = counters_np(serial)
            t0 = time.perf_counter()
            out, _ = serial.generate({"tokens": p})
            serial_s += time.perf_counter() - t0
            after = counters_np(serial)  # untimed harvest between requests
            want.append(np.asarray(out)[0])
            serial_ctrs.append(tuple(a - b for a, b in zip(after, before)))
        toks = n * max_new
        mega0 = cont.stats["megasteps"]
        t0 = time.perf_counter()
        rids = [cont.submit(p) for p in prompts[:n]]
        res = cont.run()
        cont_s = time.perf_counter() - t0
        tokens_exact = all(
            np.array_equal(res[r].tokens, w) for r, w in zip(rids, want))
        counters_allclose = all(
            np.array_equal(np.asarray(res[r].counters.calls), sc[0])
            and np.allclose(np.asarray(res[r].counters.values), sc[1],
                            rtol=1e-4, atol=1e-6)
            and np.array_equal(np.asarray(res[r].counters.samples), sc[2])
            for r, sc in zip(rids, serial_ctrs)
        )
        workload = f"serve N={n}"
        rows.append({
            "workload": workload, "case": "serve_serial", "streams": n,
            "toks": toks, "min_ms": round(serial_s * 1e3, 1),
            "toks_per_s": round(toks / serial_s, 1),
            "n_lanes": 1, "steps_per_commit": 1,
        })
        rows.append({
            "workload": workload, "case": "serve_continuous", "streams": n,
            "toks": toks, "min_ms": round(cont_s * 1e3, 1),
            "toks_per_s": round(toks / cont_s, 1),
            "n_lanes": n_lanes, "steps_per_commit": steps_per_commit,
            "megasteps": cont.stats["megasteps"] - mega0,
            "serial_toks_per_s": round(toks / serial_s, 1),
            "speedup_x": round(serial_s / cont_s, 2),
            "tokens_exact": bool(tokens_exact),
            "counters_allclose": bool(counters_allclose),
            "dropped_tokens": cont.runtime.telemetry.dropped_tokens,
        })
    return rows


_SERVE_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import time
import jax
import numpy as np

from repro.configs import model_config
from repro.models.registry import Arch
from repro.serve.engine import ContinuousEngine, ServeConfig

assert len(jax.devices()) == 2
N_LANES = %d
MAX_NEW = %d
N_REQ = %d

arch = Arch(model_config("xlstm_125m", smoke=True))
params = arch.init(jax.random.PRNGKey(0))
prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i),
                                         (1, 16), 0, arch.cfg.vocab))
           for i in range(N_REQ)]


def run(shards, spec=None):
    cfg = ServeConfig(cache_len=16 + MAX_NEW + 16, max_new_tokens=MAX_NEW,
                      n_lanes=N_LANES, steps_per_commit=8,
                      lane_shards=shards)
    eng = ContinuousEngine(arch, params, cfg, spec=spec)
    # warmup: compile all three programs before the timed run
    eng.submit(prompts[0])
    eng.run()
    t0 = time.perf_counter()
    rids = [eng.submit(p) for p in prompts]
    res = eng.run()
    dt = time.perf_counter() - t0
    return eng, [res[r] for r in rids], dt

e1, res1, dt1 = run(1)
e2, res2, dt2 = run(2, spec=e1.spec)

tokens_exact = all(np.array_equal(a.tokens, b.tokens)
                   for a, b in zip(res1, res2))
counters_exact = all(
    np.array_equal(np.asarray(a.counters.calls),
                   np.asarray(b.counters.calls))
    and np.array_equal(np.asarray(a.counters.samples),
                       np.asarray(b.counters.samples))
    for a, b in zip(res1, res2))
values_allclose = all(
    np.allclose(np.asarray(a.counters.values),
                np.asarray(b.counters.values), rtol=1e-5, atol=1e-6)
    for a, b in zip(res1, res2))

toks = N_REQ * MAX_NEW
print(json.dumps({
    "toks": toks,
    "ms_1shard": round(dt1 * 1e3, 1),
    "ms_2shard": round(dt2 * 1e3, 1),
    "toks_per_s_1shard": round(toks / dt1, 1),
    "toks_per_s_2shard": round(toks / dt2, 1),
    "tokens_exact": bool(tokens_exact),
    "counters_exact": bool(counters_exact),
    "values_allclose": bool(values_allclose),
    "megastep_traces": e2.compile_stats()["megastep_traces"],
}))
"""


def run_serve_shard_sweep(n_lanes: int = 8, max_new: int = 32,
                          n_req: int = 12) -> list[dict]:
    """Lane-sharded serve engine on a forced 2-host-device mesh: the SAME
    total lane count split 1 vs 2 ways (``ServeConfig.lane_shards``), all
    other knobs equal.

    The contract is exactness, not host-CPU speed (two forced host devices
    share the same cores — tokens/s parity is all one can ask): greedy
    tokens bitwise equal across shardings, integer counters (calls,
    samples) exactly equal, values allclose under psum reassociation.

    Runs in a subprocess because the forced device count must be set
    before JAX initializes.
    """
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
    proc = subprocess.run(
        [sys.executable, "-c",
         _SERVE_SHARD_SCRIPT % (n_lanes, max_new, n_req)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    row = {"workload": f"serve shard N={n_req}", "case": "serve_shard",
           "streams": n_req, "n_lanes": n_lanes, "lane_shards": 2}
    if proc.returncode != 0:
        row.update(error=proc.stderr[-1000:], tokens_exact=False,
                   counters_exact=False)
        return [row]
    row.update(json.loads(proc.stdout.strip().splitlines()[-1]))
    row["min_ms"] = row.get("ms_2shard")
    return [row]


def run_prefill_bucket_sweep(n_req: int = 100, max_new: int = 4,
                             n_lanes: int = 8) -> list[dict]:
    """Prompt-length bucketing vs per-length re-tracing, end to end.

    ``n_req`` requests with prompt lengths cycling over every value in
    [3, 40] hit the admission path of two engines: one with pow2 buckets
    (compiles once per BUCKET), one with exact-length prefill (compiles
    once per DISTINCT LENGTH).  Both runs include compile time — that is
    the point: the bucketed engine's trace count is bounded by its bucket
    count, so it amortizes, while the baseline pays XLA per length.
    """
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg = model_config("xlstm_125m", smoke=True)
    arch = Arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    lengths = [3 + (i % 38) for i in range(n_req)]
    prompts = [
        jax.random.randint(jax.random.PRNGKey(200 + i), (1, s), 0,
                           cfg.vocab)
        for i, s in enumerate(lengths)
    ]
    scfg = dict(cache_len=64 + max_new + 16, max_new_tokens=max_new,
                n_lanes=n_lanes, steps_per_commit=4)

    def run(buckets):
        eng = ContinuousEngine(
            arch, params,
            ServeConfig(prefill_buckets=buckets, **scfg))
        t0 = time.perf_counter()
        rids = [eng.submit(p) for p in prompts]
        res = eng.run()
        dt = time.perf_counter() - t0
        return eng, res, rids, dt

    import warnings

    with warnings.catch_warnings():
        # the exact-length baseline intentionally trips the re-trace alarm
        warnings.simplefilter("ignore", RuntimeWarning)
        b_eng, b_res, b_rids, b_dt = run(None)
    eng, res, rids, dt = run("pow2")
    tokens_exact = all(
        np.array_equal(res[r].tokens, b_res[br].tokens)
        for r, br in zip(rids, b_rids))
    cs, bcs = eng.compile_stats(), b_eng.compile_stats()
    n_buckets = len(cs["buckets_used"])
    toks = n_req * max_new
    workload = f"prefill bucket N={n_req}"
    rows = [{
        "workload": workload, "case": "prefill_bucket_baseline",
        "streams": n_req, "toks": toks, "min_ms": round(b_dt * 1e3, 1),
        "toks_per_s": round(toks / b_dt, 1),
        "prefill_traces": bcs["prefill_traces"],
        "distinct_lengths": len(set(lengths)),
    }, {
        "workload": workload, "case": "prefill_bucket",
        "streams": n_req, "toks": toks, "min_ms": round(dt * 1e3, 1),
        "toks_per_s": round(toks / dt, 1),
        "prefill_traces": cs["prefill_traces"],
        "n_buckets": n_buckets,
        "buckets_used": cs["buckets_used"],
        "pad_waste_frac": round(cs["pad_waste_frac"], 4),
        "traces_bounded": bool(cs["prefill_traces"] <= n_buckets),
        "speedup_x": round(b_dt / dt, 2),
        "tokens_exact": bool(tokens_exact),
    }]
    return rows


def _serve_summary(rows: list[dict]) -> dict:
    """Aggregate continuous-vs-serial serve verdicts for the trajectory
    JSON (the acceptance bar: >=3x at the 16-stream point, exact tokens,
    allclose per-request counters)."""
    cont = [r for r in rows if r.get("case") == "serve_continuous"]
    wide = [r for r in cont if r.get("streams", 0) >= 16]
    shard = [r for r in rows if r.get("case") == "serve_shard"]
    bucket = [r for r in rows if r.get("case") == "prefill_bucket"]
    return {
        "compared": len(cont),
        "tokens_exact_all": bool(cont) and all(
            r.get("tokens_exact", False) for r in cont),
        "counters_allclose_all": bool(cont) and all(
            r.get("counters_allclose", False) for r in cont),
        "no_dropped_tokens": all(
            r.get("dropped_tokens", 0) == 0 for r in cont),
        "speedup_at_16": max(
            (r["speedup_x"] for r in wide), default=None),
        "speedup_3x_at_16": bool(wide) and all(
            r["speedup_x"] >= 3.0 for r in wide),
        # lane-sharding: 2-shard mesh == single device, exactly
        "shard_tokens_exact": bool(shard) and all(
            r.get("tokens_exact", False) for r in shard),
        "shard_counters_exact": bool(shard) and all(
            r.get("counters_exact", False) for r in shard),
        # bucketing: traces bounded by buckets, >=2x vs per-length retrace
        "bucket_traces_bounded": bool(bucket) and all(
            r.get("traces_bounded", False) for r in bucket),
        "bucket_tokens_exact": bool(bucket) and all(
            r.get("tokens_exact", False) for r in bucket),
        "bucket_speedup_x": max(
            (r["speedup_x"] for r in bucket), default=None),
        "bucket_speedup_2x": bool(bucket) and all(
            r["speedup_x"] >= 2.0 for r in bucket),
    }


# ---------------------------------------------------------------------------
# fleet telemetry sweep: encode cost per drain, aggregator merge throughput,
# wire compactness vs raw JSONL
# ---------------------------------------------------------------------------

def run_fleet_agg_sweep(host_counts=(4, 16, 64), frames_per_host: int = 200,
                        steps: int = 48) -> list[dict]:
    """The fleet tier (repro.telemetry), three measurements:

    fleet_encode  a live monitored workload with a ``FleetAgent`` sink on
                  the plane: the agent's frame-encode time as a fraction of
                  total drain time (the acceptance bar: < 5% — shipping a
                  drained delta must be nearly free next to draining it)
    fleet_merge   aggregator fan-in throughput over pre-encoded frames from
                  4/16/64 simulated hosts (decode + fingerprint check +
                  sum + reservoir per frame), with an f64 exactness check
                  of the merged sums against the encoding-side oracle
    fleet_wire    bytes per delta frame vs the same payload as raw JSONL
                  (what shipping per-host JsonlSink lines would cost)
    """
    import json as json_lib

    from repro.telemetry import wire
    from repro.telemetry.aggregator import Aggregator

    spec = _adaptive_spec()           # 6 scopes x 4 events = 24 lanes
    lay = plan_lib.spec_layout(spec)
    rng = np.random.default_rng(0)
    rows = []

    # -- encode cost per drain, on a live monitored workload ---------------
    agg = Aggregator(("127.0.0.1", 0), node_id="bench").serve()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    agent = runtime.attach_fleet_agent("bench-host", agg.address)
    mon = scalpel.Monitor(spec, telemetry=runtime.telemetry,
                          counter_axes=())
    const = jnp.full((1 << 14,), 1.5)

    def work(x):
        for s in spec.scopes:
            with scalpel.function(s):
                scalpel.probe(x=const)
        return x * 1.0001

    fn = mon.jit(work)
    ms, x = mon.init(), jnp.ones((256,))

    # shadow capture of every drained payload: the codec measurement
    # below re-encodes EXACTLY what the agent shipped
    payloads = []

    def _capture(snap):
        d = snap.delta
        payloads.append((np.asarray(d.calls).reshape(-1).copy(),
                         np.asarray(d.values, np.float32).reshape(-1)
                         .copy(),
                         np.asarray(d.samples).reshape(-1).copy(),
                         int(snap.step)))

    runtime.telemetry.add_sink(scalpel.CallbackSink(_capture))

    def run(n):
        nonlocal ms, x
        for _ in range(n):
            ms = mon.sync(ms, runtime=runtime)
            x, ms = fn(ms, x)
            runtime.on_step(ms.counters, ring=ms.ring)
            runtime.flush()

    # steady state only: the first few frames pay one-time costs (compile,
    # codec/struct caches) that a long-running host never sees again
    run(6)
    agent.flush(2.0)        # lazy sender-side encodes must have run
    drain0 = runtime.telemetry.drain_seconds
    st0 = agent.stats()
    run(steps)
    agent.flush(2.0)
    drain_s = runtime.telemetry.drain_seconds - drain0
    st = agent.stats()
    runtime.close()
    agg.close()
    emit_s = st["emit_seconds"] - st0["emit_seconds"]
    frames = st["frames_encoded"] - st0["frames_encoded"]

    # codec cost per frame: tight-loop re-encode of the captured drained
    # payloads (the encode runs on the link's SENDER thread in
    # production, off the drain path entirely — what rides the drain is
    # the emit row below)
    sample = payloads[-max(frames, 1):]
    reps = max(1, 400 // max(len(sample), 1))
    enc = wire.DeltaStreamEncoder("bench-host", spec.fingerprint)
    best = float("inf")
    for _ in range(5):      # min-of-5: preemption noise only ever adds
        t0 = time.perf_counter()
        for _ in range(reps):
            for i, (c, v, smp, stp) in enumerate(sample):
                enc.encode(c, v, smp, seq=i, step_lo=stp - 1, step_hi=stp)
        best = min(best, time.perf_counter() - t0)
    encode_per_frame = best / reps / max(len(sample), 1)
    drain_per_frame = drain_s / max(frames, 1)
    encode_s = encode_per_frame * frames
    rows.append({
        "workload": "fleet encode", "case": "fleet_encode",
        "frames": frames, "lanes": lay.total, "steps": steps,
        "drain_ms": round(drain_s * 1e3, 3),
        "drain_us_per_frame": round(1e6 * drain_per_frame, 2),
        "encode_us_per_frame": round(1e6 * encode_per_frame, 2),
        "encode_frac_pct": round(
            100 * encode_per_frame / max(drain_per_frame, 1e-12), 2),
        "encode_under_5pct": bool(
            encode_per_frame <= 0.05 * drain_per_frame),
        # what the agent sink actually costs the drain thread per frame
        # (normalize + lazy enqueue; the encode itself is deferred)
        "emit_us_per_frame": round(1e6 * emit_s / max(frames, 1), 2),
        "emit_frac_pct": round(100 * emit_s / max(drain_s, 1e-12), 2),
        # sender-thread codec CPU as accounted live by the agent
        "sender_encode_us_per_frame": round(
            1e6 * (st["encode_seconds"] - st0["encode_seconds"])
            / max(frames, 1), 2),
        "frames_dropped": st["dropped_frames"],
    })

    # -- merge throughput at 4/16/64 simulated hosts -----------------------
    for n_hosts in host_counts:
        packed = []
        want_calls = np.zeros((spec.n_scopes,), np.int64)
        want_values = np.zeros((lay.total,), np.float64)
        for h in range(n_hosts):
            for s in range(frames_per_host):
                calls = rng.integers(0, 100, spec.n_scopes)
                values = (rng.normal(size=lay.total) * 3.0).astype(
                    np.float32)
                samples = rng.integers(0, 50, lay.total)
                want_calls += calls
                want_values += values.astype(np.float64)
                packed.append(wire.encode_delta(
                    calls, values, samples, host_id=f"h{h}", seq=s,
                    fingerprint=spec.fingerprint,
                    step_lo=2 * s, step_hi=2 * (s + 1)))
        agg2 = Aggregator(("127.0.0.1", 0), node_id=f"merge{n_hosts}")
        t0 = time.perf_counter()
        for buf in packed:
            agg2.ingest(wire.decode_frame(buf))
        dt = time.perf_counter() - t0
        view = agg2.merged()
        merge_ok = bool(
            np.array_equal(view.calls, want_calls)
            and np.allclose(view.values, want_values, rtol=1e-9)
            and view.dropped == 0 and view.n_hosts == n_hosts)
        rows.append({
            "workload": f"fleet merge H={n_hosts}", "case": "fleet_merge",
            "hosts": n_hosts, "frames": len(packed), "lanes": lay.total,
            "merge_ms": round(dt * 1e3, 1),
            "frames_per_s": int(len(packed) / dt),
            "merge_us_per_frame": round(1e6 * dt / len(packed), 2),
            "merge_allclose": merge_ok,
            "p50_lane0": round(float(view.reservoirs[0].percentile(50.0)),
                               4) if view.reservoirs else None,
        })

    # -- wire compactness vs raw JSONL of the same payload -----------------
    wire_b, jsonl_b = [], []
    for s in range(32):
        calls = rng.integers(0, 100, spec.n_scopes)
        values = (rng.normal(size=lay.total) * 0.1).astype(np.float32)
        samples = rng.integers(0, 50, lay.total)
        frame = wire.encode_delta(
            calls, values, samples, host_id="h0", seq=s,
            fingerprint=spec.fingerprint, step_lo=2 * s,
            step_hi=2 * (s + 1))
        wire_b.append(len(frame) + 4)   # + the stream length prefix
        jsonl_b.append(len(json_lib.dumps({
            "host": "h0", "seq": s, "step": [2 * s, 2 * (s + 1)],
            "fingerprint": spec.fingerprint,
            "calls": calls.tolist(),
            "values": [float(v) for v in values],
            "samples": samples.tolist(),
        }) + "\n"))
    wb, jb = float(np.mean(wire_b)), float(np.mean(jsonl_b))
    rows.append({
        "workload": "fleet wire", "case": "fleet_wire",
        "lanes": lay.total, "frames": len(wire_b),
        "wire_bytes": round(wb, 1), "jsonl_bytes": round(jb, 1),
        "wire_over_jsonl": round(wb / jb, 3),
        "wire_smaller": bool(wb < jb),
    })
    return rows


def _fleet_summary(rows: list[dict]) -> dict:
    """Aggregate fleet-tier verdicts for the trajectory JSON."""
    enc = [r for r in rows if r.get("case") == "fleet_encode"]
    mrg = [r for r in rows if r.get("case") == "fleet_merge"]
    wr = [r for r in rows if r.get("case") == "fleet_wire"]
    return {
        "encode_frac_pct": max(
            (r["encode_frac_pct"] for r in enc), default=None),
        "encode_under_5pct": bool(enc) and all(
            r["encode_under_5pct"] for r in enc),
        "merge_allclose": bool(mrg) and all(
            r["merge_allclose"] for r in mrg),
        "min_frames_per_s": min(
            (r["frames_per_s"] for r in mrg), default=None),
        "max_hosts": max((r["hosts"] for r in mrg), default=None),
        "wire_over_jsonl": min(
            (r["wire_over_jsonl"] for r in wr), default=None),
        "wire_smaller_than_jsonl": bool(wr) and all(
            r["wire_smaller"] for r in wr),
    }


def main(fast: bool = False):
    iters = 3 if fast else 5
    # the Monitor-vs-manual comparison runs FIRST, on a fresh process: the
    # arch/callcount sweeps leave hundreds of live compiled executables
    # behind, and the resulting allocator/cache pressure skews the tiny
    # paired steps by ~10% (measured: in-driver-last ratios 1.03-1.13 vs
    # fresh-process 0.83-1.04 for identical code).
    rows = run_monitor_sweep(
        probe_sizes=(1 << 12, 1 << 14),   # 16 and 64 KiB probes
        k=12 if fast else 16,
        iters=5 if fast else 7,
        rounds=6 if fast else 8,
    )
    # still fresh-process territory: the megastep ratios compare ~100µs
    # steps and need the same clean allocator the wrap/manual pairs get
    rows += run_megastep_sweep(
        ks=(1, 4, 16),
        steps_per_round=32 if fast else 64,
        rounds=3 if fast else 4,
    )
    rows += run_monitor_psum_check()
    rows += run_train_boundary_check()
    rows += run_arch_workloads(iters=iters)
    # Fig. 3's axis spans tens to thousands of calls; full mode keeps the
    # 1024-call point (its 6-event unrolled graphs take minutes of XLA CPU
    # compile time, so fast/CI mode stops at 256).
    rows += run_callcount_sweep(
        counts=(64, 256) if fast else (64, 256, 1024),
        iters=5 if fast else 7,
    )
    rows += run_plan_sweep(
        probe_sizes=(1 << 14, 1 << 16) if fast else (1 << 14, 1 << 16,
                                                     1 << 18),
        k=16 if fast else 24,
        iters=5 if fast else 7,
        rounds=2 if fast else 3,
    )
    rows += run_readback_sweep(
        hook_everys=(1, 4) if fast else (1, 2, 8),
        depths=(4, 16),
        steps=24 if fast else 32,
        rounds=2 if fast else 3,
    )
    rows += run_adaptive_sweep(
        probe_size=1 << 14 if fast else 1 << 15,
        settle_steps=40 if fast else 48,
        block=24 if fast else 32,
        rounds=4 if fast else 6,
    )
    rows += run_plan_dedup_sweep(rounds=2 if fast else 3)
    rows += run_serve_throughput_sweep(
        streams=(1, 4, 16),
        max_new=16 if fast else 32,
    )
    rows += run_serve_shard_sweep(
        max_new=8 if fast else 32,
        n_req=8 if fast else 12,
    )
    rows += run_prefill_bucket_sweep(
        n_req=40 if fast else 100,
    )
    rows += run_fleet_agg_sweep(
        host_counts=(4, 16, 64),
        frames_per_host=80 if fast else 200,
        steps=32 if fast else 48,
    )
    save_json("overhead.json", rows, sub="bench")
    print(fmt_table(
        rows,
        ["workload", "case", "min_ms", "overhead_pct", "per_call_us",
         "bp_calls"],
        title="ScALPEL overhead: vanilla / selective / all / perfmon "
              "(paper Figs. 2-3)",
    ))
    print(fmt_table(
        [r for r in rows if str(r.get("case", "")).startswith("plan_")],
        ["workload", "case", "min_ms", "sweep_channels", "union_min_ms",
         "plan_gain_pct", "plan_allclose"],
        title="Sparse-active-set sweep: per-set MomentPlans vs union "
              "baseline (probe-plan compiler)",
    ))
    print(fmt_table(
        [r for r in rows if str(r.get("case", "")).startswith("monitor_")],
        ["workload", "case", "min_ms", "med_ms", "state_lanes",
         "manual_med_ms", "wrap_gain_pct", "wrap_allclose",
         "counters_equal"],
        title="Functional Monitor.wrap (one compact MonitorState pytree) "
              "vs manual collecting() baseline + 2-device psum check",
    ))
    print(fmt_table(
        [r for r in rows
         if r.get("case") in ("monitor_scan", "train_megastep_boundary")],
        ["workload", "case", "steps_per_commit", "per_step_us",
         "scan_over_k1_ratio", "scan_gain_pct", "scan_allclose",
         "params_reattached", "tstate_donated", "loss_finite"],
        title="Megastep driver: K steps per commit/dispatch (Monitor.scan) "
              "+ leaf-wise train jit boundary",
    ))
    print(fmt_table(
        [r for r in rows if str(r.get("case", "")).startswith("readback_")],
        ["workload", "case", "hook_every", "ring_depth", "min_ms",
         "per_step_us", "readback_gain_pct", "readback_allclose",
         "snapshots_drained", "ring_slots_copied"],
        title="Readback stall: sync CounterState device_get vs telemetry "
              "ring + incremental background drain",
    ))
    print(fmt_table(
        [r for r in rows if str(r.get("case", "")).startswith("adaptive_")],
        ["workload", "case", "per_step_us", "ctl_over_off_ratio",
         "nan_localized_k5", "steady_levels_sentinel", "final_cadence",
         "counters_allclose_vs_wide", "calls_equal_vs_wide"],
        title="Closed adaptive loop: controller steady state vs "
              "monitoring-off floor vs always-wide ceiling",
    ))
    print(fmt_table(
        [r for r in rows
         if str(r.get("case", "")).startswith("plan_dedup_")],
        ["workload", "case", "n_sets", "n_branches", "plans_deduped",
         "lower_ms", "compile_ms", "min_ms", "dedup_gain_pct"],
        title="Plan-dedup compile sweep: m identical multiplexed sets "
              "(1 shared branch body) vs m distinct sets (m bodies)",
    ))
    print(fmt_table(
        [r for r in rows if str(r.get("case", "")).startswith("serve_")],
        ["workload", "case", "streams", "toks", "min_ms", "toks_per_s",
         "megasteps", "speedup_x", "tokens_exact", "counters_allclose"],
        title="Continuous-batching serve: lane-packed K-token megasteps "
              "(on-device sampling, token-ring egress) vs serial engine",
    ))
    print(fmt_table(
        [r for r in rows if r.get("case") == "serve_shard"],
        ["workload", "case", "streams", "n_lanes", "lane_shards",
         "ms_1shard", "ms_2shard", "tokens_exact", "counters_exact",
         "values_allclose"],
        title="Lane-sharded serve (2 forced host devices): shard_map "
              "megasteps, 1 vs 2 shards over the same slab",
    ))
    print(fmt_table(
        [r for r in rows
         if str(r.get("case", "")).startswith("prefill_bucket")],
        ["workload", "case", "streams", "min_ms", "toks_per_s",
         "prefill_traces", "n_buckets", "pad_waste_frac", "speedup_x",
         "tokens_exact"],
        title="Prompt-length bucketing: pow2 pad buckets vs per-length "
              "prefill re-trace (compile time included — that's the point)",
    ))
    print(fmt_table(
        [r for r in rows if str(r.get("case", "")).startswith("fleet_")],
        ["workload", "case", "hosts", "frames", "lanes", "encode_frac_pct",
         "merge_us_per_frame", "frames_per_s", "merge_allclose",
         "wire_bytes", "jsonl_bytes", "wire_over_jsonl"],
        title="Fleet telemetry tier: frame encode cost per drain, "
              "aggregator merge throughput, wire bytes vs raw JSONL",
    ))
    # the paper's hierarchy, asserted softly (plan/readback rows carry no
    # perfmon case)
    by = {}
    for r in rows:
        if "min_ms" not in r:   # e.g. the subprocess psum-equality row
            continue
        by.setdefault(r["workload"], {})[r["case"]] = r["min_ms"]
    hier = {w: c for w, c in by.items() if "perfmon" in c}
    ok = sum(
        1 for w, c in hier.items()
        if c["perfmon"] >= max(c["selective"], c["all"]) * 0.9
    )
    plans = _plan_summary(rows)
    readback = _readback_summary(rows)
    monitor = _monitor_summary(rows)
    adaptive = _adaptive_summary(rows)
    serve = _serve_summary(rows)
    fleet = _fleet_summary(rows)
    print(f"\nhierarchy check: perfmon slowest in {ok}/{len(hier)} workloads")
    print(
        f"Monitor.wrap vs manual: not-slower in "
        f"{monitor['wrap_not_slower']}/{monitor['compared']} configs "
        f"(max gain {monitor['max_gain_pct']}%); counters allclose: "
        f"{monitor['allclose_all']}; 2-device psum == per-shard sum: "
        f"{monitor['psum_2dev_equal']}"
    )
    print(
        f"megastep: K=16 gain {monitor['megastep_k16_gain_pct']}% per step "
        f"(>=15%: {monitor['megastep_speedup_15pct']}); counters == "
        f"unrolled: {monitor['megastep_allclose']}; train boundary "
        f"params-not-output: {monitor['train_params_not_output']} "
        f"(tstate donated: {monitor['train_tstate_donated']})"
    )
    print(
        f"per-set plans vs union: faster in {plans['per_set_faster']}/"
        f"{plans['compared']} configs "
        f"(strict: {plans['strictly_faster']}, max gain "
        f"{plans['max_gain_pct']}%); counters allclose: "
        f"{plans['allclose_all']}"
    )
    print(
        f"readback: ring faster in {readback['ring_faster']}/"
        f"{readback['compared']} configs "
        f"(strict at hook_every=1: {readback['ring_faster_at_hook1']}); "
        f"drained counters allclose: {readback['allclose_all']}"
    )
    print(
        f"adaptive: NaN localized within K=5: "
        f"{adaptive['nan_localized_k5']}; steady-state ctl/off ratio "
        f"{adaptive['max_ctl_over_off_ratio']} "
        f"(within 5%: {adaptive['ctl_within_5pct']}); quiet-scope "
        f"counters allclose vs always-wide: {adaptive['counters_allclose']}"
    )
    print(
        f"serve: continuous speedup at 16 streams "
        f"{serve['speedup_at_16']}x (>=3x: {serve['speedup_3x_at_16']}); "
        f"greedy tokens == serial: {serve['tokens_exact_all']}; "
        f"per-request counters allclose: {serve['counters_allclose_all']}"
    )
    print(
        f"serve shard: 2-shard tokens == 1-shard: "
        f"{serve['shard_tokens_exact']}; integer counters exact: "
        f"{serve['shard_counters_exact']}"
    )
    print(
        f"prefill bucketing: traces bounded by buckets: "
        f"{serve['bucket_traces_bounded']}; speedup vs per-length retrace "
        f"{serve['bucket_speedup_x']}x (>=2x: {serve['bucket_speedup_2x']}); "
        f"tokens exact: {serve['bucket_tokens_exact']}"
    )
    print(
        f"fleet: encode {fleet['encode_frac_pct']}% of drain time "
        f"(<5%: {fleet['encode_under_5pct']}); merge exact at up to "
        f"{fleet['max_hosts']} hosts: {fleet['merge_allclose']} "
        f"(>= {fleet['min_frames_per_s']} frames/s); wire/jsonl bytes "
        f"{fleet['wire_over_jsonl']}"
    )
    return {
        "schema": "scalpel-overhead-v10",
        "backend": jax.default_backend(),
        "probe_events": list(PROBE_EVENTS),
        "plan_sets": [list(s) for s in PLAN_SETS],
        "plan_fingerprint": _plan_spec().fingerprint,
        "rows": rows,
        "per_mode_min_ms": by,
        "overhead_ratio": {
            w: {c: round(t / cs["vanilla"], 4) for c, t in cs.items()}
            for w, cs in by.items() if cs.get("vanilla")
        },
        "plans": plans,
        "monitor": monitor,
        "readback": readback,
        "adaptive": adaptive,
        "serve": serve,
        "fleet": fleet,
        "hierarchy_ok": ok,
    }


if __name__ == "__main__":
    import sys

    main(fast="--fast" in sys.argv)
