"""Single-cell perf analysis for the §Perf hillclimb loop.

    PYTHONPATH=src python -m benchmarks.perf_cell <arch> <shape> [--multi]
        [--tag NAME] [--breakdown]

Lowers + compiles one (arch x shape x mesh) cell, runs the while-aware HLO
analysis, prints the three roofline terms, and appends a JSON line to
experiments/perf/<arch>__<shape>.jsonl so before/after iterations are
recorded side by side.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.core.backends import hlo_graph  # noqa: E402
from repro.dist.partition import sharding_ctx  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyze(arch, shape, multi=False, tag="", show_breakdown=False,
            policy_overrides=None):
    t0 = time.time()
    fn, args, shardings, donate, mesh, meta = build_cell(
        arch, shape, multi, policy_overrides=policy_overrides
    )
    with mesh, sharding_ctx(mesh):
        lowered = jax.jit(
            fn, in_shardings=shardings, donate_argnums=donate
        ).lower(*args)
        compiled = lowered.compile()
    text = compiled.as_text()
    g = hlo_graph.analyze_text(text, default_group=meta["n_devices"])
    ma = compiled.memory_analysis()
    terms = {
        "compute_s": g["flops"] / PEAK_FLOPS,
        "memory_s": g["hbm_bytes"] / HBM_BW,
        "collective_s": g["collective_link_bytes"] / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    rec = {
        "tag": tag or "baseline",
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi else "16x16",
        **{k: round(v, 4) for k, v in terms.items()},
        "dominant": dom,
        "flops_per_chip": g["flops"],
        "hbm_per_chip": g["hbm_bytes"],
        "coll_per_chip": g["collective_link_bytes"],
        "coll_by_kind": g["collectives_by_kind"],
        "temp_gib": round(ma.temp_size_in_bytes / 2**30, 2),
        "wall_s": round(time.time() - t0, 1),
    }
    print(json.dumps(rec, indent=1))
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/{arch}__{shape}.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    if show_breakdown:
        bd = hlo_graph.breakdown(text, default_group=meta["n_devices"],
                                 top=12)
        print("\n=== top by HBM ===")
        for e in bd["by_hbm"]:
            print(f"{e['hbm'] / 1e9:9.1f} GB x{e['mult']:6.0f} "
                  f"{e['kind']:16s} {e['path'][:48]}")
            print("     ", e["line"][:140])
        print("\n=== top by FLOPs ===")
        for e in bd["by_flops"]:
            print(f"{e['flops'] / 1e12:9.2f} TF x{e['mult']:6.0f} "
                  f"{e['kind']:16s} {e['path'][:48]}")
            print("     ", e["line"][:140])
        print("\n=== collectives ===")
        for k, v in sorted(g["collectives_by_kind"].items()):
            print(f"  {k:20s} {v / 1e9:9.2f} GB/chip")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--breakdown", action="store_true")
    a = ap.parse_args()
    analyze(a.arch, a.shape, a.multi, a.tag, a.breakdown)


if __name__ == "__main__":
    main()
