"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time

import jax


def out_dir(sub: str = "") -> str:
    d = os.path.join("experiments", sub) if sub else "experiments"
    os.makedirs(d, exist_ok=True)
    return d


def save_json(name: str, obj, sub: str = "") -> str:
    path = os.path.join(out_dir(sub), name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


def bench(fn, *args, iters: int = 5, warmup: int = 2) -> dict:
    """Median wall-time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return {
        "median_s": ts[len(ts) // 2],
        "min_s": ts[0],
        "max_s": ts[-1],
        "iters": iters,
    }


def fmt_table(rows: list[dict], cols: list[str], title: str = "") -> str:
    if not rows:
        return (f"== {title} ==\n(no rows)" if title else "(no rows)")
    w = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append(" | ".join(c.ljust(w[c]) for c in cols))
    lines.append("-+-".join("-" * w[c] for c in cols))
    for r in rows:
        lines.append(" | ".join(str(r.get(c, "")).ljust(w[c]) for c in cols))
    return "\n".join(lines)
