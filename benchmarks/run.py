"""Benchmark driver: python -m benchmarks.run [--fast]

One benchmark per paper table/figure + the scale deliverables:
  overhead    — paper Figs. 2-3 (vanilla/perfmon/all/selective, per-set
                probe plans vs the union baseline, readback sweeps).  Its
                structured result is written to ``BENCH_overhead.json`` at
                the repo root so the monitoring overhead trajectory is
                machine-readable across PRs.
  case_study  — paper Table 2 + Fig. 4 (two GEMM schedules through counters)
  kernels     — Pallas kernel vs oracle timings + cost-model table
  roofline    — per (arch x shape) three-term roofline from the dry-run
"""
from __future__ import annotations

import json
import os
import sys
import traceback

# anchored to the repo root (parent of benchmarks/), not the CWD, so the
# trajectory file lands where CI and git expect it from any launch dir
OVERHEAD_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_overhead.json",
)


def _write_overhead_json(payload: dict) -> None:
    with open(OVERHEAD_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"\nwrote {OVERHEAD_JSON} "
          f"(plans: {payload.get('plans')}; "
          f"monitor: {payload.get('monitor')}; "
          f"readback: {payload.get('readback')}; "
          f"adaptive: {payload.get('adaptive')}; "
          f"serve: {payload.get('serve')})")


def main() -> int:
    fast = "--fast" in sys.argv
    failures = []
    print("=" * 72)
    print("ScALPEL-JAX benchmark suite")
    print("=" * 72)

    from . import case_study, kernels_bench, overhead, roofline

    def run_overhead():
        _write_overhead_json(overhead.main(fast=fast))

    for name, fn in [
        ("overhead (paper Figs. 2-3)", run_overhead),
        ("case study (paper Table 2 / Fig. 4)",
         lambda: case_study.main(fast=fast)),
        ("kernel microbench", lambda: kernels_bench.main(fast=fast)),
        ("roofline 16x16", lambda: roofline.main(mesh="16x16")),
        ("roofline 2x16x16", lambda: roofline.main(mesh="2x16x16")),
    ]:
        print("\n" + "=" * 72)
        print(f"--- {name}")
        print("=" * 72)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print("\n" + "=" * 72)
    if failures:
        print(f"FAILED benchmarks: {failures}")
        return 1
    print("all benchmarks completed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
