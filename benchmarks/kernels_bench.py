"""Kernel microbench: Pallas kernels vs pure-jnp oracles (interpret mode) +
the analytic cost-model table per schedule/block-shape (the numbers a real
TPU run would validate against).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, probe_reduce, ref

from .common import bench, fmt_table, save_json


def gemm_cost_table():
    rows = []
    for m, n, k in [(2048, 2048, 2048), (4096, 4096, 4096),
                    (8192, 8192, 1024)]:
        for sched, blocks in [
            ("cache_blocked", dict(bm=128, bn=128, bk=128)),
            ("cache_blocked", dict(bm=256, bn=256, bk=256)),
            ("cache_blocked", dict(bm=512, bn=512, bk=512)),
            ("panel_streaming", dict(bm=128, bn=256, bk=0)),
            ("panel_streaming", dict(bm=256, bn=512, bk=0)),
        ]:
            c = ops.matmul_cost(sched, m, n, k, **{k2: v for k2, v in
                                                   blocks.items() if v})
            rows.append({
                "mnk": f"{m}x{n}x{k}",
                "schedule": sched,
                "blocks": "/".join(str(v) for v in blocks.values() if v),
                "GFLOP": round(c["FLOPS"] / 1e9, 1),
                "HBM_MB": round(c["HBM_BYTES"] / 1e6, 1),
                "AI": round(c["arithmetic_intensity"], 1),
                "VMEM_KB": round(c["vmem_working_set_bytes"] / 1e3, 1),
                "stall_kcyc": round(c["EST_STALL_CYCLES"] / 1e3, 1),
            })
    return rows


def correctness_and_speed(fast: bool):
    rows = []
    # gemm
    m = n = k = 256
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    want = np.asarray(ref.matmul(a, b))
    for sched in ops.SCHEDULES:
        got = ops.matmul(a, b, sched, bm=128, bn=128, bk=128)
        err = float(np.max(np.abs(np.asarray(got) - want)))
        t = bench(lambda: ops.matmul(a, b, sched, bm=128, bn=128, bk=128),
                  iters=3 if fast else 5)
        rows.append({"kernel": f"gemm/{sched}", "shape": f"{m}^3",
                     "max_err": f"{err:.1e}",
                     "ms_interpret": round(t["min_s"] * 1e3, 2)})
    # flash attention
    bq, s, h, d = 1, 512, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (bq, s, h, d), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(3), (bq, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (bq, s, h, d), jnp.float32)
    want = np.asarray(ref.attention(q, kk, v, causal=True))
    got = ops.flash_attention(q, kk, v, causal=True, block_q=128,
                              block_kv=128)
    err = float(np.max(np.abs(np.asarray(got) - want)))
    t = bench(lambda: ops.flash_attention(q, kk, v, causal=True,
                                          block_q=128, block_kv=128),
              iters=3 if fast else 5)
    rows.append({"kernel": "flash_attn", "shape": f"s{s} h{h} d{d}",
                 "max_err": f"{err:.1e}",
                 "ms_interpret": round(t["min_s"] * 1e3, 2)})
    # ssm scan
    B, S, D = 2, 1024, 64
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (B, S, D))) * 0.3
    bb = jax.random.normal(jax.random.PRNGKey(6), (B, S, D))
    want = np.asarray(ref.ssm_scan(None, la, bb))
    got = ops.ssm_scan(la, bb, chunk=256, bd=64)
    err = float(np.max(np.abs(np.asarray(got) - want)))
    t = bench(lambda: ops.ssm_scan(la, bb, chunk=256, bd=64),
              iters=3 if fast else 5)
    rows.append({"kernel": "ssm_scan", "shape": f"B{B} S{S} D{D}",
                 "max_err": f"{err:.1e}",
                 "ms_interpret": round(t["min_s"] * 1e3, 2)})
    # fused probe-moment reduction (the monitoring hot path)
    x = jax.random.normal(jax.random.PRNGKey(7), (1 << 16,), jnp.float32)
    want = np.asarray(probe_reduce.moments_ref(x))
    got = np.asarray(ops.probe_moments(x, interpret=True))
    err = float(np.max(np.abs(got - want)))
    t = bench(lambda: ops.probe_moments(x, interpret=True),
              iters=3 if fast else 5)
    rows.append({"kernel": "probe_reduce", "shape": f"{x.size} elems",
                 "max_err": f"{err:.1e}",
                 "ms_interpret": round(t["min_s"] * 1e3, 2)})
    return rows


def main(fast: bool = False):
    rows = correctness_and_speed(fast)
    print(fmt_table(rows, ["kernel", "shape", "max_err", "ms_interpret"],
                    title="Pallas kernels vs oracle (interpret mode on CPU)"))
    cost = gemm_cost_table()
    print()
    print(fmt_table(
        cost,
        ["mnk", "schedule", "blocks", "GFLOP", "HBM_MB", "AI", "VMEM_KB",
         "stall_kcyc"],
        title="GEMM schedule cost model (TPU v5e constants)",
    ))
    save_json("kernels.json", {"correctness": rows, "cost": cost},
              sub="bench")
    return rows


if __name__ == "__main__":
    import sys

    main(fast="--fast" in sys.argv)
