"""CI gate: no new code under src/ or examples/ may call the deprecated
``collecting()`` region API directly — the functional ``scalpel.Monitor``
transformation (``mon.wrap`` / ``@scalpel.monitored``) is the supported
path.  AST-based (not a text grep) so docstrings and comments that *mention*
``collecting()`` don't trip the gate; only real call sites do.

Benchmarks and tests are exempt: ``collecting()`` survives there as the
measured manual baseline and the shim's own regression coverage.

    python tools/check_deprecated.py   # exits 1 on violations
"""
from __future__ import annotations

import ast
import pathlib

# the shim's own definition lives here (it *is* the deprecated path)
ALLOWLIST = {
    pathlib.PurePosixPath("src/repro/core/instrument.py"),
}
GATED_ROOTS = ("src", "examples")
DEPRECATED_CALLS = {"collecting"}


def violations(repo_root: pathlib.Path) -> list[str]:
    out = []
    for root in GATED_ROOTS:
        for path in sorted((repo_root / root).rglob("*.py")):
            rel = path.relative_to(repo_root)
            if pathlib.PurePosixPath(rel.as_posix()) in ALLOWLIST:
                continue
            tree = ast.parse(path.read_text(), filename=str(rel))
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                name = fn.attr if isinstance(fn, ast.Attribute) else \
                    getattr(fn, "id", "")
                if name in DEPRECATED_CALLS:
                    out.append(f"{rel}:{node.lineno}: call to deprecated "
                               f"{name}()")
    return out


def main() -> int:
    repo_root = pathlib.Path(__file__).resolve().parent.parent
    bad = violations(repo_root)
    if bad:
        print("deprecated API calls in gated trees (use scalpel.Monitor):")
        print("\n".join(f"  {b}" for b in bad))
        return 1
    print("deprecated-API gate clean over "
          + ", ".join(r + "/" for r in GATED_ROOTS))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
