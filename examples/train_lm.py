"""End-to-end training driver: data pipeline -> jitted train step ->
ScALPEL runtime -> checkpoint/restart, on a reduced xLSTM-125M.

    PYTHONPATH=src python examples/train_lm.py                # ~2 min CPU
    PYTHONPATH=src python examples/train_lm.py --steps 300    # longer run
    PYTHONPATH=src python examples/train_lm.py --arch qwen3_14b
    PYTHONPATH=src python examples/train_lm.py --full         # full 125M cfg

Kill it mid-run and start again with the same --ckpt-dir: it resumes from
the latest atomic checkpoint with the counter state (and therefore the
multiplex schedule) intact.
"""
import argparse

from repro.configs import model_config
from repro.data import DataConfig
from repro.models.registry import Arch
from repro.optim import OptConfig
from repro.train.loop import TrainLoopConfig, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm_125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/scalpel_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--jsonl", default="")
    args = ap.parse_args()

    cfg = model_config(args.arch, smoke=not args.full)
    if not args.full:
        # widen the smoke config toward ~15M params for a meaningful run
        cfg = cfg.replace(d_model=max(cfg.d_model, 256),
                          n_layers=max(cfg.n_layers, 4), vocab=8192)
    arch = Arch(cfg)
    print(f"arch {cfg.name}: {arch.n_params() / 1e6:.1f}M params")

    out = fit(
        arch,
        OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
        TrainLoopConfig(
            steps=args.steps, log_every=10,
            ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
            hook_every=10, jsonl_path=args.jsonl or None,
        ),
    )
    print(f"\nfinal loss {out['final_loss']:.4f} "
          f"(first {out['losses'][0]:.4f})")
    st = out["step_stats"]
    print(f"step time: mean {st.mean_s * 1e3:.1f}ms p95 {st.p95_s * 1e3:.1f}ms")
    if out["events"]:
        print("events:", *out["events"], sep="\n  ")
    print()
    print(out["report"])


if __name__ == "__main__":
    main()
