"""Quickstart: monitor a model with ScALPEL-JAX in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build a model (any callable using scalpel.function/probe scopes).
2. Discover the compile-time scope set (the '-finstrument-functions' pass).
3. Inspect the compiled probe plans (what each event set will actually
   sweep — core/plan.py).
4. Wrap the step with the functional Monitor: ONE MonitorState pytree
   threads compact counters + step stamp through jit — no hand-threaded
   ``state = state.add(col.delta)`` anywhere.
5. Pick a runtime subset; run; read the per-scope report.
"""
import jax

from repro import core as scalpel
from repro.configs import model_config
from repro.models.registry import Arch


def main():
    # -- 1. the application: a small LM forward+loss ----------------------
    arch = Arch(model_config("qwen3_14b", smoke=True))
    params = arch.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                     arch.cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0,
                                      arch.cfg.vocab),
    }

    # -- 2. compile-time set: discover every scope the program touches ----
    seen = scalpel.discover(arch.loss_fn, params, batch)
    spec = scalpel.spec_from_discovery(
        seen, tensor_events=("ACT_RMS", "ACT_MAX_ABS")
    )
    print("compile-time scope set:")
    print(spec.describe())

    # -- 2b. the compiled probe plans: per (scope, event set), exactly the
    # raw channels that set sweeps per probed tensor (identical sweeps
    # share one switch branch body — see 'plans_deduped').  The fingerprint
    # is the attestation that the runtime reconfig below re-selects among
    # these plans instead of re-tracing.
    print("\ncompiled probe plans:")
    print(scalpel.describe_plans(spec))
    print(f"plan fingerprint: {spec.fingerprint[:12]}")

    # -- 3. the functional Monitor: wrap the step once, thread ONE pytree -
    # monitor only attention scopes to start (the runtime subset)
    attn_scopes = [s for s in spec.scopes if s.endswith("attn")]
    mon = scalpel.Monitor(
        spec, scalpel.MonitorParams.selective(spec, attn_scopes)
    )
    step = jax.jit(mon.wrap(lambda b: arch.loss_fn(params, b)))
    mstate = mon.init()

    for _ in range(3):
        loss, mstate = step(mstate, batch)

    # -- 4. report (paper: stdout on termination) — reports read the
    # compact counter lanes directly; no padded block is ever built
    print(f"\nloss={float(loss):.4f}")
    print(mon.report(mstate))

    # flipping the monitored subset is a data swap riding IN the state
    # pytree — NO recompile; the compiled plans (and their fingerprint)
    # are untouched:
    mstate = mon.sync(mstate, params=scalpel.MonitorParams.selective(
        spec, [s for s in spec.scopes if s.endswith("mlp")]
    ))
    loss, mstate = step(mstate, batch)  # same compiled step
    print("\nafter runtime reconfig to mlp scopes (no re-trace, plan "
          f"fingerprint still {spec.fingerprint[:12]}):")
    print(mon.report(mstate))


if __name__ == "__main__":
    main()
