"""Paper §4.2 case study, runnable: compare two GEMM implementations
through ScALPEL counters with call-count multiplexing.

    PYTHONPATH=src python examples/case_study_gemm.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks import case_study  # noqa: E402


if __name__ == "__main__":
    case_study.main(fast=True)
