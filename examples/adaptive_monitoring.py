"""Adaptive monitoring, closed-loop (paper §3.3 + C5): the self-retuning
``AdaptiveController`` localizes an injected NaN to the right scope,
widens that scope's event set, raises the snapshot rate, then decays
everything back down the degradation ladder once the anomaly passes.

What replaced the old SIGUSR1 demo: the reconfiguration POLICY is now in
the library (core/adaptive.py) instead of a hand-rolled signal handler.
The mechanism is unchanged — every controller action is a MonitorParams /
cadence reference swap picked up by ``mon.sync`` between steps, never a
re-trace (``runtime.plan_fingerprint`` is printed before and after to
attest it).  The controller runs as a ``CallbackSink`` over drained
telemetry snapshots and never dispatches device work.

The degradation ladder, per scope:

    wide        scope+slot masks all-on, multiplex period 1 (escalated)
    configured  whatever params the controller was installed with
    sentinel    scope_mask 0 — presence counters only (the probe path's
                lax.cond skips every event sweep; interception still
                counts calls for free)

The fault harness (repro.testing.faults) injects a deterministic NaN into
ONE scope's probed tensor at a known step; the smoke assertion is the
acceptance criterion — the right scope escalates within K=5 drained
snapshots, and nothing else does.

    PYTHONPATH=src python examples/adaptive_monitoring.py
"""
import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.core.adaptive import AdaptiveConfig
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.testing.faults import FaultInjector, TensorFault

EVENTS = ("ACT_RMS", "ACT_ZERO_FRAC", "NAN_COUNT", "INF_COUNT")
SCOPES = ("layer/attn", "layer/mlp", "head")
FAULT_SCOPE = "layer/attn"
# The NaN must land while its scope still monitors (a scope that already
# decayed to the sentinel rung is blind to tensor anomalies by design —
# only the global step-time detector wakes sentinels): with quiet_steps=12
# the scopes hibernate around step 12, so inject at step 10.  Patience is
# denominated in STEPS (snapshot stamp spans), not drained snapshots, so
# the timing here is independent of the ring cadence.
NAN_STEP = 10        # carried step at which the NaN is spliced in
STEPS = 56
CADENCE = 2          # baseline ring-append cadence (steps per snapshot)
K_DRAINS = 5         # acceptance bound: escalate within K drained snapshots


def build_spec() -> MonitorSpec:
    return MonitorSpec.of([
        ScopeContext.exhaustive(s, [EventSpec(e, "x") for e in EVENTS])
        for s in SCOPES
    ])


def main():
    spec = build_spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=CADENCE,
                                     graceful_shutdown=True)
    ctl = runtime.attach_controller(AdaptiveConfig(
        quiet_steps=12, cooldown_steps=4, warmup_drains=2,
        escalated_cadence=1,
        # this demo drains synchronously inside a trivial workload, so the
        # measured drain overhead IS most of the wall time — park the
        # budget loop (run_adaptive_sweep exercises it on a real workload)
        overhead_budget=1.0,
    ))
    injector = FaultInjector([
        TensorFault(FAULT_SCOPE, "x", step=NAN_STEP, kind="nan"),
    ])
    fp_before = runtime.plan_fingerprint

    mon = scalpel.Monitor(spec, telemetry=runtime.telemetry,
                          counter_axes=())
    key = jax.random.PRNGKey(0)
    w1, w2, w3 = (jax.random.normal(k, (64, 64)) * 0.2
                  for k in jax.random.split(key, 3))

    def workload(x, step):
        h = jnp.tanh(x @ w1)
        with scalpel.function("layer/attn"):
            # the fault corrupts only the PROBED copy: the anomaly shows up
            # in exactly one scope's counters and nowhere downstream
            scalpel.probe(x=injector.corrupt(FAULT_SCOPE, "x", step, h))
        m = jnp.tanh(h @ w2)
        with scalpel.function("layer/mlp"):
            scalpel.probe(x=m)
        y = m @ w3
        with scalpel.function("head"):
            scalpel.probe(x=y)
        return x, step + 1

    step_fn = mon.jit(workload)
    mstate = mon.init()
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    step = jnp.zeros((), jnp.int32)
    for _ in range(STEPS):
        # pick up the controller's latest mask/period/cadence decisions —
        # reference swaps into the carried pytree, never a re-trace
        mstate = mon.sync(mstate, runtime=runtime)
        (x, step), mstate = step_fn(mstate, x, step)
        runtime.on_step(mstate.counters, ring=mstate.ring)
        # deterministic demo: drain synchronously so controller decisions
        # land at fixed steps (production leaves this to the drain thread)
        runtime.flush()

    print(f"plan fingerprint: {fp_before[:12]} -> "
          f"{runtime.plan_fingerprint[:12]} (unchanged: no re-trace)")
    print(ctl.describe())
    print(mon.report(mstate.counters, title="ScALPEL adaptive demo"))

    # ---- smoke assertions (CI adaptive-smoke job greps for PASS) --------
    assert runtime.plan_fingerprint == fp_before
    wide = [t for t in ctl.transitions if t.to == "wide"]
    assert wide, f"no escalation happened: {ctl.events}"
    assert all(t.scope == FAULT_SCOPE for t in wide), \
        f"escalated the wrong scope(s): {wide}"
    # localized within K drained snapshots of the faulty step's snapshot
    t = wide[0]
    assert t.step - NAN_STEP <= CADENCE * K_DRAINS, (t, NAN_STEP)
    assert "NAN_COUNT" in t.reason
    # the ladder decayed once quiet: the faulty scope stepped back down and
    # quiet scopes reached the sentinel rung (presence counters only)
    assert ctl.stats["deescalations"] > 0, ctl.events
    assert ctl.levels[FAULT_SCOPE] != "wide", ctl.levels
    assert "sentinel" in ctl.levels.values(), ctl.levels
    # escalation raised the snapshot rate, the decay restored it
    assert runtime.telemetry.cadence == CADENCE, runtime.telemetry.cadence
    print("ADAPTIVE-SMOKE: PASS")
    runtime.shutdown()


if __name__ == "__main__":
    main()
