"""Adaptive monitoring (paper §3.3 + C5): config-file driven contexts,
SIGUSR1 hot-reload mid-training, call-count multiplexing, and adaptive
hooks that react to live counters.

Hooks run on *drained telemetry snapshots*: the jitted train step appends
counters to a device-side ring at the runtime cadence, a background thread
drains and delta-decodes them (incrementally — only slots newer than the
drain cursor are copied), and the hook fires on the drain thread — the
step loop never stalls for monitoring.  The hook below also closes the
adaptive loop on the telemetry plane itself, retuning the ring cadence
(``runtime.telemetry.set_cadence`` — a dynamic-input swap, no re-trace)
once the monitored statistics settle.

Every reconfiguration here — the SIGUSR1 config swap to multiplexed
phase-2 contexts included — re-selects among the probe plans compiled per
(scope, event set) at trace time (core/plan.py): the phase-2 attn scope
sweeps only what its ACTIVE set needs on each call, and
``runtime.plan_fingerprint`` is printed before and after the reload to
attest that no re-trace happened.

Under the hood the train loop threads ONE functional ``MonitorState``
pytree (scalpel.Monitor): compact counters, the telemetry ring, the step
stamp, and the reloaded MonitorParams all ride the same carried state —
the reconfigurations land as reference swaps into that pytree.

    PYTHONPATH=src python examples/adaptive_monitoring.py
"""
import os
import signal

from repro import core as scalpel
from repro.configs import model_config
from repro.data import DataConfig
from repro.models.registry import Arch
from repro.optim import OptConfig
from repro.train.loop import TrainLoopConfig, fit

CONFIG_PHASE1 = """\
BINARY=train_lm                      // paper Table-1 grammar
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=grads                      // monitor only gradient stats first
NO_EVENTS=0                          // bare block: all compiled slots
[/FUNCTION]
"""

# phase 2: switch to per-layer activation monitoring, multiplexed over two
# event sets every 5 calls (the paper's case-study mechanism)
CONFIG_PHASE2 = """\
BINARY=train_lm
NO_FUNCTIONS=2
[FUNCTION]
FUNC_NAME=layer/attn
MULTIPLEX_PERIOD=5
NO_EVENTS=2
[EVENT]
ID=ACT_RMS:out
SET=0
NO_SUBEVENTS=0
[/EVENT]
[EVENT]
ID=ACT_RMS:q
SET=1
NO_SUBEVENTS=0
[/EVENT]
[/FUNCTION]
[FUNCTION]
FUNC_NAME=layer/mlp
NO_EVENTS=1
[EVENT]
ID=ACT_RMS:out
NO_SUBEVENTS=0
[/EVENT]
[/FUNCTION]
"""


def main():
    arch = Arch(model_config("mistral_nemo_12b", smoke=True))
    cfg_path = "/tmp/scalpel_adaptive.cfg"
    with open(cfg_path, "w") as f:
        f.write(CONFIG_PHASE1)

    phase_log = []
    drained_log = []

    def hook(runtime, reports):
        """Adaptive logic on drained snapshots (paper C5: runtime decisions).

        Runs on the telemetry drain thread with the ring snapshot's reports —
        the train step that produced these counters has long since returned.
        """
        est = {r.scope: {s.slot_id: s.estimate for s in r.slots}
               for r in reports}
        g = est.get("grads", {}).get("MEAN:gnorm")
        if g is not None:
            phase_log.append(f"drained-hook: grad-norm estimate {g:.3f} "
                             f"(reloads so far: {runtime.reload_count}, "
                             f"cadence: {runtime.telemetry.cadence}, "
                             f"plans: {runtime.plan_fingerprint[:12]})")
        # after the first hook, hot-swap the config via SIGUSR1 — exactly
        # the paper's 'new configuration file may be loaded at any time by
        # sending a signal to the application'
        if runtime.reload_count == 0:
            with open(cfg_path, "w") as f:
                f.write(CONFIG_PHASE2)
            os.kill(os.getpid(), signal.SIGUSR1)
        elif len(drained_log) >= 2 and runtime.telemetry.cadence < 8:
            # adaptive telemetry: once phase-2 statistics are flowing,
            # monitoring has told us what we need — back the ring-append
            # cadence off (a dynamic-input swap: the step never re-traces)
            runtime.telemetry.set_cadence(8)
            phase_log.append("adaptive: relaxed telemetry cadence to 8")

    def on_snapshot(snap):
        """Raw-sink view of the same plane: per-snapshot delta decoding."""
        drained_log.append(
            f"snapshot seq={snap.seq} step={snap.step} "
            f"delta-calls={int(snap.delta.calls.sum())}"
        )

    scalpel.ScalpelRuntime._example_sink = on_snapshot
    out = fit(
        arch,
        OptConfig(lr=1e-3, warmup_steps=5),
        DataConfig(vocab=arch.cfg.vocab, seq_len=64, global_batch=4),
        TrainLoopConfig(steps=16, log_every=8, ckpt_every=0, hook_every=4,
                        monitor_config_path=cfg_path),
        on_report=hook,
    )
    rt = out["runtime"]
    # install_signal is off by default in fit(); emulate the signal path:
    # (the runtime object exposes reload() which the handler calls)
    print("\n".join(phase_log))
    print("\n".join(drained_log))
    print(f"\nconfig reloads during run: {rt.reload_count}")
    print(f"plan fingerprint after reloads: {rt.plan_fingerprint[:12]} "
          "(constant — reconfig re-selects compiled per-set plans, "
          "never re-traces)")
    print("per-(scope, event set) probe plans in effect:")
    print(rt.describe_plans())
    print(f"final telemetry cadence: {rt.telemetry.cadence} "
          f"(ring writes drained: {len(drained_log)}, "
          f"dropped: {rt.telemetry.dropped_snapshots}, "
          f"ring slots copied: {rt.telemetry.slots_copied})")
    print(rt.report("final report (phase-2 contexts, multiplexed)"))
    est = rt.estimates()
    attn = next((s for s in est if s.endswith("attn")), None)
    if attn:
        print(f"\nattn multiplexed estimates: {est[attn]}")


if __name__ == "__main__":
    # fit() builds its own runtime; install the SIGUSR1 handler globally
    # (and this example's raw snapshot sink) by monkeypatching
    # ScalpelRuntime defaults for this example
    orig = scalpel.ScalpelRuntime.__init__

    def patched(self, *a, **kw):
        kw["install_signal"] = True
        orig(self, *a, **kw)
        sink_fn = getattr(scalpel.ScalpelRuntime, "_example_sink", None)
        if sink_fn is not None:
            self.telemetry.add_sink(scalpel.CallbackSink(sink_fn))

    scalpel.ScalpelRuntime.__init__ = patched
    try:
        main()
    finally:
        scalpel.ScalpelRuntime.__init__ = orig
