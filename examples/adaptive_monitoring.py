"""Adaptive monitoring (paper §3.3 + C5): config-file driven contexts,
SIGUSR1 hot-reload mid-training, call-count multiplexing, and an adaptive
hook that reacts to live counters.

    PYTHONPATH=src python examples/adaptive_monitoring.py
"""
import os
import signal

import jax

from repro import core as scalpel
from repro.configs import model_config
from repro.data import DataConfig
from repro.models.registry import Arch
from repro.optim import OptConfig
from repro.train.loop import TrainLoopConfig, fit

CONFIG_PHASE1 = """\
BINARY=train_lm                      // paper Table-1 grammar
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=grads                      // monitor only gradient stats first
NO_EVENTS=0                          // bare block: all compiled slots
[/FUNCTION]
"""

# phase 2: switch to per-layer activation monitoring, multiplexed over two
# event sets every 5 calls (the paper's case-study mechanism)
CONFIG_PHASE2 = """\
BINARY=train_lm
NO_FUNCTIONS=2
[FUNCTION]
FUNC_NAME=layer/attn
MULTIPLEX_PERIOD=5
NO_EVENTS=2
[EVENT]
ID=ACT_RMS:out
SET=0
NO_SUBEVENTS=0
[/EVENT]
[EVENT]
ID=ACT_RMS:q
SET=1
NO_SUBEVENTS=0
[/EVENT]
[/FUNCTION]
[FUNCTION]
FUNC_NAME=layer/mlp
NO_EVENTS=1
[EVENT]
ID=ACT_RMS:out
NO_SUBEVENTS=0
[/EVENT]
[/FUNCTION]
"""


def main():
    arch = Arch(model_config("mistral_nemo_12b", smoke=True))
    cfg_path = "/tmp/scalpel_adaptive.cfg"
    with open(cfg_path, "w") as f:
        f.write(CONFIG_PHASE1)

    phase_log = []

    def hook(runtime, reports):
        """Adaptive logic on live counters (paper C5: runtime decisions)."""
        est = {r.scope: {s.slot_id: s.estimate for s in r.slots}
               for r in reports}
        g = est.get("grads", {}).get("MEAN:gnorm")
        if g is not None:
            phase_log.append(f"step-hook: grad-norm estimate {g:.3f} "
                             f"(reloads so far: {runtime.reload_count})")
        # after the first hook, hot-swap the config via SIGUSR1 — exactly
        # the paper's 'new configuration file may be loaded at any time by
        # sending a signal to the application'
        if runtime.reload_count == 0:
            with open(cfg_path, "w") as f:
                f.write(CONFIG_PHASE2)
            os.kill(os.getpid(), signal.SIGUSR1)

    out = fit(
        arch,
        OptConfig(lr=1e-3, warmup_steps=5),
        DataConfig(vocab=arch.cfg.vocab, seq_len=64, global_batch=4),
        TrainLoopConfig(steps=16, log_every=8, ckpt_every=0, hook_every=4,
                        monitor_config_path=cfg_path),
        on_report=hook,
    )
    rt = out["runtime"]
    # install_signal is off by default in fit(); emulate the signal path:
    # (the runtime object exposes reload() which the handler calls)
    print("\n".join(phase_log))
    print(f"\nconfig reloads during run: {rt.reload_count}")
    print(rt.report("final report (phase-2 contexts, multiplexed)"))
    est = rt.estimates()
    attn = next((s for s in est if s.endswith("attn")), None)
    if attn:
        print(f"\nattn multiplexed estimates: {est[attn]}")


if __name__ == "__main__":
    # fit() builds its own runtime; install the SIGUSR1 handler globally by
    # monkeypatching ScalpelRuntime defaults for this example
    orig = scalpel.ScalpelRuntime.__init__

    def patched(self, *a, **kw):
        kw["install_signal"] = True
        orig(self, *a, **kw)

    scalpel.ScalpelRuntime.__init__ = patched
    try:
        main()
    finally:
        scalpel.ScalpelRuntime.__init__ = orig
