"""Fleet telemetry, end to end (ROADMAP item 2): five simulated hosts ship
compact binary frames over localhost sockets into one ``Aggregator``; a
``FleetHead`` on the tree root reports fleet percentiles, exact fleet
counter sums, and straggler flags, then rebroadcasts a tripwire hint back
DOWN the tree so a lingering host's ``AdaptiveController`` escalates.

The moving parts, in ship order:

    simhost x5  --frames-->  Aggregator (root)  --merged-->  FleetHead
        ^                         |
        '------- KIND_HINT -------'          (fleet-wide escalation)

* every host runs ``repro.telemetry.simhost`` — the same monitored
  workload behind ``tests/test_fleet_agg.py`` — so each prints a
  ``FLEET-ORACLE:`` JSON line with its agent's own shipped-frame sums;
* host ``h2`` carries a ``StragglerDelay`` (~15x slower steps): the head
  must flag it, and ONLY it, from EWMA+MAD step rates — the three healthy
  hosts agree tightly, so the MAD collapses and the relative floor sets
  the outlier threshold;
* host ``h0`` gets a NaN spliced into one probed tensor and lingers with
  an attached controller: the head's ``auto_hints`` sees the fleet-level
  NAN_COUNT tick and pushes a hint down the wire — ``h0``'s controller
  escalates without ever seeing its neighbours' telemetry.

The smoke assertions are the acceptance criteria: fleet sums equal the
sum of per-host oracles exactly (int lanes) / to f64 tolerance (float
lanes), fleet percentiles match a merged-stream oracle, the straggler is
flagged, and the downlink hint lands.

    PYTHONPATH=src python examples/fleet_monitor.py
"""
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import plan as plan_lib
from repro.telemetry.aggregator import Aggregator
from repro.telemetry.head import FleetHead
from repro.telemetry.simhost import build_spec

N_HOSTS = 5
STEPS = 20
CADENCE = 2
STRAGGLER = "h2"          # gets the per-step StragglerDelay
STRAGGLE_S = 0.06         # ~15x the healthy 4ms pace
NAN_HOST = "h0"           # gets the TensorFault + lingering controller
NAN_STEP = 6
LINGER_S = 8.0            # h0 waits this long for the downlink hint


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return env


def main():
    spec = build_spec()
    agg = Aggregator(("127.0.0.1", 0), node_id="root", reservoir_k=256,
                     seed=7).serve()
    _, port = agg.address
    report_path = os.path.join(tempfile.mkdtemp(prefix="fleet_"),
                               "fleet.jsonl")
    head = FleetHead(agg, spec=spec, jsonl_path=report_path)
    print(f"aggregator root listening on 127.0.0.1:{port}")

    procs = []
    for i in range(N_HOSTS):
        hid = f"h{i}"
        cmd = [sys.executable, "-m", "repro.telemetry.simhost",
               "--host-id", hid, "--port", str(port),
               "--steps", str(STEPS), "--cadence", str(CADENCE),
               "--seed", str(i), "--pace-s", "0.004"]
        if hid == STRAGGLER:
            cmd += ["--straggle-s", str(STRAGGLE_S)]
        if hid == NAN_HOST:
            cmd += ["--nan-step", str(NAN_STEP), "--adaptive",
                    "--linger-s", str(LINGER_S)]
        procs.append(subprocess.Popen(cmd, env=_env(),
                                      stdout=subprocess.PIPE,
                                      stderr=subprocess.PIPE, text=True))
    print(f"spawned {N_HOSTS} hosts: {STRAGGLER} straggles "
          f"({STRAGGLE_S * 1000:.0f}ms/step), {NAN_HOST} hits a NaN at "
          f"step {NAN_STEP} and lingers for the hint")

    # while the hosts run, the head scans tripwire lanes: the first
    # fleet-level NAN_COUNT tick becomes a KIND_HINT pushed down every
    # connected agent link (h0's controller is waiting for exactly that)
    hints = []
    while any(p.poll() is None for p in procs):
        hints.extend(head.auto_hints())
        time.sleep(0.05)

    oracles = {}
    for p in procs:
        out, err = p.communicate(timeout=60)
        assert p.returncode == 0, err[-3000:]
        line = [ln for ln in out.splitlines()
                if ln.startswith("FLEET-ORACLE: ")][-1]
        o = json.loads(line[len("FLEET-ORACLE: "):])
        oracles[o["host_id"]] = o

    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        view = agg.merged()
        if (len(view.hosts) == N_HOSTS
                and all(r.shutdown for r in view.hosts.values())):
            break
        time.sleep(0.02)

    snap = head.write_report()
    labels = list(plan_lib.lane_slot_ids(spec))

    # -- fleet report ------------------------------------------------------
    print(f"\nfleet report  (hosts={snap['n_hosts']} "
          f"frames={snap['frames_in']} dropped={snap['dropped']} "
          f"fingerprint={snap['fingerprint'][:12]}...)")
    print(f"{'scope':<12} {'slot':<22} {'samples':>7} "
          f"{'p50':>9} {'p95':>9} {'p99':>9}")
    for lane in snap["lanes"]:
        if not lane["reservoir_n"]:
            continue
        print(f"{lane['scope']:<12} {lane['slot']:<22} "
              f"{lane['samples']:>7} {lane['p50']:>9.4f} "
              f"{lane['p95']:>9.4f} {lane['p99']:>9.4f}")
    print(f"\n{'host':<6} {'frames':>6} {'rate/s':>8} {'shutdown':>8} "
          f"{'straggler':>9}")
    for hid in sorted(snap["hosts"]):
        h = snap["hosts"][hid]
        rate = h["rate_smoothed"]
        print(f"{hid:<6} {h['frames']:>6} "
              f"{('-' if rate is None else f'{rate:.1f}'):>8} "
              f"{str(h['shutdown']):>8} {str(h['straggler']):>9}")
    print(f"hints broadcast: {hints}")
    print(f"report line appended to {report_path}")

    # -- smoke assertions (the acceptance criteria) ------------------------
    # 1. every host compiled the same plans, and the wire agrees
    fps = {o["fingerprint"] for o in oracles.values()}
    assert fps == {spec.fingerprint} == {snap["fingerprint"]}, fps
    assert snap["n_hosts"] == N_HOSTS and snap["dropped"] == 0

    # 2. fleet sums == sum of per-host shipped-frame oracles
    oracle_calls = np.sum([o["shipped_calls"] for o in oracles.values()],
                          axis=0)
    assert snap["calls"] == [int(c) for c in oracle_calls]
    oracle_vals = np.sum([o["shipped_values"] for o in oracles.values()],
                         axis=0)
    np.testing.assert_allclose([ln["sum"] for ln in snap["lanes"]],
                               oracle_vals, rtol=1e-9)
    oracle_samp = np.sum([o["shipped_samples"] for o in oracles.values()],
                         axis=0)
    assert [ln["samples"] for ln in snap["lanes"]] == \
        [int(s) for s in oracle_samp]

    # 3. fleet percentiles match the merged per-host interval-mean streams
    checked = 0
    for i, lane in enumerate(snap["lanes"]):
        merged = np.concatenate([
            np.asarray(o["lane_means"][i], np.float64)
            for o in oracles.values() if o["lane_means"]])
        if (not lane["reservoir_n"] or not len(merged)
                or not np.all(np.isfinite(merged))):
            continue
        got = [lane["p50"], lane["p95"], lane["p99"]]
        want = np.percentile(merged, [50, 95, 99])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6,
                                   err_msg=str(labels[i]))
        checked += 1
    assert checked >= 6, checked

    # 4. the straggler is flagged — and only the straggler
    assert snap["stragglers"] == [STRAGGLER], snap["hosts"]
    assert oracles[STRAGGLER]["straggler_fired"]

    # 5. the NaN tripwire round-tripped: head saw the fleet-level tick,
    #    broadcast a hint, and h0's controller applied it from the downlink
    assert any(r == "fleet:nan_count" for _, r in hints), hints
    assert head.hints_broadcast >= 1
    assert oracles[NAN_HOST]["fleet_hints"] >= 1, oracles[NAN_HOST]

    # 6. per-host frame accounting agrees end to end, report parses back
    for hid, o in oracles.items():
        assert snap["hosts"][hid]["frames"] == o["agent"]["frames_sent"]
        assert snap["hosts"][hid]["shutdown"] is True
    with open(report_path) as f:
        lines = f.read().splitlines()
    assert len(lines) == 1 and json.loads(lines[0])["n_hosts"] == N_HOSTS

    agg.close()
    print("FLEET-SMOKE: PASS")


if __name__ == "__main__":
    main()
