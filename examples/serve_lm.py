"""Continuous-batching serving with per-lane decode-time monitoring.

    PYTHONPATH=src python examples/serve_lm.py
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python examples/serve_lm.py --shards 2

Serves a small transformer LM through the lane-packed continuous engine:
requests enter free decode lanes as they arrive, every lane advances K
tokens per device dispatch (on-device sampling, token egress through the
telemetry ring), and ScALPEL attributes NaN/entropy counters to each
REQUEST via its lane's counter row — while the lane-summed aggregate
feeds the usual runtime report.

The demo oversubscribes 6 requests onto the lanes (mixed greedy + seeded
sampling), prints the per-lane attribution table, and cross-checks one
greedy request bitwise against the serial engine.  With ``--shards N``
the decode slab spans N devices (``ServeConfig.lane_shards`` —
shard_map'd megasteps, psum-reduced aggregate counters) and every check
still holds bitwise.
"""
import argparse

import jax
import numpy as np

from repro.configs import model_config
from repro.models.registry import Arch
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


def main(shards: int = 1):
    arch = Arch(model_config("mistral_nemo_12b", smoke=True))
    params = arch.init(jax.random.PRNGKey(0))
    # lane_shards must divide n_lanes: 3 lanes solo, 4 lanes over 2 shards
    n_lanes = 3 if shards == 1 else 2 * shards
    cfg = ServeConfig(cache_len=96, max_new_tokens=12,
                      n_lanes=n_lanes, steps_per_commit=4,
                      lane_shards=shards)
    eng = ContinuousEngine(arch, params, cfg)

    prompts = [
        jax.random.randint(jax.random.PRNGKey(10 + i), (1, 16), 0,
                           arch.cfg.vocab)
        for i in range(6)
    ]
    # 6 requests onto 3 lanes: greedy ones plus two SAME-SEED sampled ones
    # (which must sample identical tokens no matter which lane serves them)
    rids = [
        eng.submit(prompts[0], max_new=12),
        eng.submit(prompts[1], max_new=8, seed=7),
        eng.submit(prompts[2], max_new=6),
        eng.submit(prompts[3], max_new=10),
        eng.submit(prompts[1], max_new=8, seed=7),
        eng.submit(prompts[4], max_new=4),
    ]
    results = eng.run()

    total = sum(len(r.tokens) for r in results.values())
    print(f"served {len(results)} requests / {total} tokens on "
          f"{cfg.n_lanes} lanes in {eng.stats['megasteps']} megasteps "
          f"(K={cfg.steps_per_commit}, {eng.stats['wall_s'] * 1e3:.0f}ms, "
          f"{total / eng.stats['wall_s']:.0f} tok/s)")

    print("\nper-request attribution (lane counter rows):")
    for rid in rids:
        r = results[rid]
        calls = int(np.sum(r.counters.calls))
        print(f"  rid={rid} lane={r.lane} tokens={len(r.tokens)} "
              f"scope_calls={calls} first_toks={r.tokens[:4].tolist()}")

    print()
    print(eng.report())

    # -- checks behind the PASS marker ------------------------------------
    # 1. same-seed requests sampled identical tokens on different turns
    np.testing.assert_array_equal(results[rids[1]].tokens,
                                  results[rids[4]].tokens)
    # 2. a greedy request matches the serial oracle bitwise
    oracle = Engine(arch, params, ServeConfig(cache_len=96,
                                              max_new_tokens=12))
    want, _ = oracle.generate({"tokens": prompts[0]})
    np.testing.assert_array_equal(results[rids[0]].tokens,
                                  np.asarray(want)[0])
    # 3. attribution is complete and the aggregate is the lane sum
    agg = sum(int(np.sum(results[r].counters.calls)) for r in rids)
    assert agg == int(np.sum(np.asarray(eng.counters.calls))), (
        agg, eng.counters.calls)
    # 4. the decode loop never blocked per token and lost nothing
    assert eng.runtime.telemetry.dropped_tokens == 0
    assert eng.stats["token_drains"] >= eng.stats["megasteps"]
    print("\nSERVE-SMOKE: PASS")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the decode slab over this many devices")
    main(shards=ap.parse_args().shards)
