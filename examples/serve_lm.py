"""Batched serving with decode-time monitoring.

    PYTHONPATH=src python examples/serve_lm.py

Serves a small LM with a static batch of requests; ScALPEL counters run
through prefill and every decode step, and the monitored subset is
reconfigured BETWEEN decode steps with zero recompilation.
"""
import jax

from repro import core as scalpel
from repro.configs import model_config
from repro.models.registry import Arch
from repro.serve.engine import Engine, ServeConfig


def main():
    arch = Arch(model_config("mistral_nemo_12b", smoke=True))
    params = arch.init(jax.random.PRNGKey(0))
    eng = Engine(arch, params,
                 ServeConfig(cache_len=160, max_new_tokens=24))

    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 32), 0, arch.cfg.vocab
        )
    }
    out, stats = eng.generate(batch)
    print(f"generated {out.shape[1]} tokens x {out.shape[0]} requests")
    print(f"prefill {stats['prefill_s'] * 1e3:.1f}ms, "
          f"decode p50 {stats['decode_p50_s'] * 1e3:.1f}ms/token")
    print(eng.report())

    # runtime reconfiguration between requests: drop to interception-only
    eng.runtime.set_params(scalpel.MonitorParams.all_off(eng.spec))
    out2, stats2 = eng.generate(batch)
    print("\nafter masking all scopes (interception-only, same compiled "
          f"decode): p50 {stats2['decode_p50_s'] * 1e3:.1f}ms/token")


if __name__ == "__main__":
    main()
