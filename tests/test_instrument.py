"""Instrumentation semantics: interception, masking, call-count multiplexing
(the paper's central mechanism), scan threading, recursion, discovery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import core as scalpel
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams


def _spec_one(scope="f", sets=None, period=1):
    if sets is None:
        return MonitorSpec.of(
            [ScopeContext.exhaustive(scope, [EventSpec("MEAN", "x")])]
        )
    return MonitorSpec.of([
        ScopeContext.multiplexed(
            scope, [[EventSpec(e, "x") for e in s] for s in sets],
            period=period,
        )
    ])


def run_step(spec, params, state, fn, *args):
    with scalpel.collecting(spec, params, state) as col:
        out = fn(*args)
    return out, state.add(col.delta)


def test_vanilla_no_collector_is_identity():
    def f(x):
        with scalpel.function("f"):
            scalpel.probe(x=x)
            return x * 2

    x = jnp.arange(4.0)
    # no collector anywhere: results identical, no tracing overhead paths
    np.testing.assert_array_equal(f(x), x * 2)


def test_interception_counts_calls():
    spec = _spec_one()
    params = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)

    def prog(x):
        for _ in range(5):
            with scalpel.function("f"):
                scalpel.probe(x=x)
        return x

    _, state = run_step(spec, params, state, prog, jnp.ones(3))
    assert int(state.calls[0]) == 5
    assert int(state.samples[0, 0]) == 5


def test_scope_mask_off_intercepts_but_skips_events():
    """The paper's 'all' mode: interception without monitoring."""
    spec = _spec_one()
    params = MonitorParams.all_off(spec)
    state = CounterState.zeros(spec)

    def prog(x):
        with scalpel.function("f"):
            scalpel.probe(x=x)
        return x

    _, state = run_step(spec, params, state, prog, jnp.ones(3))
    assert int(state.calls[0]) == 1          # intercepted
    assert int(state.samples[0, 0]) == 0     # not monitored
    assert float(state.values[0, 0]) == 0.0


def test_mask_change_does_not_retrace():
    spec = _spec_one()
    traces = []

    @jax.jit
    def step(state, params, x):
        traces.append(1)
        with scalpel.collecting(spec, params, state) as col:
            with scalpel.function("f"):
                scalpel.probe(x=x)
        return state.add(col.delta)

    x = jnp.ones(3)
    s = CounterState.zeros(spec)
    s = step(s, MonitorParams.all_on(spec), x)
    s = step(s, MonitorParams.all_off(spec), x)  # flip mask: same trace
    p = MonitorParams.all_on(spec).set_period(spec, "f", 7)
    s = step(s, p, x)                            # change period: same trace
    assert len(traces) == 1
    assert int(s.calls[0]) == 3
    assert int(s.samples[0, 0]) == 2  # one masked-off call


def test_slot_mask_disables_single_event():
    spec = MonitorSpec.of([
        ScopeContext.exhaustive(
            "f", [EventSpec("MEAN", "x"), EventSpec("L2NORM", "x")]
        )
    ])
    params = MonitorParams.all_on(spec).set_slot(spec, "f", "L2NORM:x", False)
    state = CounterState.zeros(spec)

    def prog(x):
        with scalpel.function("f"):
            scalpel.probe(x=x)
        return x

    _, state = run_step(spec, params, state, prog, 2.0 * jnp.ones(4))
    assert float(state.values[0, 0]) == pytest.approx(2.0)
    assert int(state.samples[0, 0]) == 1
    assert int(state.samples[0, 1]) == 0


def _multiplex_sim(call_values, period, n_sets):
    """Expected (per-set sums, per-set sample counts) for MEAN events."""
    sums = [0.0] * n_sets
    counts = [0] * n_sets
    for c, v in enumerate(call_values):
        k = (c // period) % n_sets
        sums[k] += v
        counts[k] += 1
    return sums, counts


def test_multiplex_schedule_exact():
    """Set index must follow (calls // period) % n_sets exactly (paper C4)."""
    sets = [["MEAN"], ["L2NORM"], ["ACT_MAX_ABS"]]
    period = 2
    spec = _spec_one(sets=sets, period=period)
    params = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)
    n_calls = 13

    def prog(x):
        for i in range(n_calls):
            with scalpel.function("f"):
                scalpel.probe(x=x * (i + 1))
        return x

    _, state = run_step(spec, params, state, prog, jnp.ones(2))
    vals = [float(v) for v in (i + 1.0 for i in range(n_calls))]
    # MEAN of x*(i+1) over 2 elements = i+1; L2NORM = (i+1)*sqrt(2);
    # MAX_ABS = i+1
    per_call = {
        0: vals,
        1: [v * np.sqrt(2) for v in vals],
        2: vals,
    }
    for k in range(3):
        want_sum = sum(
            per_call[k][c] for c in range(n_calls)
            if (c // period) % 3 == k
        )
        want_n = sum(1 for c in range(n_calls) if (c // period) % 3 == k)
        assert float(state.values[0, k]) == pytest.approx(want_sum, rel=1e-5)
        assert int(state.samples[0, k]) == want_n


def test_multiplex_continues_across_steps():
    """Call counts carry across jit boundaries: the schedule never resets."""
    sets = [["MEAN"], ["L2NORM"]]
    spec = _spec_one(sets=sets, period=1)
    params = MonitorParams.all_on(spec)

    @jax.jit
    def step(state, x):
        with scalpel.collecting(spec, params, state) as col:
            with scalpel.function("f"):
                scalpel.probe(x=x)
        return state.add(col.delta)

    s = CounterState.zeros(spec)
    for _ in range(4):
        s = step(s, jnp.ones(2))
    # alternating sets: calls 0,2 -> set0; 1,3 -> set1
    assert int(s.samples[0, 0]) == 2
    assert int(s.samples[0, 1]) == 2


def test_nested_scopes_and_recursion_paths():
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("outer", [EventSpec("MEAN", "x")]),
        ScopeContext.exhaustive("outer/inner", [EventSpec("MEAN", "x")]),
    ])
    params = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)

    def rec(x, depth):
        with scalpel.function("outer"):
            scalpel.probe(x=x)
            with scalpel.function("inner"):
                scalpel.probe(x=x + 1)
            if depth:
                return rec(x, depth - 1)
            return x

    _, state = run_step(spec, params, state, lambda x: rec(x, 2),
                        jnp.zeros(2))
    # both parent and child are monitored on every level (3 calls each)
    assert int(state.calls[spec.scope_index("outer")]) == 3
    assert int(state.calls[spec.scope_index("outer/inner")]) == 3


def test_scan_with_counters_matches_unrolled():
    spec = _spec_one(sets=[["MEAN"], ["L2NORM"]], period=1)
    params = MonitorParams.all_on(spec)
    xs = jnp.arange(6.0).reshape(6, 1)

    def body(carry, x):
        with scalpel.function("f"):
            scalpel.probe(x=x + carry)
        return carry + 1.0, x

    # scan version
    state = CounterState.zeros(spec)
    with scalpel.collecting(spec, params, state) as col:
        scalpel.scan_with_counters(body, jnp.zeros(()), xs)
    scan_state = state.add(col.delta)

    # unrolled version
    state2 = CounterState.zeros(spec)
    with scalpel.collecting(spec, params, state2) as col2:
        c = jnp.zeros(())
        for i in range(6):
            c, _ = body(c, xs[i])
    unrolled = state2.add(col2.delta)

    np.testing.assert_allclose(scan_state.calls, unrolled.calls)
    np.testing.assert_allclose(
        scan_state.values, unrolled.values, rtol=1e-6)
    np.testing.assert_allclose(scan_state.samples, unrolled.samples)


def test_scan_with_counters_no_collector_plain_scan():
    def body(c, x):
        return c + x, c

    out, ys = scalpel.scan_with_counters(body, jnp.zeros(()), jnp.arange(4.0))
    assert float(out) == 6.0


def test_scan_with_counters_remat():
    spec = _spec_one()
    params = MonitorParams.all_on(spec)
    xs = jnp.ones((4, 2))

    def body(carry, x):
        with scalpel.function("f"):
            scalpel.probe(x=x)
        return carry * 2.0, x

    def loss(c0):
        state = CounterState.zeros(spec)
        with scalpel.collecting(spec, params, state) as col:
            c, _ = scalpel.scan_with_counters(
                body, c0, xs, remat=jax.checkpoint
            )
        return (c * state.add(col.delta).values[0, 0]).sum()

    g = jax.grad(loss)(jnp.ones(()))
    assert np.isfinite(float(g))


def test_instrument_decorator_and_probe_scope():
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("g", [EventSpec("MEAN", "out")]),
        ScopeContext.exhaustive("h", [EventSpec("MEAN", "y")]),
    ])
    params = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)

    g = scalpel.instrument(lambda x: x * 3, "g")

    def prog(x):
        out = g(x)
        scalpel.probe_scope("h", y=out + 1)
        return out

    _, state = run_step(spec, params, state, prog, jnp.ones(2))
    assert float(state.values[0, 0]) == pytest.approx(3.0)
    assert float(state.values[1, 0]) == pytest.approx(4.0)


def test_discovery_enumerates_scopes_and_tensors():
    def prog(x):
        with scalpel.function("a"):
            scalpel.probe(x=x)
            with scalpel.function("b"):
                scalpel.probe(y=x, z=x)
        return x

    seen = scalpel.discover(prog, jnp.ones((2, 2)))
    assert seen["a"] == ("x",)
    assert set(seen["a/b"]) == {"y", "z"}
    spec = scalpel.spec_from_discovery(seen, tensor_events=("ACT_RMS",))
    assert spec.n_scopes == 2
    assert spec.context("a/b").slot_ids == ("ACT_RMS:y", "ACT_RMS:z")


def test_counters_cross_shard_psum_shape():
    spec = _spec_one()
    s = CounterState.zeros(spec)
    # psum outside pmap raises; just validate add/zeros algebra instead
    s2 = s.add(s)
    assert s2.calls.shape == s.calls.shape


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 9),        # period
    st.integers(1, 4),        # n_sets
    st.integers(1, 30),       # calls
)
def test_multiplex_property(period, n_sets, n_calls):
    """Property: per-set sample counts follow the schedule for ANY
    (period, n_sets, calls) combination."""
    sets = [["MEAN"], ["L2NORM"], ["ACT_MAX_ABS"], ["ACT_MEAN_ABS"]][:n_sets]
    spec = _spec_one(sets=sets, period=period)
    params = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)

    def prog(x):
        for _ in range(n_calls):
            with scalpel.function("f"):
                scalpel.probe(x=x)
        return x

    _, state = run_step(spec, params, state, prog, jnp.ones(2))
    for k in range(n_sets):
        want = sum(
            1 for c in range(n_calls) if (c // period) % n_sets == k
        )
        assert int(state.samples[0, k]) == want, (period, n_sets, k)
