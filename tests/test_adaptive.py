"""The closed adaptive loop: escalation/localization, hysteresis bounds,
degradation-ladder round trips with exact counters, drain-thread survival
through injected sink failures, the overhead budget loop, and graceful
shutdown.  Faults come from the deterministic harness in
``repro.testing.faults``."""
import os
import signal
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import plan as plan_lib
from repro.core import telemetry as telemetry_lib
from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams
from repro.testing.faults import (
    FailingSink,
    FaultInjector,
    SlowSink,
    StragglerDelay,
    TensorFault,
)

EVENTS = ("ACT_RMS", "ACT_ZERO_FRAC", "NAN_COUNT", "INF_COUNT")


def _spec(scopes=("hot", "cold")):
    return MonitorSpec.of([
        ScopeContext.exhaustive(s, [EventSpec(e, "x") for e in EVENTS])
        for s in scopes
    ])


def _drive(spec, runtime, steps, injector=None, warmup=0,
           attach=None):
    """A monitored loop probing a CONSTANT tensor per scope (so estimates
    are invariant to WHICH calls get sampled — the round-trip tests compare
    them across different controller schedules).  ``runtime.flush()`` every
    step makes controller ticks deterministic.  ``attach`` (if given) runs
    after the ``warmup`` steps — e.g. installing the controller once jit
    compile time is out of the step-time baseline."""
    mon = scalpel.Monitor(spec, telemetry=runtime.telemetry,
                          counter_axes=())
    base = jnp.full((16,), 1.5)

    def work(step):
        for s in spec.scopes:
            v = base
            if injector is not None:
                v = injector.corrupt(s, "x", step, v)
            with scalpel.function(s):
                scalpel.probe(x=v)
        return step + 1

    fn = mon.jit(work)
    mstate = mon.init()
    step = jnp.zeros((), jnp.int32)
    for i in range(warmup):
        mstate = mon.sync(mstate, runtime=runtime)
        step, mstate = fn(mstate, step)
        runtime.on_step(mstate.counters, ring=mstate.ring)
        runtime.flush()
    if attach is not None:
        attach()
    for i in range(warmup, steps):
        mstate = mon.sync(mstate, runtime=runtime)
        step, mstate = fn(mstate, step)
        runtime.on_step(mstate.counters, ring=mstate.ring)
        if injector is not None:
            injector.host_step(i)
        runtime.flush()
    return mon, mstate


# ---------------------------------------------------------------------------
# sentinel-set compilation (plan.py)
# ---------------------------------------------------------------------------

def test_compile_sentinels_table():
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("a", [EventSpec("ACT_RMS", "x"),
                                      EventSpec("NAN_COUNT", "x")]),
        ScopeContext.exhaustive("b", [EventSpec("ACT_ZERO_FRAC", "x"),
                                      EventSpec("ATTN_ENTROPY", "p"),
                                      EventSpec("INF_COUNT", "x")]),
    ])
    table = plan_lib.compile_sentinels(spec)
    assert [t.scope for t in table] == ["a", "b"]
    a, b = table
    # ACT_RMS carries no detector; lanes target the spec-wide dense layout
    assert [(l.slot_id, l.detector, l.lane) for l in a.lanes] == [
        ("NAN_COUNT:x", plan_lib.DETECT_TRIPWIRE, 1),
    ]
    assert [(l.slot_id, l.detector, l.lane) for l in b.lanes] == [
        ("ACT_ZERO_FRAC:x", plan_lib.DETECT_SPIKE, 2),
        ("ATTN_ENTROPY:p", plan_lib.DETECT_COLLAPSE, 3),
        ("INF_COUNT:x", plan_lib.DETECT_TRIPWIRE, 4),
    ]
    # lanes line up with the layout the compact carriers use
    assert a.lanes[0].lane == spec.slot_lane("a", "NAN_COUNT:x")
    assert b.lanes[2].lane == spec.slot_lane("b", "INF_COUNT:x")


# ---------------------------------------------------------------------------
# escalation: localization within K drained snapshots
# ---------------------------------------------------------------------------

def test_nan_localized_to_correct_scope_within_k_drains():
    spec = _spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    ctl = runtime.attach_controller(AdaptiveConfig(
        quiet_drains=100, cooldown_drains=2, overhead_budget=1.0,
    ))
    injector = FaultInjector([TensorFault("hot", "x", step=8)])
    _drive(spec, runtime, steps=16, injector=injector)
    runtime.close()

    wide = [t for t in ctl.transitions if t.to == "wide"]
    assert len(wide) == 1 and wide[0].scope == "hot", ctl.events
    # K=5 acceptance bound (cadence 1: snapshots == steps); detection is
    # same-snapshot, so the latency is the append+drain pipeline only
    assert wide[0].step - 8 <= 5, wide[0]
    assert "NAN_COUNT:x" in wide[0].reason
    # the hot-swap actually widened the live params for that scope alone
    hi, ci = spec.scope_index("hot"), spec.scope_index("cold")
    p = runtime.params
    assert float(p.scope_mask[hi]) == 1.0
    assert np.asarray(p.slot_mask)[hi].min() == 1.0
    assert int(p.period[hi]) == 1
    assert ctl.levels["cold"] == "configured"
    # and raised the ring cadence while escalated
    assert runtime.telemetry.cadence == 1


def test_inf_fault_also_trips():
    spec = _spec(scopes=("hot",))
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    ctl = runtime.attach_controller(AdaptiveConfig(
        quiet_drains=100, overhead_budget=1.0,
    ))
    injector = FaultInjector([TensorFault("hot", "x", step=5, kind="inf")])
    _drive(spec, runtime, steps=10, injector=injector)
    runtime.close()
    wide = [t for t in ctl.transitions if t.to == "wide"]
    assert len(wide) == 1 and "INF_COUNT:x" in wide[0].reason


# ---------------------------------------------------------------------------
# hysteresis: a never-quiet scope cannot thrash plans
# ---------------------------------------------------------------------------

def test_never_quiet_scope_escalates_once_and_stays():
    spec = _spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    # step_time_floor_s pins the wall-clock wake path off: this test is
    # about the tensor-anomaly ladder, and the sub-ms harness steps would
    # otherwise let a scheduler hiccup wake the sentinel mid-assert
    ctl = runtime.attach_controller(AdaptiveConfig(
        quiet_drains=3, cooldown_drains=2, overhead_budget=1.0,
        step_time_floor_s=10.0,
    ))
    # NaN on EVERY step from 0: the scope never goes quiet
    injector = FaultInjector([TensorFault("hot", "x", step=0, every=1)])
    _drive(spec, runtime, steps=30, injector=injector)
    runtime.close()

    assert ctl.stats["drains"] >= 25
    hot_t = [t for t in ctl.transitions if t.scope == "hot"]
    # the hysteresis bound: ONE escalation, zero flapping after it
    assert [(t.frm, t.to) for t in hot_t] == [("configured", "wide")]
    assert ctl.levels["hot"] == "wide"
    # cold decays to sentinel exactly once — total plan swaps stay bounded
    # by ladder depth, not by drain count
    cold_t = [t for t in ctl.transitions if t.scope == "cold"]
    assert [(t.frm, t.to) for t in cold_t] == [("configured", "sentinel")]
    assert ctl.stats["plan_swaps"] == len(ctl.transitions) == 2


# ---------------------------------------------------------------------------
# round trip: de-escalation/re-escalation keeps counters exact
# ---------------------------------------------------------------------------

def _roundtrip_run(with_controller: bool):
    spec = _spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    ctl = None

    def attach():
        nonlocal ctl
        if with_controller:
            ctl = runtime.attach_controller(AdaptiveConfig(
                quiet_drains=2, cooldown_drains=1, warmup_drains=2,
                step_time_sigma=6.0, overhead_budget=1.0,
            ))

    injector = FaultInjector([StragglerDelay(step=20, seconds=0.25)])
    mon, mstate = _drive(spec, runtime, steps=32, injector=injector,
                         warmup=4, attach=attach)
    calls = np.asarray(mstate.calls).copy()
    est = mon.estimates(mstate)
    runtime.close()
    return calls, est, ctl


def test_roundtrip_keeps_counters_exact_vs_always_wide():
    calls_on, est_on, ctl = _roundtrip_run(with_controller=True)
    calls_off, est_off, _ = _roundtrip_run(with_controller=False)

    # the ladder actually cycled: decay to sentinel, step-time wake back up
    down = [t for t in ctl.transitions if t.to == "sentinel"]
    up = [t for t in ctl.transitions if t.frm == "sentinel"
          and t.to == "configured"]
    assert down and up, ctl.events
    assert ctl.stats["step_time_wakes"] >= 1

    # interception is free at every rung: calls are EXACT either way
    np.testing.assert_array_equal(calls_on, calls_off)
    # anomaly-free scopes probe a stationary tensor, so the estimates are
    # invariant to which calls the controller's schedule sampled
    for scope in est_off:
        for slot_id, v_off in est_off[scope].items():
            v_on = est_on[scope][slot_id]
            assert np.isfinite(v_on) == np.isfinite(v_off), (scope, slot_id)
            if np.isfinite(v_off):
                np.testing.assert_allclose(v_on, v_off, rtol=1e-6,
                                           err_msg=f"{scope}/{slot_id}")


# ---------------------------------------------------------------------------
# drain-thread hardening (satellite: sinks that raise must not kill drains)
# ---------------------------------------------------------------------------

def _plane(spec, cadence=1, depth=4):
    # interval_s long enough that only explicit flush() drains — the tests
    # own the drain clock
    return telemetry_lib.TelemetryPlane(spec, depth=depth, cadence=cadence,
                                        interval_s=60.0)


def _pump(plane, spec, n, start=0):
    """Append+flush n snapshots synchronously; returns drained steps."""
    seen = []
    for i in range(start, start + n):
        plane.append(CounterState.zeros(spec), step=i + 1)
        plane.flush()
        seen.append(i + 1)
    return seen


def test_drain_survives_sink_failure_and_heals():
    spec = _spec()
    plane = _plane(spec)
    bad = FailingSink(fail_first=2)
    good: list[int] = []
    plane.add_sink(bad)
    plane.add_sink(telemetry_lib.CallbackSink(
        lambda s: good.append(s.step)))
    _pump(plane, spec, 12)
    # the healthy sink saw EVERY snapshot despite its neighbor raising
    assert good == list(range(1, 13))
    # the failing sink backed off exponentially (drains 1, 3, 7: two
    # failures, then healed) and its errors are accounted
    assert bad.attempts >= 3 and bad.emitted, (bad.attempts, bad.emitted)
    errs = plane.sink_errors
    assert list(errs.values()) == [2], errs
    assert "FailingSink" in next(iter(errs))
    assert plane.dropped_sinks == []
    plane.close()


def test_sink_dropped_after_consecutive_failures():
    spec = _spec()
    plane = _plane(spec)
    bad = FailingSink(fail_always=True)
    good: list[int] = []
    plane.add_sink(bad)
    plane.add_sink(telemetry_lib.CallbackSink(
        lambda s: good.append(s.step)))
    # backoff schedule retries at drains 1, 3, 7, 15, 31 — the 5th
    # consecutive failure drops the sink
    _pump(plane, spec, 34)
    assert bad.attempts == 5
    assert bad not in plane.sinks
    assert len(plane.dropped_sinks) == 1
    assert "FailingSink" in plane.dropped_sinks[0]
    assert list(plane.sink_errors.values()) == [5]
    assert good == list(range(1, 35))  # drains never stopped
    plane.close()


def test_flush_failure_is_accounted_not_raised():
    class BadFlush(telemetry_lib.Sink):
        def emit(self, snap):
            pass

        def flush(self):
            raise OSError("disk full")

    spec = _spec()
    plane = _plane(spec)
    plane.add_sink(BadFlush())
    _pump(plane, spec, 2)
    assert sum(plane.sink_errors.values()) >= 1
    plane.close()


# ---------------------------------------------------------------------------
# budget loop: hold measured overhead within the configured fraction
# ---------------------------------------------------------------------------

def test_budget_loop_raises_cadence_under_overhead():
    spec = _spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    # a sink stalling 30ms per snapshot: drain overhead dwarfs the 5%
    # budget, the proportional controller must back the cadence off
    runtime.telemetry.add_sink(SlowSink(seconds=0.03))
    ctl = runtime.attach_controller(AdaptiveConfig(
        overhead_budget=0.05, quiet_drains=1000,
    ))
    _drive(spec, runtime, steps=14)
    runtime.close()
    assert runtime.telemetry.cadence > 1, ctl.events
    assert ctl.stats["cadence_changes"] >= 1
    assert ctl.overhead_frac > 0.05


def test_drain_seconds_accounting_monotonic():
    spec = _spec()
    plane = _plane(spec)
    assert plane.drain_seconds == 0.0
    _pump(plane, spec, 3)
    after = plane.drain_seconds
    assert after > 0.0
    plane.flush()   # empty drain still ticks the clock (head probe)
    assert plane.drain_seconds >= after
    plane.close()


# ---------------------------------------------------------------------------
# standalone controller (no runtime): Monitor.sync picks it up
# ---------------------------------------------------------------------------

def test_monitor_sync_picks_up_controller_without_runtime():
    spec = _spec()
    plane = _plane(spec, cadence=4)
    ctl = AdaptiveController(
        spec=spec, params=MonitorParams.all_on(spec), telemetry=plane,
        config=AdaptiveConfig(escalated_cadence=1),
    ).install()
    mon = scalpel.Monitor(spec, telemetry=plane, counter_axes=())
    mstate = mon.init()
    assert int(mstate.tparams.cadence) == 4
    ctl.escalate("hot")
    m2 = mon.sync(mstate, controller=ctl)
    assert m2.params is ctl.params
    assert float(m2.params.scope_mask[spec.scope_index("hot")]) == 1.0
    # the escalation pinned the plane cadence down; sync carried it in
    assert plane.cadence == 1 and int(m2.tparams.cadence) == 1
    assert ctl.levels["hot"] == "wide"
    plane.close()


def test_controller_levels_and_transitions_are_auditable():
    spec = _spec()
    plane = _plane(spec)
    ctl = AdaptiveController(spec=spec, params=MonitorParams.all_on(spec),
                             telemetry=plane).install()
    assert set(ctl.levels.values()) == {"configured"}
    ctl.escalate("cold", "manual")
    t = ctl.transitions[-1]
    assert (t.scope, t.frm, t.to) == ("cold", "configured", "wide")
    assert ctl.stats["escalations"] == 1
    assert "wide" in ctl.describe()
    plane.close()


# ---------------------------------------------------------------------------
# graceful shutdown (satellite): SIGTERM/atexit path, idempotent with close
# ---------------------------------------------------------------------------

def test_shutdown_is_idempotent_with_close(capsys):
    spec = _spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    runtime.on_step(CounterState.zeros(spec))
    rep = runtime.shutdown()
    assert rep is not None and "ScALPEL final report" in rep
    assert runtime.closed
    assert runtime.shutdown() is None     # second shutdown: no-op
    runtime.close()                        # close after shutdown: no-op
    out = capsys.readouterr().out
    assert out.count("ScALPEL final report") == 1


def test_close_first_makes_shutdown_noop():
    spec = _spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
    runtime.close()
    assert runtime.shutdown() is None


def test_sigterm_flushes_and_chains_previous_handler():
    calls: list[str] = []
    prev = signal.signal(signal.SIGTERM, lambda s, f: calls.append("prev"))
    try:
        spec = _spec()
        runtime = scalpel.ScalpelRuntime(spec, hook_every=1)
        runtime.install_shutdown()
        runtime.install_shutdown()        # idempotent
        os.kill(os.getpid(), signal.SIGTERM)
        deadline = time.time() + 5
        while not calls and time.time() < deadline:
            time.sleep(0.01)
        assert calls == ["prev"]          # chained, exactly once
        assert runtime.closed             # flushed + closed before chaining
    finally:
        signal.signal(signal.SIGTERM, prev)


# ---------------------------------------------------------------------------
# fit() integration: the adaptive knob
# ---------------------------------------------------------------------------

def test_fit_with_adaptive_controller():
    from repro.configs import model_config
    from repro.data import DataConfig
    from repro.models.registry import Arch
    from repro.optim import OptConfig
    from repro.train.loop import TrainLoopConfig, fit

    arch = Arch(model_config("xlstm_125m", smoke=True))
    out = fit(
        arch,
        OptConfig(lr=3e-3, warmup_steps=2, total_steps=200,
                  weight_decay=0.01),
        DataConfig(vocab=512, seq_len=32, global_batch=4),
        TrainLoopConfig(steps=10, log_every=0, ckpt_every=0, hook_every=2,
                        adaptive=AdaptiveConfig(overhead_budget=1.0)),
    )
    ctl = out["controller"]
    assert ctl is not None and ctl.stats["drains"] > 0
    assert np.isfinite(out["final_loss"])


# ---------------------------------------------------------------------------
# fault harness unit behaviour
# ---------------------------------------------------------------------------

def test_tensor_fault_is_step_addressed_and_trace_stable():
    import jax

    inj = FaultInjector([TensorFault("s", "x", step=3, count=2)])
    traces = []

    @jax.jit
    def f(step, x):
        traces.append(1)
        return inj.corrupt("s", "x", step, x)

    x = jnp.ones((4,))
    clean = f(jnp.asarray(2, jnp.int32), x)
    hit = f(jnp.asarray(3, jnp.int32), x)
    assert len(traces) == 1               # step is data, not a trace key
    np.testing.assert_array_equal(np.asarray(clean), np.ones((4,)))
    assert np.isnan(np.asarray(hit)[:2]).all()
    assert np.isfinite(np.asarray(hit)[2:]).all()
    # unmatched scope/tensor: untouched
    same = inj.corrupt("other", "x", jnp.asarray(3, jnp.int32), x)
    np.testing.assert_array_equal(np.asarray(same), np.ones((4,)))


def test_fault_kind_validated():
    with pytest.raises(ValueError):
        TensorFault("s", "x", step=0, kind="bogus")
