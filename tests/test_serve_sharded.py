"""Mesh-sharded continuous batching (serve/driver.py over a ``lanes``
mesh), in a subprocess with 2 forced host devices so the XLA device-count
flag never leaks into the other tests' 1-device environment.

The acceptance contract of the lane-sharding redesign:

* EXACTNESS — a 2-shard engine (``ServeConfig.lane_shards=2``) produces
  greedy tokens BITWISE equal to the single-device engine, and per-request
  lane-counter attribution allclose to fresh serial-engine runs, for
  requests landing on lanes of BOTH shards (including lane reuse);

* PER-SHARD SCHEDULE — ``lane_sched`` stays per-shard under shard_map
  with K=4 megasteps and a multiplexed scope: it tracks ``lane_calls``
  exactly (both seed and advance together; a psum would double one of
  them), and the sharded aggregate counters — including the mux samples
  split — exactly equal the unsharded run's;

* ZERO HOST SYNCS — the sharded decode loop still never calls
  ``jax.block_until_ready``: megasteps, admissions, psum-reduced counter
  publishes and token-ring publishes are all async, with the single
  blocking readback at the final completion drain.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.configs import model_config
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.models.registry import Arch
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

assert len(jax.devices()) == 2

arch = Arch(model_config("xlstm_125m", smoke=True))
params = arch.init(jax.random.PRNGKey(0))
V = arch.cfg.vocab


def prompt(seed, s=8):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed),
                                         (1, s), 0, V))


def serial(p, max_new, seed=None):
    eng = Engine(arch, params, ServeConfig(cache_len=64,
                                           max_new_tokens=max_new))
    out, _ = eng.generate({"tokens": p}, seed=seed)
    return np.asarray(out)[0], eng.counters


def run_engine(shards, spec=None, k=4):
    cfg = ServeConfig(cache_len=64, max_new_tokens=6, n_lanes=4,
                      steps_per_commit=k, lane_shards=shards)
    eng = ContinuousEngine(arch, params, cfg, spec=spec)
    rids = [eng.submit(prompt(100 + i), max_new=6) for i in range(6)]
    return eng, rids, eng.run()

# ---- sharded == single-device, bitwise tokens + allclose counters ------
e1, rids1, res1 = run_engine(1)
e2, rids2, res2 = run_engine(2)

tokens_equal = all(
    np.array_equal(res1[a].tokens, res2[b].tokens)
    for a, b in zip(rids1, rids2)
)
# 6 requests over 4 lanes across 2 shards: both shards served requests,
# and at least one lane was reused (re-admission on a sharded slab)
lanes2 = [res2[r].lane for r in rids2]
both_shards_used = any(ln < 2 for ln in lanes2) and \
    any(ln >= 2 for ln in lanes2)
lane_reused = len(lanes2) > len(set(lanes2))

ctr_close = True
for a, b in zip(rids1, rids2):
    for x, y in zip(jax.tree.leaves(res1[a].counters),
                    jax.tree.leaves(res2[b].counters)):
        ctr_close &= bool(np.allclose(np.asarray(x), np.asarray(y),
                                      rtol=1e-5, atol=1e-6))

# ---- per-request attribution vs fresh SERIAL runs, both shards ---------
serial_close = True
for i, rid in enumerate(rids2):
    want_toks, want_ctr = serial(prompt(100 + i), max_new=6)
    serial_close &= bool(np.array_equal(res2[rid].tokens, want_toks))
    got = res2[rid].counters
    serial_close &= bool(np.array_equal(np.asarray(got.calls),
                                        np.asarray(want_ctr.calls)))
    serial_close &= bool(np.array_equal(np.asarray(got.samples),
                                        np.asarray(want_ctr.samples)))
    serial_close &= bool(np.allclose(np.asarray(got.values),
                                     np.asarray(want_ctr.values),
                                     rtol=1e-5, atol=1e-6))

agg_close = all(
    np.allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)
    for x, y in zip(jax.tree.leaves(e1.counters),
                    jax.tree.leaves(e2.counters))
)

# ---- multiplexed scope under K=4 sharded megasteps ---------------------
# Rebuild the serve spec with its widest scope MULTIPLEXED into two event
# sets.  The schedule base is per-lane AND per-shard (lane_sched); if the
# megastep fed psum-reduced totals back as the base, the sharded run's
# set rotation — hence its sampled counters — would diverge from the
# unsharded run's.


def probe_fn(p, toks):
    cache, logits = arch.prefill(p, {"tokens": toks}, cache_len=64)
    return arch.decode_step(p, cache, toks[:, :1])


seen = scalpel.discover(probe_fn, arch.abstract_params(),
                        jax.ShapeDtypeStruct((1, 8), jnp.int32))
ctxs = []
for scope, tnames in sorted(seen.items()):
    slots = [EventSpec(event=ev, tensor=t) for t in tnames
             for ev in ("ACT_RMS", "ACT_MEAN_ABS")]
    if scope == max(seen, key=lambda s: len(seen[s])):
        half = max(1, len(slots) // 2)
        ctxs.append(ScopeContext.multiplexed(scope,
                                             [slots[:half], slots[half:]]))
    else:
        ctxs.append(ScopeContext.exhaustive(scope, slots))
mux_spec = MonitorSpec.of(ctxs)

m1, _, _ = run_engine(1, spec=mux_spec, k=4)
m2, _, _ = run_engine(2, spec=mux_spec, k=4)
mux_agg_equal = bool(
    np.array_equal(np.asarray(m1.counters.calls),
                   np.asarray(m2.counters.calls))
    and np.array_equal(np.asarray(m1.counters.samples),
                       np.asarray(m2.counters.samples))
    and np.allclose(np.asarray(m1.counters.values),
                    np.asarray(m2.counters.values), rtol=1e-5, atol=1e-6)
)
# both event sets actually sampled (the mux rotated), on both engines
mux_rotated = bool((np.asarray(m1.counters.samples) > 0).all()
                   and (np.asarray(m2.counters.samples) > 0).all())
# the per-shard schedule invariant: lane_sched tracks lane_calls exactly
# (seeded and advanced together; any stray reduction breaks one of them)
sched_per_shard = bool(
    np.array_equal(np.asarray(m2.lstate.lane_sched),
                   np.asarray(m2.lstate.lane_calls))
)

# ---- zero-host-sync attestation on the sharded engine ------------------
blocks = []
real_block = jax.block_until_ready
jax.block_until_ready = lambda x: (blocks.append(1), real_block(x))[1]
try:
    e3, rids3, res3 = run_engine(2)
finally:
    jax.block_until_ready = real_block
no_syncs = not blocks
sharded_complete = (len(res3) == 6
                    and all(len(res3[r].tokens) == 6 for r in rids3)
                    and e3.runtime.telemetry.dropped_tokens == 0)

print(json.dumps({
    "tokens_equal": tokens_equal,
    "both_shards_used": both_shards_used,
    "lane_reused": lane_reused,
    "ctr_close": ctr_close,
    "serial_close": serial_close,
    "agg_close": agg_close,
    "mux_agg_equal": mux_agg_equal,
    "mux_rotated": mux_rotated,
    "sched_per_shard": sched_per_shard,
    "no_syncs": no_syncs,
    "sharded_complete": sharded_complete,
    "lanes2": lanes2,
    "compile_stats": {k: v for k, v in e2.compile_stats().items()},
}))
"""


@pytest.mark.slow
def test_serve_sharded_2dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["tokens_equal"], res
    assert res["both_shards_used"], res
    assert res["lane_reused"], res
    assert res["ctr_close"], res
    assert res["serial_close"], res
    assert res["agg_close"], res
    assert res["mux_agg_equal"], res
    assert res["mux_rotated"], res
    assert res["sched_per_shard"], res
    assert res["no_syncs"], res
    assert res["sharded_complete"], res
    # the sharded engine compiled each program exactly once (one prompt
    # bucket; no per-length or per-lane re-traces)
    cs = res["compile_stats"]
    assert cs["prefill_traces"] == 1, cs
    assert cs["admission_traces"] == 1, cs
    assert cs["megastep_traces"] == 1, cs
