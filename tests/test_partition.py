"""Logical-axis partitioning rules and relaxation."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec

from repro.dist.partition import (
    axis_size,
    input_sharding,
    logical_to_pspec,
    relaxed_pspec,
    shard,
    sharding_ctx,
    tree_shardings,
)


@pytest.fixture()
def mesh1():
    return jax.make_mesh((1,), ("data",))


def test_no_context_is_noop():
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x
    assert logical_to_pspec(("batch", None)) == PartitionSpec()


def test_logical_to_pspec_rules(mesh1):
    with sharding_ctx(mesh1):
        ps = logical_to_pspec(("batch", "mlp"))
    # 'model' axis absent in this mesh -> mlp falls to replicated
    assert ps == PartitionSpec("data", None)


def test_pspec_duplicate_mesh_axis_suppressed(mesh1):
    # embed and batch both map to 'data'; an axis may appear only once
    with sharding_ctx(mesh1):
        ps = logical_to_pspec(("batch", "embed"))
    assert ps == PartitionSpec("data", None)


def test_relaxation_drops_nondividing(mesh1):
    mesh = jax.make_mesh((1,), ("model",))
    rules = {"mlp": ("model",)}
    ps = relaxed_pspec((7,), ("mlp",), mesh, rules)
    assert ps == PartitionSpec("model")  # 1 divides everything
    mesh2 = jax.make_mesh((1,), ("data",))  # model axis absent
    ps2 = relaxed_pspec((7,), ("mlp",), mesh2, rules)
    assert ps2 == PartitionSpec(None)


def test_axis_size_defaults(mesh1):
    assert axis_size("model") == 1  # no ctx
    with sharding_ctx(mesh1):
        assert axis_size("data") == 1
        assert axis_size("model") == 1


def test_tree_shardings_structure(mesh1):
    abs_tree = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    ax_tree = {"w": ("embed", "mlp")}
    sh = tree_shardings(abs_tree, ax_tree, mesh1)
    assert sh["w"].mesh.axis_names == ("data",)


def test_input_sharding_applied(mesh1):
    sh = input_sharding((8, 8), ("batch", None), mesh1)
    x = jax.device_put(jnp.ones((8, 8)), sh)
    assert x.sharding == sh


def test_shard_constraint_inside_jit(mesh1):
    with sharding_ctx(mesh1):
        @jax.jit
        def f(x):
            return shard(x, "batch", None) * 2

        y = f(jnp.ones((4, 4)))
    assert float(y[0, 0]) == 2.0
