"""While-aware HLO cost analysis (the roofline source).

``compiled.cost_analysis()`` counts while bodies once; hlo_graph must scale
by trip count and account slice/update traffic in place.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.backends import hlo_graph


def _analyze(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return hlo_graph.analyze_text(c.as_text()), c


def test_scan_trip_count_scaling():
    M, T = 256, 12

    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    r, c = _analyze(
        f,
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((T, M, M), jnp.float32),
    )
    want = 2.0 * M ** 3 * T
    assert r["flops"] == pytest.approx(want, rel=0.05)
    assert r["unscaled_whiles"] == 0
    # raw cost_analysis counts the body once — the very bug this fixes
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert float(ca["flops"]) < want / 2


def test_scan_memory_not_multiplied_by_full_operand():
    """The scan body dynamic-slices one [M,M] layer per trip; traffic must
    scale with the slice, not the whole [T,M,M] stack."""
    M, T = 128, 64

    def body(x, w):
        return x + w, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    r, _ = _analyze(
        f,
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((T, M, M), jnp.float32),
    )
    full_stack_per_trip = T * (T * M * M * 4)  # the overcount we reject
    assert r["hbm_bytes"] < full_stack_per_trip / 4
    assert r["hbm_bytes"] > T * M * M * 4  # at least reads each slice once


def test_nested_scan_multiplies():
    M, T1, T2 = 128, 5, 7

    def inner(x, w):
        return jnp.tanh(x @ w), None

    def outer(x, ws):
        def obody(c, _):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, None

        y, _ = jax.lax.scan(obody, x, None, length=T1)
        return y

    r, _ = _analyze(
        outer,
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((T2, M, M), jnp.float32),
    )
    assert r["flops"] == pytest.approx(2.0 * M ** 3 * T1 * T2, rel=0.05)


def test_unrolled_matches_scan():
    M, T = 128, 6

    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(T):
            x = jnp.tanh(x @ ws[i])
        return x

    xs = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, M, M), jnp.float32)
    r1, _ = _analyze(f_scan, xs, ws)
    r2, _ = _analyze(f_unroll, xs, ws)
    assert r1["flops"] == pytest.approx(r2["flops"], rel=0.05)


def test_breakdown_returns_top_entries():
    M = 256

    def f(a, b):
        return jnp.tanh(a @ b)

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32),
    ).compile()
    bd = hlo_graph.breakdown(c.as_text())
    assert bd["by_flops"][0]["flops"] == pytest.approx(2 * M ** 3, rel=0.05)
