"""Megastep driver (``Monitor.scan`` / ``steps_per_commit``): fused K-step
commits equal unrolled single steps exactly, ring snapshots land on true
per-step stamps even when the cadence does not divide K, dynamic knob swaps
apply at the next megastep boundary without a re-trace, ring-epoch resets
mid-run keep draining, and the adaptive ladder's quiet accounting stays
step-denominated under megastep snapshots."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import telemetry as T
from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import MonitorParams


def _spec():
    return MonitorSpec.of([
        ScopeContext.multiplexed("hot", [
            [EventSpec("MEAN", "x")],
            [EventSpec("L2NORM", "x")],
        ]),
        ScopeContext.exhaustive("cold", [EventSpec("ACT_RMS", "x"),
                                         EventSpec("NUMEL", "x")]),
    ])


def _work(x):
    for i in range(4):
        with scalpel.function("hot"):
            scalpel.probe(x=x * (i + 1))
    with scalpel.function("cold"):
        scalpel.probe(x=x + 1)
    return x * 2.0


def _state_equal(a, b):
    assert np.array_equal(np.asarray(a.calls), np.asarray(b.calls))
    assert np.array_equal(np.asarray(a.samples), np.asarray(b.samples))
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-7)
    assert int(a.step) == int(b.step)


# ---------------------------------------------------------------------------
# exactness: one K-step megastep == K unrolled commits
# ---------------------------------------------------------------------------

def test_megastep_counters_match_unrolled():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    K = 6
    mega = mon.jit(_work, steps_per_commit=K)
    single = jax.jit(mon.wrap(_work))

    ms_a = mon.init()
    _, ms_a = mega(ms_a, jnp.ones(8))

    ms_b, x = mon.init(), jnp.ones(8)
    for _ in range(K):
        x, ms_b = single(ms_b, x)

    _state_equal(ms_a, ms_b)
    assert int(ms_a.step) == K
    # the multiplex schedule advanced K x 4 hot calls — the estimates see
    # both event sets of the 2-way multiplexed scope
    est = mon.estimates(ms_a)
    assert np.isfinite(est["hot"]["MEAN:x"])
    assert np.isfinite(est["hot"]["L2NORM:x"])


def test_wrap_steps_per_commit_is_the_scan_driver():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    w4 = mon.wrap(_work, steps_per_commit=4)
    w1 = mon.wrap(_work)

    ms_a = mon.init()
    x_a, ms_a = w4(ms_a, jnp.ones(4))

    ms_b, x_b = mon.init(), jnp.ones(4)
    for _ in range(4):
        x_b, ms_b = w1(ms_b, x_b)

    _state_equal(ms_a, ms_b)
    np.testing.assert_allclose(np.asarray(x_a), np.asarray(x_b))


def test_scan_xs_mode_stacks_ys_and_sets_length():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())

    def body(c, x):
        with scalpel.function("cold"):
            scalpel.probe(x=x)
        return c + jnp.sum(x), c

    mega = mon.scan(body)   # length comes from xs
    xs = jnp.arange(10.0).reshape(5, 2)
    (carry, ys), ms = mega(mon.init(), jnp.zeros(()), xs)
    assert int(ms.step) == 5
    assert ys.shape == (5,)
    assert int(np.asarray(ms.calls)[spec.scope_index("cold")]) == 5


def test_scan_rejects_bad_k():
    mon = scalpel.Monitor(_spec(), counter_axes=())
    with pytest.raises(ValueError):
        mon.scan(lambda c, x: (c, None), steps_per_commit=0)
    mega = mon.scan(lambda c, x: (c, None))   # no K, no xs
    with pytest.raises(ValueError):
        mega(mon.init(), jnp.zeros(()))


# ---------------------------------------------------------------------------
# telemetry: true step stamps when cadence does not divide K
# ---------------------------------------------------------------------------

def test_cadence_not_dividing_k_lands_true_stamps():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=32, cadence=3, interval_s=60.0)
    mon = scalpel.Monitor(spec, telemetry=plane, counter_axes=())
    mega = mon.jit(_work, steps_per_commit=5)   # cadence 3 does not divide 5
    ms = mon.init()
    for _ in range(3):                          # 15 steps
        _, ms = mega(ms, jnp.ones(4))
    plane.publish(ms.ring)
    snaps = plane.flush()
    assert sorted(s.step for s in snaps) == [3, 6, 9, 12, 15]
    # snapshot deltas cover exactly one cadence interval each
    assert all(int(s.delta.calls[spec.scope_index("cold")]) == 3
               for s in snaps)
    plane.close()


def test_ring_epoch_reset_mid_run_keeps_draining():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=16, cadence=2, interval_s=60.0)
    mon = scalpel.Monitor(spec, telemetry=plane, counter_axes=())
    mega = mon.jit(_work, steps_per_commit=5)
    got = []
    plane.add_sink(T.CallbackSink(
        lambda s: got.append((int(s.step),
                              int(s.delta.calls[spec.scope_index("cold")])))))

    ms = mon.init()
    _, ms = mega(ms, jnp.ones(4))
    plane.publish(ms.ring)
    plane.flush()
    assert [s for s, _ in got] == [2, 4]

    # restart the ring lineage mid-run (elastic resume / engine swap):
    # counters carry on, the fresh epoch's head restarts at 0 — the plane
    # must reset its cursor and delta base instead of going silent
    ms = dataclasses.replace(ms, ring=plane.make_ring(compact=True))
    _, ms = mega(ms, jnp.ones(4))
    plane.publish(ms.ring)
    plane.flush()
    steps = [s for s, _ in got]
    assert steps == [2, 4, 6, 8, 10]
    # first post-reset snapshot's delta base is the epoch start: its delta
    # carries the whole cumulative state (6 cold calls), not state - prev
    deltas = dict(got)
    assert deltas[6] == 6 and deltas[8] == 2
    plane.close()


# ---------------------------------------------------------------------------
# dynamic knobs: swaps land at the next megastep boundary, no re-trace
# ---------------------------------------------------------------------------

def test_sync_swap_applies_at_next_megastep_without_retrace():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    traces = []

    def fn(x):
        traces.append(1)
        return _work(x)

    mega = mon.jit(fn, steps_per_commit=4)
    ms = mon.init()
    _, ms = mega(ms, jnp.ones(4))
    samples_on = np.asarray(ms.samples).copy()

    # mask everything off: the swap is a reference swap inside the state,
    # picked up by the NEXT megastep — same compiled program
    ms = mon.sync(ms, params=MonitorParams.all_off(spec))
    _, ms = mega(ms, jnp.ones(4))
    assert len(traces) == 1
    assert mega._cjit._cache_size() == 1
    # all 4 inner steps of the second megastep saw the masked params:
    # calls still count (interception is free) but nothing sampled
    assert np.array_equal(np.asarray(ms.samples), samples_on)
    assert int(ms.step) == 8
    assert int(np.asarray(ms.calls)[spec.scope_index("hot")]) == 32


# ---------------------------------------------------------------------------
# train loop: fit at steps_per_commit=K reproduces single-step training
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fit_megastep_matches_single_step():
    from repro.configs import model_config
    from repro.data import DataConfig
    from repro.models.registry import Arch
    from repro.optim import OptConfig
    from repro.train import TrainLoopConfig, fit

    arch = Arch(model_config("xlstm_125m", smoke=True))
    opt = OptConfig(lr=1e-3, warmup_steps=0)
    data = DataConfig(vocab=256, seq_len=16, global_batch=4)

    def run(k):
        out = fit(arch, opt, data,
                  TrainLoopConfig(steps=5, log_every=0, ckpt_every=0,
                                  steps_per_commit=k))
        return out["losses"]

    base = run(1)
    mega = run(2)   # ragged tail: megasteps of 2, 2, 1 — traces two K's
    assert len(base) == len(mega) == 5
    np.testing.assert_allclose(np.asarray(mega), np.asarray(base),
                               rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# adaptive: ladder patience is step-denominated under megastep snapshots
# ---------------------------------------------------------------------------

def _quiet_work(x):
    with scalpel.function("hot"):
        scalpel.probe(x=jnp.full((8,), 1.5))
    return x


def test_adaptive_quiet_accounting_counts_steps_not_drains():
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("hot", [EventSpec("ACT_RMS", "x"),
                                        EventSpec("NAN_COUNT", "x")]),
    ])
    K = 4
    # cadence == K: each megastep publishes ONE snapshot spanning K steps
    plane = T.TelemetryPlane(spec, depth=32, cadence=K, interval_s=60.0)
    ctl = AdaptiveController(
        spec=spec, telemetry=plane,
        config=AdaptiveConfig(quiet_steps=6, cooldown_steps=1,
                              overhead_budget=1.0),
    ).install()
    mon = scalpel.Monitor(spec, telemetry=plane, counter_axes=())
    mega = mon.jit(_quiet_work, steps_per_commit=K)
    ms = mon.init()
    # 2 megasteps = 8 quiet steps seen as TWO snapshots: step-denominated
    # patience (6 steps) de-escalates via the stamp spans; the old
    # snapshot-counted ladder would sit at quiet=2, four snapshots short
    for _ in range(2):
        ms = mon.sync(ms, controller=ctl)
        _, ms = mega(ms, jnp.ones(4))
        plane.publish(ms.ring)
        plane.flush()
    down = [t for t in ctl.transitions
            if t.frm == "configured" and t.to == "sentinel"]
    assert down and down[0].step <= 2 * K
    assert ctl.stats["drains"] == 2      # one spanning snapshot per megastep
    plane.close()
