"""Wire codec contract (repro.telemetry.wire) — PR 10 satellite.

Covers the acceptance checklist: hypothesis-style round-trip property
tests (via the conftest-registered stub when real hypothesis is absent),
truncated/corrupt-frame rejection, plan-fingerprint mismatch rejection at
the aggregator, version-skew handling, and the stream FrameReader.  Also
attests the module's device-freedom: it must not import jax at all.

Deliberately jax-free and subprocess-free — this file runs in
milliseconds.
"""
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.telemetry import wire
from repro.telemetry.reservoir import Reservoir

FP = "ab" * 20
FP2 = "cd" * 20


def mk_delta(n_scopes=3, total=12, seed=0, **kw):
    rng = np.random.default_rng(seed)
    calls = rng.integers(0, 1000, n_scopes)
    values = rng.normal(size=total).astype(np.float32)
    samples = rng.integers(0, 500, total)
    kw.setdefault("host_id", "h0")
    kw.setdefault("seq", 7)
    kw.setdefault("fingerprint", FP)
    kw.setdefault("step_lo", -1)
    kw.setdefault("step_hi", 42)
    return calls, values, samples, wire.encode_delta(
        calls, values, samples, **kw)


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(
    n_scopes=st.integers(0, 9),
    total=st.integers(0, 64),
    seed=st.integers(0, 10_000),
    seq=st.integers(0, 1 << 40),
    step_lo=st.integers(-1, 1 << 30),
    step_hi=st.integers(0, 1 << 31),
    shutdown=st.booleans(),
    host=st.text(min_size=0, max_size=24),
)
def test_delta_roundtrip_property(n_scopes, total, seed, seq, step_lo,
                                  step_hi, shutdown, host):
    rng = np.random.default_rng(seed)
    calls = rng.integers(0, 1 << 31, n_scopes)
    values = (rng.normal(size=total) * rng.choice(
        [1e-20, 1.0, 1e20])).astype(np.float32)
    samples = rng.integers(0, 1 << 31, total)
    buf = wire.encode_delta(calls, values, samples, host_id=host, seq=seq,
                            fingerprint=FP, step_lo=step_lo,
                            step_hi=step_hi, shutdown=shutdown)
    f = wire.decode_frame(buf)
    assert f.kind == wire.KIND_DELTA
    assert f.host_id == host
    assert f.seq == seq
    assert f.fingerprint == FP
    assert f.step_lo == step_lo and f.step_hi == step_hi
    assert f.shutdown == shutdown
    np.testing.assert_array_equal(f.calls, calls.astype(np.int64))
    np.testing.assert_array_equal(f.samples, samples.astype(np.int64))
    np.testing.assert_array_equal(f.values, values)  # f32 pack is exact


@settings(max_examples=15)
@given(total=st.integers(1, 16), seed=st.integers(0, 1000),
       k=st.integers(1, 8))
def test_agg_roundtrip_property(total, seed, k):
    rng = np.random.default_rng(seed)
    calls = rng.integers(-5, 1 << 40, 4)
    values = rng.normal(size=total).astype(np.float64) * 1e6
    samples = rng.integers(0, 1 << 40, total)
    reservoirs = [
        (int(rng.integers(0, 1000)) + k, rng.normal(size=k).astype(np.float32))
        for _ in range(total)
    ]
    buf = wire.encode_agg(calls, values, samples, reservoirs, host_id="agg0",
                          seq=3, fingerprint=FP, step_lo=-1, step_hi=99,
                          n_hosts=12, frames_in=345, dropped=6)
    f = wire.decode_frame(buf)
    assert f.kind == wire.KIND_AGG
    assert (f.n_hosts, f.frames_in, f.dropped) == (12, 345, 6)
    np.testing.assert_array_equal(f.calls, calls)
    np.testing.assert_array_equal(f.values, values)  # f64 pack is exact
    np.testing.assert_array_equal(f.samples, samples)
    assert len(f.reservoirs) == total
    for (seen, items), (dseen, ditems) in zip(reservoirs, f.reservoirs):
        assert dseen == seen
        np.testing.assert_array_equal(ditems, items)


def test_hint_roundtrip():
    buf = wire.encode_hint("layer/attn", "fleet:nan_count", host_id="head",
                           seq=1, tripwire=True)
    f = wire.decode_frame(buf)
    assert f.kind == wire.KIND_HINT
    assert (f.scope, f.reason, f.tripwire) == (
        "layer/attn", "fleet:nan_count", True)
    # empty scope = global hint
    g = wire.decode_frame(wire.encode_hint("", "wake", host_id="head", seq=2))
    assert g.scope == "" and g.tripwire is False


def test_empty_fingerprint_encodes_zero_fp():
    _, _, _, buf = mk_delta(fingerprint="")
    assert wire.decode_frame(buf).fingerprint == wire._ZERO_FP


def test_bad_fingerprint_rejected_at_encode():
    with pytest.raises(ValueError, match="hex"):
        mk_delta(fingerprint="zz" * 20)
    with pytest.raises(ValueError, match="20 bytes"):
        mk_delta(fingerprint="ab" * 10)


# ---------------------------------------------------------------------------
# rejection: truncation, corruption, version skew
# ---------------------------------------------------------------------------

def test_truncated_frames_rejected_at_every_length():
    _, _, _, buf = mk_delta()
    for n in range(len(buf)):
        with pytest.raises(wire.WireError):
            wire.decode_frame(buf[:n])


def test_corrupt_byte_rejected_everywhere():
    _, _, _, buf = mk_delta()
    # flip every byte position (except the version byte — that's skew)
    for i in range(len(buf)):
        if i == 2:
            continue
        bad = bytearray(buf)
        bad[i] ^= 0xFF
        with pytest.raises(wire.WireError):
            wire.decode_frame(bytes(bad))


def test_bad_magic_is_corrupt():
    _, _, _, buf = mk_delta()
    with pytest.raises(wire.CorruptFrameError, match="magic"):
        wire.decode_frame(b"XX" + buf[2:])


def test_crc_catches_payload_tamper():
    _, _, _, buf = mk_delta()
    bad = bytearray(buf)
    bad[-6] ^= 0x01         # inside payload, before the crc tail
    with pytest.raises(wire.CorruptFrameError, match="CRC"):
        wire.decode_frame(bytes(bad))


def test_version_skew_detected_before_crc():
    """A future sender bumps the version: the decoder must say SKEW (an
    actionable, accounted condition), not CRC corruption."""
    _, _, _, buf = mk_delta()
    bad = bytearray(buf)
    bad[2] = wire.WIRE_VERSION + 1
    with pytest.raises(wire.VersionSkewError, match="version"):
        wire.decode_frame(bytes(bad))


def test_trailing_garbage_rejected():
    _, _, _, buf = mk_delta()
    with pytest.raises(wire.WireError):
        wire.decode_frame(buf + b"\x00")


def test_varint_guards():
    out = bytearray()
    with pytest.raises(ValueError):
        wire._put_uvarint(out, -1)
    # >64-bit varint is corrupt, not an infinite loop
    with pytest.raises(wire.CorruptFrameError):
        wire._get_uvarint(b"\xff" * 11, 0)


def test_zigzag_symmetry():
    for v in (0, 1, -1, 2**62, -(2**62), 12345, -54321):
        assert wire._unzigzag(wire._zigzag(v)) == v


# ---------------------------------------------------------------------------
# stream framing
# ---------------------------------------------------------------------------

@settings(max_examples=10)
@given(chunk=st.integers(1, 13), n_frames=st.integers(1, 6),
       seed=st.integers(0, 999))
def test_frame_reader_reassembles_any_chunking(chunk, n_frames, seed):
    frames = [mk_delta(seed=seed + i, seq=i)[3] for i in range(n_frames)]
    stream = b"".join(wire.pack_frame(f) for f in frames)
    reader = wire.FrameReader()
    got = []
    for i in range(0, len(stream), chunk):
        reader.feed(stream[i:i + chunk])
        got.extend(reader.frames())
    assert [f.seq for f in got] == list(range(n_frames))
    assert reader.pending_bytes == 0


def test_frame_reader_leaves_partial_buffered():
    buf = wire.pack_frame(mk_delta()[3])
    reader = wire.FrameReader()
    reader.feed(buf[:-1])
    assert list(reader.frames()) == []
    assert reader.pending_bytes == len(buf) - 1
    reader.feed(buf[-1:])
    assert len(list(reader.frames())) == 1


def test_frame_reader_length_cap():
    reader = wire.FrameReader()
    reader.feed(b"\xff\xff\xff\xff")
    with pytest.raises(wire.CorruptFrameError, match="cap"):
        list(reader.frames())


def test_pack_frame_size_cap():
    with pytest.raises(ValueError, match="too large"):
        wire.pack_frame(b"x" * (wire.MAX_FRAME_BYTES + 1))


# ---------------------------------------------------------------------------
# fingerprint mismatch rejection (aggregator policy)
# ---------------------------------------------------------------------------

def test_aggregator_rejects_fingerprint_mismatch():
    from repro.telemetry.aggregator import Aggregator

    agg = Aggregator(node_id="t")
    ok = agg.ingest(wire.decode_frame(mk_delta(fingerprint=FP, seq=0)[3]))
    assert ok
    bad = agg.ingest(wire.decode_frame(mk_delta(fingerprint=FP2, seq=0,
                                                host_id="h1")[3]))
    assert not bad
    st_ = agg.stats()
    assert st_["rejected_fingerprint"] == 1
    assert st_["frames_in"] == 1
    assert agg.dropped == 1
    # zero (control) fingerprint is always accepted — pure-shutdown agents
    zero = wire.encode_delta([], [], [], host_id="h2", seq=0,
                             fingerprint="", step_lo=-1, step_hi=-1,
                             shutdown=True)
    assert agg.ingest(wire.decode_frame(zero))


def test_aggregator_counts_seq_gaps_as_lost():
    from repro.telemetry.aggregator import Aggregator

    agg = Aggregator(node_id="t")
    for seq in (0, 1, 4, 9):       # gaps: 2,3 then 5..8 -> 6 lost
        agg.ingest(wire.decode_frame(mk_delta(seq=seq)[3]))
    assert agg.stats()["lost_frames"] == 6
    assert agg.merged().dropped == 6


# ---------------------------------------------------------------------------
# reservoir (percentile substrate)
# ---------------------------------------------------------------------------

def test_reservoir_exact_below_capacity():
    r = Reservoir(64, np.random.default_rng(0))
    xs = list(range(50))
    for x in xs:
        r.add(x)
    assert len(r) == 50 and r.seen == 50
    assert r.percentile(50) == pytest.approx(np.percentile(xs, 50))


def test_reservoir_merge_exact_when_fits():
    a = Reservoir(100, np.random.default_rng(1))
    for x in range(40):
        a.add(float(x))
    a.merge(np.arange(40, 80, dtype=np.float32), 40)
    assert len(a) == 80 and a.seen == 80
    assert a.percentile(99) == pytest.approx(
        np.percentile(np.arange(80), 99), rel=1e-6)


def test_reservoir_subsamples_at_capacity():
    r = Reservoir(32, np.random.default_rng(2))
    for x in range(1000):
        r.add(float(x))
    assert len(r) == 32 and r.seen == 1000
    # a uniform sample of 0..999: the median estimate can't be wildly off
    assert 150 < r.percentile(50) < 850


def test_reservoir_merge_weights_by_seen():
    # side A: 10 items standing for 1000 observations around 100;
    # side B: 10 items standing for 10 observations around 0.
    # the merged sample must be dominated by A.
    r = Reservoir(16, np.random.default_rng(3))
    for _ in range(3):
        r.merge(np.full(10, 100.0, np.float32), 1000)
        r.merge(np.zeros(10, np.float32), 10)
    assert r.percentile(50) == pytest.approx(100.0)
    assert r.seen == 3030


def test_reservoir_empty_and_errors():
    r = Reservoir(4)
    assert np.isnan(r.percentile(50))
    with pytest.raises(ValueError):
        Reservoir(0)
    with pytest.raises(ValueError, match="seen"):
        r.merge([1.0, 2.0], 1)


# ---------------------------------------------------------------------------
# device-freedom attestation (module level)
# ---------------------------------------------------------------------------

def test_wire_and_agent_modules_are_jax_free():
    """The codec and agent run on drain/IO threads — they must not even
    import jax (the raising-guard runtime attestation lives in
    test_fleet_agg.py; this is the static half)."""
    import repro.telemetry.agent as agent_mod

    for mod in (wire, agent_mod):
        assert not hasattr(mod, "jnp"), mod
        assert not hasattr(mod, "jax"), mod
    src = open(wire.__file__).read() + open(agent_mod.__file__).read()
    assert "import jax" not in src


def test_importing_telemetry_package_does_not_import_jax():
    import subprocess
    import sys as _sys

    out = subprocess.run(
        [_sys.executable, "-c",
         "import sys; import repro.telemetry; "
         "print('jax' in sys.modules)"],
        capture_output=True, text=True,
        env={"PYTHONPATH": ":".join(_sys.path)}, timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "False"
