"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-1}


# ---------------------------------------------------------------------------
# GEMM: both schedules, shape x dtype sweep
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (128, 128, 128),
    (256, 128, 384),
    (512, 256, 128),
    (128, 512, 256),
]


@pytest.mark.parametrize("schedule", ops.SCHEDULES)
@pytest.mark.parametrize("m,n,k", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_allclose(schedule, m, n, k, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    want = ref.matmul(a, b)
    got = ops.matmul(a, b, schedule, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=TOL[dtype] * np.sqrt(k),
        rtol=TOL[dtype],
    )


def test_gemm_schedules_agree():
    a = jax.random.normal(jax.random.PRNGKey(2), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(3), (256, 256), jnp.float32)
    c1 = ops.matmul(a, b, "cache_blocked", bm=128, bn=128, bk=128)
    c2 = ops.matmul(a, b, "panel_streaming", bm=128, bn=128)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=2e-4)


def test_gemm_cost_model_properties():
    """The case-study claim: identical FLOPs, different memory traffic."""
    m = n = k = 2048
    cb = ops.matmul_cost("cache_blocked", m, n, k, bm=256, bn=256, bk=256)
    ps = ops.matmul_cost("panel_streaming", m, n, k, bm=256, bn=256)
    assert cb["FLOPS"] == ps["FLOPS"] == 2.0 * m * n * k
    # panel streaming reads A exactly once; cache_blocked refetches it
    assert ps["HBM_BYTES"] < cb["HBM_BYTES"]
    assert ps["VMEM_TILE_REFILLS"] < cb["VMEM_TILE_REFILLS"]
    # but its VMEM working set is larger (the Goto trade-off)
    assert ps["vmem_working_set_bytes"] > cb["vmem_working_set_bytes"]
    assert ps["arithmetic_intensity"] > cb["arithmetic_intensity"]


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([128, 256, 384]),
    st.sampled_from([128, 256]),
    st.sampled_from([128, 384]),
)
def test_gemm_property_any_blocking(m, n, k):
    """Property: every legal blocking yields the same product."""
    a = jax.random.normal(jax.random.PRNGKey(4), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (k, n), jnp.float32)
    want = np.asarray(ref.matmul(a, b))
    got = ops.matmul(a, b, "cache_blocked", bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(got), want, atol=5e-4)


# ---------------------------------------------------------------------------
# flash attention sweep
# ---------------------------------------------------------------------------

FA_CASES = [
    # b, sq, sk, h, kvh, d, causal, window
    (2, 128, 128, 4, 4, 64, True, 0),
    (2, 128, 128, 4, 2, 64, True, 0),      # GQA
    (1, 256, 256, 2, 1, 32, True, 0),      # MQA
    (1, 128, 384, 2, 2, 64, True, 0),      # kv prefix (prefill-with-cache)
    (2, 128, 128, 4, 4, 64, False, 0),     # bidirectional (encoder)
    (1, 256, 256, 2, 2, 64, True, 64),     # sliding window
    (1, 64, 192, 1, 1, 128, True, 0),      # single head, tall kv
]


@pytest.mark.parametrize("b,sq,sk,h,kvh,d,causal,win", FA_CASES)
def test_flash_attention_allclose(b, sq, sk, h, kvh, d, causal, win):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, kvh, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, kvh, d), jnp.float32)
    kr = jnp.repeat(k, h // kvh, axis=2)
    vr = jnp.repeat(v, h // kvh, axis=2)
    want = ref.attention(q, kr, vr, causal=causal, window=win)
    got = ops.flash_attention(q, k, v, causal=causal, window=win,
                              block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    b, s, h, d = 1, 128, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d), dtype)
    want = ref.attention(q, k, v, causal=True)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_attention_block_shape_invariance():
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d), jnp.float32)
    outs = [
        ops.flash_attention(q, k, v, block_q=bq, block_kv=bkv)
        for bq, bkv in [(64, 64), (128, 64), (64, 128), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(outs[0]), atol=2e-5
        )


def test_flash_attention_cost_causal_skip():
    c = ops.flash_attention_cost(1, 1024, 1024, 1, 64, causal=True,
                                 block_q=128, block_kv=128)
    full = ops.flash_attention_cost(1, 1024, 1024, 1, 64, causal=False,
                                    block_q=128, block_kv=128)
    assert c["live_tiles"] == 8 * 9 // 2      # lower triangle of 8x8
    assert full["live_tiles"] == 64
    assert c["FLOPS"] < full["FLOPS"]


# ---------------------------------------------------------------------------
# chunked SSM scan sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,chunk,bd", [
    (1, 128, 32, 32, 32),
    (2, 256, 64, 64, 32),
    (2, 512, 96, 128, 96),
    (1, 1024, 16, 256, 16),
])
def test_ssm_scan_allclose(B, S, D, chunk, bd):
    la = -jnp.abs(
        jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    ) * 0.3
    bb = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    want = ref.ssm_scan(None, la, bb)
    got = ops.ssm_scan(la, bb, chunk=chunk, bd=bd)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4
    )


def test_ssm_scan_chunk_invariance():
    B, S, D = 1, 256, 32
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (B, S, D))) * 0.5
    bb = jax.random.normal(jax.random.PRNGKey(3), (B, S, D))
    o1 = ops.ssm_scan(la, bb, chunk=64, bd=32)
    o2 = ops.ssm_scan(la, bb, chunk=256, bd=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def test_ssm_scan_decay_identity():
    """log_a = -inf-ish -> h_t == b_t; log_a = 0 -> h_t = cumsum(b)."""
    B, S, D = 1, 64, 8
    bb = jax.random.normal(jax.random.PRNGKey(4), (B, S, D))
    h_dead = ops.ssm_scan(jnp.full((B, S, D), -40.0), bb, chunk=32, bd=8)
    np.testing.assert_allclose(np.asarray(h_dead), np.asarray(bb), atol=1e-5)
    h_int = ops.ssm_scan(jnp.zeros((B, S, D)), bb, chunk=32, bd=8)
    np.testing.assert_allclose(
        np.asarray(h_int), np.cumsum(np.asarray(bb), axis=1), atol=1e-4
    )
