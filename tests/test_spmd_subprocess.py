"""SPMD semantics under a real (host-device) mesh, run in a subprocess so
the 8-device XLA flag never leaks into the other tests' 1-device world.

Checks:
  * sharded train step runs under a (2,4) ("data","model") mesh,
  * counters are replicated and call counts match the unsharded run,
  * loss matches the single-device run (SPMD correctness),
  * elastic re-mesh: a checkpoint saved under (2,4) restores under (4,2).
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import core as scalpel
from repro.configs import model_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.partition import sharding_ctx, tree_shardings
from repro.models.registry import Arch
from repro.optim import OptConfig, init_opt_state, opt_state_axes
from repro.train.step import TrainState, build_monitor_spec, make_train_step
from repro.checkpoint.manager import save_tree, restore_tree

assert len(jax.devices()) == 8

cfg = model_config("qwen3_14b", smoke=True).replace(remat="none")
arch = Arch(cfg)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
host_batch = data.batch_at(0)
batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
spec = build_monitor_spec(arch, batch)
opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, min_lr_frac=1.0)

# ---- single-device reference ----
mon1 = scalpel.Monitor(spec)
t0 = TrainState.create(arch, opt_cfg, jax.random.PRNGKey(0))
step1 = jax.jit(make_train_step(arch, opt_cfg, spec, monitor=mon1))
t1, o1, m1 = step1(t0, batch, mon1.init())
ref_loss = float(o1["loss"])
ref_calls = np.asarray(m1.calls).copy()

# ---- sharded run under (2,4) ----
# jit-SPMD: reductions over sharded tensors are already global, so the
# Monitor's "auto" counter reduction resolves to a no-op (no bound axes)
# and counters stay replicated — asserted equal to the unsharded run.
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh, sharding_ctx(mesh):
    params = arch.init(jax.random.PRNGKey(0))
    params = jax.device_put(
        params, tree_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         params),
            arch.param_axes(), mesh))
    tstate = TrainState(
        params=params,
        opt=init_opt_state(opt_cfg, params),
        step=jnp.zeros((), jnp.int32),
    )
    sb = {k: jax.device_put(
        v, NamedSharding(mesh, PartitionSpec("data"))) for k, v in
        batch.items()}
    monN = scalpel.Monitor(spec)
    stepN = jax.jit(make_train_step(arch, opt_cfg, spec, monitor=monN))
    t2, o2, m2 = stepN(tstate, sb, monN.init())
    spmd_loss = float(o2["loss"])
    spmd_calls = np.asarray(m2.calls).copy()

    # ---- elastic re-mesh: save under (2,4), restore under (4,2) ----
    save_tree("/tmp/spmd_ck.npz", t2.params)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
with mesh2, sharding_ctx(mesh2):
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t2.params)
    back = restore_tree("/tmp/spmd_ck.npz", like, mesh=mesh2,
                        axes=arch.param_axes())
    ok_elastic = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(t2.params), jax.tree.leaves(back))
    )

print(json.dumps({
    "ref_loss": ref_loss,
    "spmd_loss": spmd_loss,
    "calls_match": bool((ref_calls == spmd_calls).all()),
    "elastic_ok": bool(ok_elastic),
}))
"""


@pytest.mark.slow
def test_spmd_8dev_subprocess(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["calls_match"], res
    assert res["elastic_ok"], res
    assert abs(res["ref_loss"] - res["spmd_loss"]) < 5e-2, res
