"""Data pipeline determinism + checkpoint atomicity/restart/elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import restore_tree, save_tree
from repro.data import DataConfig, SyntheticLM, prefetch, shard_batch


def _dc(**kw):
    return DataConfig(vocab=512, seq_len=64, global_batch=4, **kw)


def test_data_deterministic_in_seed_step():
    d1 = SyntheticLM(_dc(seed=7))
    d2 = SyntheticLM(_dc(seed=7))
    b1, b2 = d1.batch_at(13), d2.batch_at(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(
        d1.batch_at(14)["tokens"], b1["tokens"]
    )


def test_data_seed_changes_stream():
    a = SyntheticLM(_dc(seed=0)).batch_at(0)
    b = SyntheticLM(_dc(seed=1)).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_targets_shifted_and_docs_bounded():
    d = SyntheticLM(_dc())
    b = d.batch_at(0)
    assert b["tokens"].shape == (4, 64)
    assert b["targets"].shape == (4, 64)
    flat_t = np.concatenate(
        [b["tokens"], b["targets"][:, -1:]], axis=1
    ).reshape(-1)
    # targets are the next-token shift of the same stream
    np.testing.assert_array_equal(
        b["targets"][:, :-1], b["tokens"][:, 1:]
    )
    assert (flat_t < 512).all() and (flat_t >= 0).all()
    # EOS tokens exist somewhere in a long enough sample
    long = SyntheticLM(_dc(mean_doc_len=32)).batch_at(0)
    assert (long["tokens"] == 0).any()


def test_prefetch_preserves_order():
    it = prefetch(iter(range(20)), depth=3)
    assert list(it) == list(range(20))


def test_shard_batch_no_mesh_is_asarray():
    b = shard_batch({"x": np.ones((2, 2), np.int32)})
    assert isinstance(b["x"], jax.Array)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(x=1.0):
    return {
        "params": {"w": jnp.full((4, 4), x), "b": jnp.arange(3.0)},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.ones((2,)), jnp.zeros((1,), jnp.bfloat16)],
    }


def test_save_restore_bitwise(tmp_path):
    path = str(tmp_path / "ck.npz")
    t = _tree(3.5)
    save_tree(path, t, extra={"step": 7})
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
    )
    back = restore_tree(path, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_restore_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_tree(path, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError, match="shape"):
        restore_tree(path, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_manager_latest_keep_k_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    assert mgr.latest() is None
    for s in [10, 20, 30, 40]:
        mgr.save(s, _tree(float(s)), block=True)
    assert mgr.steps() == [30, 40]  # keep-2 GC
    assert mgr.latest() == 40
    # no tmp dirs left behind (atomic rename)
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree()
    )
    t, meta = mgr.restore(40, like)
    assert meta["step"] == 40
    assert float(t["params"]["w"][0, 0]) == 40.0


def test_manager_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(5, _tree(5.0))
    mgr.wait()
    assert mgr.latest() == 5


def test_crash_recovery_discovers_latest_valid(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree(1.0), block=True)
    mgr.save(2, _tree(2.0), block=True)
    # simulate a crash mid-write: a stale tmp dir must be ignored
    os.makedirs(tmp_path / "tmp.3.999", exist_ok=True)
    # and a corrupt (empty) step dir must be ignored by discovery
    os.makedirs(tmp_path / "step_9", exist_ok=True)
    assert mgr.latest() == 2


def test_elastic_restore_into_mesh(tmp_path):
    """Checkpoints restore under any mesh (1-device here) via logical axes."""
    from repro.dist.partition import sharding_ctx

    mesh = jax.make_mesh((1,), ("data",))
    path = str(tmp_path / "ck.npz")
    tree = {"w": jnp.ones((8, 4))}
    save_tree(path, tree)
    like = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    axes = {"w": ("embed", "mlp")}
    with sharding_ctx(mesh):
        back = restore_tree(path, like, mesh=mesh, axes=axes)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.ones((8, 4)))
    assert back["w"].sharding.mesh.shape == {"data": 1}
