"""Runtime (SIGUSR1 reload, snapshots, hooks) + report estimates.

The report tests encode the paper's Fig. 4 methodology: a call-count
multiplexed run must reconstruct the exhaustive counters within sampling
error (EXTENSIVE events scaled by calls/samples; INTENSIVE as per-call mean).
"""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import report as report_lib
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams


def _spec():
    return MonitorSpec.of([
        ScopeContext.multiplexed(
            "f",
            [[EventSpec("NUMEL", "x")], [EventSpec("MEAN", "x")]],
            period=3,
        ),
        ScopeContext.exhaustive("g", [EventSpec("MEAN", "x")]),
    ])


def _run(spec, params, state, values):
    with scalpel.collecting(spec, params, state) as col:
        for v in values:
            with scalpel.function("f"):
                scalpel.probe(x=jnp.full((4,), v))
            with scalpel.function("g"):
                scalpel.probe(x=jnp.full((2,), v))
    return state.add(col.delta)


def test_extensive_estimate_scales_to_exhaustive():
    spec = _spec()
    state = _run(spec, MonitorParams.all_on(spec), CounterState.zeros(spec),
                 [1.0] * 12)
    reports = {r.scope: r for r in report_lib.build(spec, state)}
    f = {s.slot_id: s for s in reports["f"].slots}
    # NUMEL is extensive: sampled on 6 of 12 calls, 4 elements each ->
    # raw 24, estimate 48 (the exhaustive total)
    assert f["NUMEL:x"].samples == 6
    assert f["NUMEL:x"].raw == pytest.approx(24.0)
    assert f["NUMEL:x"].estimate == pytest.approx(48.0)
    assert f["NUMEL:x"].coverage == pytest.approx(0.5)


def test_intensive_estimate_is_per_call_mean():
    spec = _spec()
    vals = [float(i) for i in range(12)]
    state = _run(spec, MonitorParams.all_on(spec), CounterState.zeros(spec),
                 vals)
    reports = {r.scope: r for r in report_lib.build(spec, state)}
    f = {s.slot_id: s for s in reports["f"].slots}
    # MEAN sampled on calls 3,4,5,9,10,11 (period 3, set 1)
    sampled = [vals[c] for c in [3, 4, 5, 9, 10, 11]]
    assert f["MEAN:x"].estimate == pytest.approx(np.mean(sampled), rel=1e-6)
    g = {s.slot_id: s for s in reports["g"].slots}
    assert g["MEAN:x"].estimate == pytest.approx(np.mean(vals), rel=1e-6)


def test_multiplexed_vs_exhaustive_error_marginal():
    """Paper Fig. 4: sampling error of call-count multiplexing is marginal
    for stationary-ish workloads."""
    rng = np.random.default_rng(0)
    vals = rng.normal(5.0, 0.3, size=200).tolist()
    spec = _spec()
    mux = _run(spec, MonitorParams.all_on(spec), CounterState.zeros(spec),
               vals)
    est = report_lib.estimates(spec, mux)
    exhaustive = np.mean(vals)
    assert est["f"]["MEAN:x"] == pytest.approx(exhaustive, rel=0.02)
    assert est["f"]["NUMEL:x"] == pytest.approx(4 * 200, rel=0.02)


def test_unsampled_slot_reports_nan():
    spec = _spec()
    state = _run(spec, MonitorParams.all_on(spec), CounterState.zeros(spec),
                 [1.0, 1.0])  # only set 0 ever active (period 3)
    reports = {r.scope: r for r in report_lib.build(spec, state)}
    f = {s.slot_id: s for s in reports["f"].slots}
    assert np.isnan(f["MEAN:x"].estimate)


def test_report_text_and_json_roundtrip(tmp_path):
    spec = _spec()
    state = _run(spec, MonitorParams.all_on(spec), CounterState.zeros(spec),
                 [2.0] * 6)
    reports = report_lib.build(spec, state)
    text = report_lib.format_text(reports)
    assert "[f] calls=6" in text and "NUMEL:x" in text
    js = report_lib.to_json(reports)
    assert "estimate" in js
    p = tmp_path / "log.jsonl"
    report_lib.write_jsonl(str(p), 7, reports)
    import json

    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert lines[0]["step"] == 7 and len(lines) == 2


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

CONFIG_A = """
BINARY=test
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=f
NO_EVENTS=0
[/FUNCTION]
"""

CONFIG_B = """
BINARY=test
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=g
NO_EVENTS=0
[/FUNCTION]
"""


def test_runtime_reload_swaps_masks_without_retrace(tmp_path):
    spec = _spec()
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(CONFIG_A)
    rt = scalpel.ScalpelRuntime(spec, config_path=str(cfgp))
    fi, gi = spec.scope_index("f"), spec.scope_index("g")
    assert float(rt.params.scope_mask[fi]) == 1.0
    assert float(rt.params.scope_mask[gi]) == 0.0

    traces = []

    @jax.jit
    def step(state, params):
        traces.append(1)
        with scalpel.collecting(spec, params, state) as col:
            with scalpel.function("f"):
                scalpel.probe(x=jnp.ones(3))
            with scalpel.function("g"):
                scalpel.probe(x=jnp.ones(3))
        return state.add(col.delta)

    s = CounterState.zeros(spec)
    s = step(s, rt.params)
    cfgp.write_text(CONFIG_B)
    rt.reload()
    assert rt.reload_count == 1
    assert float(rt.params.scope_mask[fi]) == 0.0
    assert float(rt.params.scope_mask[gi]) == 1.0
    s = step(s, rt.params)
    assert len(traces) == 1  # reload is a data swap, not a re-trace
    assert int(s.samples[gi, 0]) == 1


@pytest.mark.skipif(not hasattr(signal, "SIGUSR1"), reason="no SIGUSR1")
def test_runtime_sigusr1_reload(tmp_path):
    spec = _spec()
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(CONFIG_A)
    rt = scalpel.ScalpelRuntime(spec, config_path=str(cfgp),
                                install_signal=True)
    cfgp.write_text(CONFIG_B)
    os.kill(os.getpid(), signal.SIGUSR1)
    assert rt.reload_count == 1
    assert float(rt.params.scope_mask[spec.scope_index("g")]) == 1.0


def test_runtime_hooks_and_snapshot():
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec, hook_every=2)
    seen = []
    rt.add_hook(lambda r, reports: seen.append(reports))
    state = _run(spec, rt.params, CounterState.zeros(spec), [1.0, 2.0])
    rt.on_step(state)   # step 1: below cadence, no ring write
    rt.on_step(state)   # step 2: ring write -> hook on drained snapshot
    rt.flush()          # hooks run asynchronously on the drain thread
    assert len(seen) == 1
    assert seen[0][0].scope == "f"
    est = rt.estimates()
    assert "f" in est and "g" in est
    rt.close()


def test_runtime_unsatisfiable_config_reported(tmp_path):
    spec = _spec()
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(
        "NO_FUNCTIONS=1\n[FUNCTION]\nFUNC_NAME=nope\nNO_EVENTS=0\n"
        "[/FUNCTION]\n"
    )
    rt = scalpel.ScalpelRuntime(spec, config_path=str(cfgp))
    assert rt.last_reload_errors == ["scope:nope"]


def test_time_block_accumulates():
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec)
    with rt.time_block("io"):
        pass
    with rt.time_block("io"):
        pass
    assert rt.wall_times["io"] >= 0.0
