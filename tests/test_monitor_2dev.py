"""Mesh-aware counter reduction under a REAL 2-device (forced host) world,
in a subprocess so the XLA device-count flag never leaks into the other
tests' 1-device environment.

The acceptance contract of the Monitor redesign:
  * a ``shard_wrap``-ped step on a ("data",)-mesh psums its counter delta
    in-graph — the carried MonitorState holds counters EXACTLY equal to the
    sum of two independent per-shard manual runs (cluster-wide sums, the
    paper's MPI support living in the transport);
  * the same wrapped function runs unchanged under plain jit on the same
    mesh (no bound axis -> the reduction melts away, jit-SPMD semantics are
    already global);
  * the wrapped train step from train/step.py behaves the same way.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import core as scalpel
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.dist.partition import sharding_ctx

assert len(jax.devices()) == 2

spec = MonitorSpec.of([
    ScopeContext.exhaustive("hot", [EventSpec("MEAN", "x"),
                                    EventSpec("NUMEL", "x"),
                                    EventSpec("ACT_MAX_ABS", "x")]),
])


def work(x):
    with scalpel.function("hot"):
        x = x * 1.5
        scalpel.probe(x=x)
    return x


x = jnp.arange(16.0)
mesh = jax.make_mesh((2,), ("data",))

# ---- shard_map: per-shard collection, in-graph psum --------------------
mon = scalpel.Monitor(spec)
with sharding_ctx(mesh):
    step = jax.jit(mon.shard_wrap(work, mesh, in_specs=P("data"),
                                  out_specs=P("data")))
    out, ms = step(mon.init(), x)

# ---- per-shard manual baseline summed on the host ----------------------
ref = scalpel.Monitor(spec, counter_axes=())
w1 = ref.wrap(work)
a = ref.init()
b = ref.init()
_, a = w1(a, x[:8])
_, b = w1(b, x[8:])
sum_calls = np.asarray(a.calls) + np.asarray(b.calls)
sum_values = np.asarray(a.values) + np.asarray(b.values)
sum_samples = np.asarray(a.samples) + np.asarray(b.samples)

psum_equal = bool(
    np.array_equal(np.asarray(ms.calls), sum_calls)
    and np.array_equal(np.asarray(ms.values), sum_values)
    and np.array_equal(np.asarray(ms.samples), sum_samples)
)

# ---- multiplexed scope: the schedule follows PER-SHARD calls -----------
# (feeding the psum-reduced totals back as the schedule base would advance
# the set index by 2 per call here and never sample set 1 again)
mspec = MonitorSpec.of([
    ScopeContext.multiplexed("mux", [
        [EventSpec("MEAN", "x")],
        [EventSpec("NUMEL", "x")],
    ]),
])


def mwork(x):
    with scalpel.function("mux"):
        scalpel.probe(x=x)
    return x


mmon = scalpel.Monitor(mspec)
with sharding_ctx(mesh):
    mstep = jax.jit(mmon.shard_wrap(mwork, mesh, in_specs=P("data"),
                                    out_specs=P("data")))
    mms = mmon.init()
    for _ in range(4):
        _, mms = mstep(mms, x)
# 4 calls alternate sets 0,1,0,1 on EVERY shard: each set sampled twice
# per shard -> psum-reduced samples [4, 4]; sched_calls stays per-shard.
mux_schedule_ok = bool(
    np.asarray(mms.samples).tolist() == [4, 4]
    and np.asarray(mms.calls).tolist() == [8]       # cluster-wide total
    and np.asarray(mms.sched_calls).tolist() == [4]  # per-shard base
)

# ---- megastep under shard_map: per-shard schedule advances K x ---------
# ONE K=4 megastep call must land exactly where the 4 unrolled calls above
# did: counters psum-exact, sched_calls still the PER-SHARD base (feeding
# the reduced totals through the scan carry would advance the set index 2x
# per inner step and skip set 1 on every shard).
from jax.experimental.shard_map import shard_map as _shard_map

mm2 = scalpel.Monitor(mspec, counter_axes=("data",))
mega = mm2.wrap(mwork, steps_per_commit=4)
smega = jax.jit(_shard_map(
    mega, mesh=mesh, in_specs=(P(), P("data")), out_specs=(P("data"), P()),
    check_rep=False,
))
_, mega_ms = smega(mm2.init(), x)
mega_mux_ok = bool(
    np.asarray(mega_ms.samples).tolist() == [4, 4]
    and np.asarray(mega_ms.calls).tolist() == [8]
    and np.asarray(mega_ms.sched_calls).tolist() == [4]
    and int(mega_ms.step) == 4
    and np.allclose(np.asarray(mega_ms.values), np.asarray(mms.values),
                    rtol=1e-6, atol=1e-8)
)

# ---- plain jit on the same mesh: reduction melts away ------------------
with sharding_ctx(mesh):
    jstep = jax.jit(mon.wrap(work))
    _, msj = jstep(mon.init(), x)
# jit-SPMD semantics are global: one call, MEAN over the full array
jit_ok = bool(
    int(msj.calls[0]) == 1
    and float(msj.values[1]) == 16.0     # NUMEL of the global tensor
)

# ---- the real train step under shard_map -------------------------------
from repro.configs import model_config
from repro.data import DataConfig, SyntheticLM
from repro.models.registry import Arch
from repro.optim import OptConfig
from repro.train.step import TrainState, build_monitor_spec, make_train_step

arch = Arch(model_config("xlstm_125m", smoke=True))
data = SyntheticLM(DataConfig(vocab=256, seq_len=16, global_batch=4))
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
tspec = build_monitor_spec(arch, batch)
opt = OptConfig(lr=1e-3, warmup_steps=0)

tmon = scalpel.Monitor(tspec, counter_axes=("data",))
tstep = make_train_step(arch, opt, tspec, monitor=tmon)
t0 = TrainState.create(arch, opt, jax.random.PRNGKey(0))

from jax.experimental.shard_map import shard_map

# NB: out_specs claims replication for tstate (per-shard grads are NOT
# psum-ed here — this exercise is about the counters, which ARE)
smap = shard_map(
    tstep, mesh=mesh,
    in_specs=(P(), {"tokens": P("data"), "targets": P("data")}, P()),
    out_specs=(P(), P(), P()),
    check_rep=False,
)
# no ambient sharding_ctx here: inside shard_map the model's logical-axis
# constraints would name manual axes (counter_axes is explicit instead)
t1, o1, m1 = jax.jit(smap)(t0, batch, tmon.init())

# per-shard baseline: run each half-batch separately and sum counters
rmon = scalpel.Monitor(tspec, counter_axes=())
rstep = make_train_step(arch, opt, tspec, monitor=rmon)
half = {k: v[:2] for k, v in batch.items()}, {k: v[2:] for k, v in batch.items()}
ca = rstep(t0, half[0], rmon.init())[2]
cb = rstep(t0, half[1], rmon.init())[2]
train_calls_equal = bool(np.array_equal(
    np.asarray(m1.calls), np.asarray(ca.calls) + np.asarray(cb.calls)
))
train_values_close = bool(np.allclose(
    np.asarray(m1.values), np.asarray(ca.values) + np.asarray(cb.values),
    rtol=1e-4, atol=1e-5,
))

print(json.dumps({
    "psum_equal": psum_equal,
    "mux_schedule_ok": mux_schedule_ok,
    "mega_mux_ok": mega_mux_ok,
    "jit_ok": jit_ok,
    "train_calls_equal": train_calls_equal,
    "train_values_close": train_values_close,
    "psum_calls": np.asarray(ms.calls).tolist(),
    "shard_sum_calls": sum_calls.tolist(),
}))
"""


@pytest.mark.slow
def test_monitor_psum_2dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["psum_equal"], res
    assert res["mux_schedule_ok"], res
    assert res["mega_mux_ok"], res
    assert res["jit_ok"], res
    assert res["train_calls_equal"], res
    assert res["train_values_close"], res
