"""Config-file grammar tests (paper Table 1) incl. hypothesis roundtrip."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import config_file as cf
from repro.core.context import EventSpec, MonitorSpec, ScopeContext

PAPER_SAMPLE = """
BINARY=my_a.out          // name of the binary
NO_FUNCTIONS=1           // number of functions
[FUNCTION]
FUNC_NAME=foo            // name of the function
NO_EVENTS=4              // total number of events
[EVENT]
ID=DATA_CACHE_MISSES     // the event name or id
NO_SUBEVENTS=0           // number of subevents
[/EVENT]
[EVENT]
ID=DISPATCHED_FPU
NO_SUBEVENTS=3
[SUBEVENT]               // list of subevents
ID=OPS_ADD
ID=OPS_ADD_PIPE_LOAD_OPS
ID=OPS_MULTIPLY_PIPE_LOAD_OPS
[/SUBEVENT]
[/EVENT]
[/FUNCTION]
"""


def test_parse_paper_sample():
    cfg = cf.parse(PAPER_SAMPLE)
    assert cfg.binary == "my_a.out"
    assert len(cfg.functions) == 1
    fn = cfg.functions[0]
    assert fn.name == "foo"
    # subevents expand into one slot each: 1 + 3
    assert len(fn.events) == 4
    assert fn.events[1].spec.subevent == "OPS_ADD"


def test_comment_styles_and_blank_lines():
    cfg = cf.parse("BINARY=x // c\n\n# full comment\nNO_FUNCTIONS=0\n")
    assert cfg.binary == "x"


@pytest.mark.parametrize(
    "text,err",
    [
        ("[FUNCTION]\n[FUNCTION]\n", "nested"),
        ("[/FUNCTION]\n", "without"),
        ("[FUNCTION]\nFUNC_NAME=f\n", "unterminated"),
        ("[FUNCTION]\n[/FUNCTION]\n", "missing FUNC_NAME"),
        ("NO_FUNCTIONS=3\n", "NO_FUNCTIONS=3"),
        ("[FUNCTION]\nFUNC_NAME=f\nNO_EVENTS=2\n[/FUNCTION]\n", "NO_EVENTS"),
        ("garbage\n", "KEY=VALUE"),
        ("WHAT=1\n", "unknown top-level"),
    ],
)
def test_parse_errors(text, err):
    with pytest.raises(cf.ConfigError, match=err):
        cf.parse(text)


def test_multiplex_sets_and_period():
    text = """
BINARY=b
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=layer/attn
MULTIPLEX_PERIOD=100
NO_EVENTS=3
[EVENT]
ID=ACT_RMS
TENSOR=out
SET=0
NO_SUBEVENTS=0
[/EVENT]
[EVENT]
ID=NAN_COUNT:out
SET=1
NO_SUBEVENTS=0
[/EVENT]
[EVENT]
ID=INF_COUNT:out
SET=1
NO_SUBEVENTS=0
[/EVENT]
[/FUNCTION]
"""
    cfg = cf.parse(text)
    ctx = cfg.functions[0].to_scope_context()
    assert ctx.n_sets == 2
    assert ctx.default_period == 100
    assert ctx.slots[0].slot_id == "ACT_RMS:out"


def _spec():
    return MonitorSpec.of([
        ScopeContext.exhaustive(
            "layer/attn",
            [EventSpec("ACT_RMS", "out"), EventSpec("NAN_COUNT", "out")],
        ),
        ScopeContext.exhaustive("layer/mlp", [EventSpec("ACT_RMS", "out")]),
    ])


def test_apply_config_masks():
    spec = _spec()
    cfg = cf.parse(
        "NO_FUNCTIONS=1\n[FUNCTION]\nFUNC_NAME=layer/attn\n"
        "MULTIPLEX_PERIOD=5\nNO_EVENTS=1\n"
        "[EVENT]\nID=ACT_RMS:out\nNO_SUBEVENTS=0\n[/EVENT]\n[/FUNCTION]\n"
    )
    params, missing = cf.apply_config(spec, cfg)
    assert missing == []
    sm = np.asarray(params.scope_mask)
    assert sm[spec.scope_index("layer/attn")] == 1.0
    assert sm[spec.scope_index("layer/mlp")] == 0.0
    slots = np.asarray(params.slot_mask)
    ai = spec.scope_index("layer/attn")
    assert slots[ai, 0] == 1.0 and slots[ai, 1] == 0.0
    assert np.asarray(params.period)[ai] == 5


def test_apply_config_bare_function_enables_all_slots():
    spec = _spec()
    cfg = cf.parse(
        "NO_FUNCTIONS=1\n[FUNCTION]\nFUNC_NAME=layer/attn\nNO_EVENTS=0\n"
        "[/FUNCTION]\n"
    )
    params, missing = cf.apply_config(spec, cfg)
    slots = np.asarray(params.slot_mask)
    assert slots[spec.scope_index("layer/attn"), :2].sum() == 2.0


def test_apply_config_outside_compile_time_set():
    spec = _spec()
    cfg = cf.parse(
        "NO_FUNCTIONS=2\n"
        "[FUNCTION]\nFUNC_NAME=not_compiled\nNO_EVENTS=0\n[/FUNCTION]\n"
        "[FUNCTION]\nFUNC_NAME=layer/attn\nNO_EVENTS=1\n"
        "[EVENT]\nID=L2NORM:out\nNO_SUBEVENTS=0\n[/EVENT]\n[/FUNCTION]\n"
    )
    params, missing = cf.apply_config(spec, cfg)
    assert "scope:not_compiled" in missing
    assert "slot:layer/attn:L2NORM:out" in missing
    with pytest.raises(cf.ConfigError, match="re-trace"):
        cf.apply_config(spec, cfg, strict=True)


# '//' and '#' start comments in the grammar, so they cannot appear in names
_name = st.text(
    alphabet=st.sampled_from("abcdefgh_/"), min_size=1, max_size=12
).filter(lambda s: "//" not in s and not s.startswith("/"))
_event = st.sampled_from(
    ["ACT_RMS", "NAN_COUNT", "MEAN", "L2NORM", "ACT_MAX_ABS"]
)
_tensor = st.sampled_from(["out", "x", "state", ""])


@st.composite
def _configs(draw):
    fns = []
    for name in draw(
        st.lists(_name, min_size=0, max_size=4, unique=True)
    ):
        events = []
        for i in range(draw(st.integers(0, 4))):
            events.append(
                cf.EventConfig(
                    spec=EventSpec(draw(_event), draw(_tensor)),
                    set_index=draw(st.integers(0, 2)),
                )
            )
        fns.append(
            cf.FunctionConfig(
                name=name, events=events,
                multiplex_period=draw(st.integers(1, 500)),
            )
        )
    return cf.ScalpelConfig(binary=draw(_name), functions=fns)


@settings(max_examples=50, deadline=None)
@given(_configs())
def test_serialize_parse_roundtrip(cfg):
    text = cf.serialize(cfg)
    back = cf.parse(text)
    assert back.binary == cfg.binary
    assert [f.name for f in back.functions] == [f.name for f in cfg.functions]
    for f1, f2 in zip(cfg.functions, back.functions):
        assert [e.spec.slot_id for e in f1.events] == [
            e.spec.slot_id for e in f2.events
        ]
        assert [e.set_index for e in f1.events] == [
            e.set_index for e in f2.events
        ]
        assert f1.multiplex_period == f2.multiplex_period
