"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; prefill/decode agreement for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, model_config
from repro.core.counters import MonitorParams
from repro.models import SHAPES
from repro.models.registry import Arch
from repro.optim import OptConfig
from repro.train.step import TrainState, build_monitor_spec, make_train_step

B, S = 2, 32


def _batch(cfg, rng=0, with_targets=True):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(rng), 3)
    toks = jax.random.randint(k1, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(
            k3, (B, S, cfg.d_model)
        ).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        n_img = S // 4
        batch["tokens"] = toks[:, : S - n_img]
        batch["img_embeds"] = jax.random.normal(
            k3, (B, n_img, cfg.d_model)
        ).astype(jnp.dtype(cfg.compute_dtype))
    if with_targets:
        batch["targets"] = jax.random.randint(
            k2, batch["tokens"].shape, 0, cfg.vocab
        )
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_and_params(request):
    cfg = model_config(request.param, smoke=True)
    arch = Arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    return request.param, arch, params


def test_exact_assigned_config_shapes(arch_and_params):
    """The FULL config must carry the exact assigned hyperparameters."""
    aid, arch, _ = arch_and_params
    full = model_config(aid)
    assigned = {
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
    }[aid]
    got = (full.n_layers, full.d_model, full.n_heads, full.n_kv_heads,
           full.d_ff, full.vocab)
    assert got == assigned, aid


def test_forward_shapes_and_finite(arch_and_params):
    aid, arch, params = arch_and_params
    cfg = arch.cfg
    batch = _batch(cfg, with_targets=False)
    logits = arch.forward(params, batch)
    ntok = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        ntok += batch["img_embeds"].shape[1]
    assert logits.shape == (B, ntok, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_loss_near_uniform_at_init(arch_and_params):
    aid, arch, params = arch_and_params
    loss = arch.loss_fn(params, _batch(arch.cfg))
    lnv = np.log(arch.cfg.vocab)
    assert 0.5 * lnv < float(loss) < 1.6 * lnv, (aid, float(loss))


def test_train_step_updates_and_counts(arch_and_params):
    from repro import core as scalpel

    aid, arch, params = arch_and_params
    batch = _batch(arch.cfg)
    spec = build_monitor_spec(arch, batch)
    opt = OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0, min_lr_frac=1.0)
    tstate = TrainState.create(arch, opt, jax.random.PRNGKey(0))
    mon = scalpel.Monitor(spec, MonitorParams.all_on(spec))
    step = jax.jit(make_train_step(arch, opt, spec, monitor=mon))
    t1, out1, m1 = step(tstate, batch, mon.init())
    t2, out2, m2 = step(t1, batch, m1)
    assert np.isfinite(float(out1["loss"]))
    # same batch twice with lr>0: loss must move (params updated)
    assert float(out2["loss"]) != pytest.approx(float(out1["loss"]),
                                                abs=1e-7)
    assert int(t2.step) == 2
    assert int(m2.step) == 2
    # every scope intercepted at least once per step
    assert int(np.asarray(m2.calls).min()) >= 1
    # no NaN counters
    assert np.isfinite(np.asarray(m2.values)).all()


def test_prefill_decode_matches_forward(arch_and_params):
    """Greedy next-token from (prefill -> decode) must agree with the
    training forward's last-position argmax (KV-cache correctness)."""
    aid, arch, params = arch_and_params
    cfg = arch.cfg
    batch = _batch(cfg, with_targets=False)
    logits_full = arch.forward(params, batch)
    cache, logits_pre = arch.prefill(params, batch, cache_len=S + 8)
    lf = np.asarray(logits_full[:, -1, :].astype(jnp.float32))
    lp = np.asarray(logits_pre[:, -1, :].astype(jnp.float32))
    np.testing.assert_allclose(lp, lf, atol=5e-2, rtol=5e-2)
    # decode one token; logits finite, cache advances
    nxt = jnp.argmax(logits_pre[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    logits_dec, cache2 = arch.decode_step(params, cache, nxt)
    assert logits_dec.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits_dec.astype(jnp.float32))))


def test_input_specs_match_assigned_shapes(arch_and_params):
    aid, arch, _ = arch_and_params
    full = Arch(model_config(aid))
    for name, sh in SHAPES.items():
        ok, why = full.supports(sh)
        if not ok:
            assert name == "long_500k" and not full.cfg.subquadratic
            continue
        specs = full.input_specs(sh)
        if sh.kind == "decode":
            assert specs["tokens"].shape == (sh.global_batch, 1)
        else:
            total = sum(
                v.shape[1] for k, v in specs.items()
                if k in ("tokens", "img_embeds", "enc_frames")
                and (k != "enc_frames")
            )
            assert total == sh.seq_len, (aid, name)
            assert specs["tokens"].shape[0] == sh.global_batch


def test_decode_stream_matches_prefill(arch_and_params):
    """Decoding tokens one-by-one must reproduce a longer prefill's logits
    (recurrent-state / KV-cache equivalence across families)."""
    aid, arch, params = arch_and_params
    cfg = arch.cfg
    if cfg.family in ("encdec",):
        pytest.skip("encdec covered by prefill test (cross-attn fixed)")
    if cfg.family == "moe":
        # capacity-based token dropping is batch-composition dependent, so
        # streamed decode only matches prefill when nothing is dropped
        import dataclasses as _dc

        cfg = cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=16.0))
        arch = type(arch)(cfg)
    batch = _batch(cfg, with_targets=False)
    toks = batch["tokens"]
    prefix = batch.get("img_embeds")
    total = toks.shape[1] + (prefix.shape[1] if prefix is not None else 0)
    n0 = toks.shape[1] - 4
    b0 = dict(batch, tokens=toks[:, :n0])
    cache, logits = arch.prefill(params, b0, cache_len=total + 4)
    for i in range(n0, toks.shape[1]):
        logits, cache = arch.decode_step(params, cache, toks[:, i:i + 1])
    full_cache, logits_full = arch.prefill(
        params, batch, cache_len=total + 4
    )
    np.testing.assert_allclose(
        np.asarray(logits[:, -1].astype(jnp.float32)),
        np.asarray(logits_full[:, -1].astype(jnp.float32)),
        atol=8e-2, rtol=8e-2,
    )
