"""Continuous-batching serve engine (serve/driver.py + serve/engine.py).

The contracts under test, in decreasing order of subtlety:

* EXACTNESS — greedy tokens from the lane-packed megastep engine are
  bitwise equal to the serial per-request oracle, and per-lane counter
  attribution matches a fresh serial engine run of the same request
  (vmap stacked-equals-individual + the emit-then-decode ordering).

* SEEDED RNG INDEPENDENCE — a seeded request's sampling stream derives
  from PRNGKey(seed) alone, so two same-seed requests sample identical
  tokens regardless of which lane they land on or how much unseeded
  traffic runs concurrently (the serial engine's documented contract,
  inherited through the per-lane key columns).

* HOST-SYNC DISCIPLINE — the decode hot loop performs zero blocking
  readbacks per token: megasteps, admissions, and ring publishes are all
  async; tokens leave through the telemetry token ring drained one
  megastep behind.  Attested by counting ``jax.block_until_ready`` calls
  and by the engine's own dispatch/drain accounting.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import model_config
from repro.models.registry import Arch
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


@pytest.fixture(scope="module")
def tiny():
    return Arch(model_config("xlstm_125m", smoke=True))


@pytest.fixture(scope="module")
def params(tiny):
    return tiny.init(jax.random.PRNGKey(0))


def _prompt(seed, s=8, vocab=512):
    return jax.random.randint(jax.random.PRNGKey(seed), (1, s), 0, vocab)


def _serial(arch, params, prompt, max_new, seed=None, temperature=0.0):
    """Fresh serial oracle: one request, returns (tokens[n], counters)."""
    eng = Engine(arch, params,
                 ServeConfig(cache_len=64, max_new_tokens=max_new,
                             temperature=temperature))
    out, _ = eng.generate({"tokens": prompt}, seed=seed)
    return np.asarray(out)[0], eng.counters


def test_continuous_matches_serial_greedy_with_lane_reuse(tiny, params):
    """4 requests over 3 lanes (forces one lane reuse): greedy tokens
    exactly equal the serial oracle and per-lane counters attribute the
    full prefill+decode cost of each request."""
    prompts = [_prompt(i) for i in range(4)]
    eng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, max_new_tokens=6, n_lanes=3,
                    steps_per_commit=4))
    rids = [eng.submit(p) for p in prompts]
    res = eng.run()
    agg_calls = np.zeros_like(np.asarray(eng.counters.calls))
    for rid, prompt in zip(rids, prompts):
        want_toks, want_ctr = _serial(tiny, params, prompt, max_new=6)
        np.testing.assert_array_equal(res[rid].tokens, want_toks)
        got = res[rid].counters
        # attribution: the lane row carries this request's whole cost
        np.testing.assert_array_equal(np.asarray(got.calls),
                                      np.asarray(want_ctr.calls))
        np.testing.assert_array_equal(np.asarray(got.samples),
                                      np.asarray(want_ctr.samples))
        np.testing.assert_allclose(np.asarray(got.values),
                                   np.asarray(want_ctr.values), rtol=1e-5)
        agg_calls += np.asarray(got.calls)
        assert 0 <= res[rid].lane < 3
    # the lane-summed aggregate equals the sum of attributions
    np.testing.assert_array_equal(np.asarray(eng.counters.calls), agg_calls)
    assert eng.sched.admitted == 4 and eng.sched.completed == 4


def test_seeded_streams_independent_of_lane_and_traffic(tiny, params):
    """Satellite: same-seed sampled requests produce identical tokens no
    matter which lane serves them or what unseeded traffic interleaves —
    and both match the serial engine's stream bitwise."""
    prompt = _prompt(11)
    eng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, max_new_tokens=5, n_lanes=2,
                    steps_per_commit=2, temperature=0.8))
    r_a = eng.submit(prompt, seed=7)
    _ = eng.submit(_prompt(12))          # unseeded noise
    _ = eng.submit(_prompt(13))          # unseeded noise
    r_b = eng.submit(prompt, seed=7)     # same seed, later admission
    res = eng.run()
    np.testing.assert_array_equal(res[r_a].tokens, res[r_b].tokens)
    want, _ = _serial(tiny, params, prompt, max_new=5, seed=7,
                      temperature=0.8)
    np.testing.assert_array_equal(res[r_a].tokens, want)


def test_oversubscribed_admission_and_varying_lengths(tiny, params):
    """7 requests over 2 lanes with max_new 1..7: lanes recycle through
    admission/retirement and every request's tokens are the right greedy
    prefix (same prompt => shorter runs are prefixes of the longest)."""
    prompt = _prompt(3)
    want, _ = _serial(tiny, params, prompt, max_new=7)
    eng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, n_lanes=2, steps_per_commit=3))
    rids = [eng.submit(prompt, max_new=n) for n in range(1, 8)]
    res = eng.run()
    for n, rid in zip(range(1, 8), rids):
        np.testing.assert_array_equal(res[rid].tokens, want[:n])
    assert eng.sched.admitted == 7 and eng.sched.completed == 7
    assert eng.stats["tokens_out"] == sum(range(1, 8))


def test_decode_loop_makes_zero_host_syncs(tiny, params, monkeypatch):
    """The zero-syncs-per-token attestation: run() never calls
    ``jax.block_until_ready``, dispatches exactly ceil(max_new/K)
    megasteps, and drains the token ring once per megastep plus the one
    final (blocking) completion drain."""
    calls = []
    real = jax.block_until_ready
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: (calls.append(1), real(x))[1])
    eng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, max_new_tokens=6, n_lanes=3,
                    steps_per_commit=4))
    for i in range(3):
        eng.submit(_prompt(20 + i))
    res = eng.run()
    assert not calls, "decode loop performed a blocking host sync"
    assert len(res) == 3 and all(len(r.tokens) == 6 for r in res.values())
    # all three admitted up front => lockstep retirement: ceil(6/4) = 2
    assert eng.stats["megasteps"] == math.ceil(6 / 4)
    assert eng.stats["token_drains"] == eng.stats["megasteps"] + 1
    assert eng.stats["prefills"] == 3 and eng.stats["admissions"] == 3
    assert eng.stats["tokens_out"] == 18
    assert eng.runtime.telemetry.dropped_tokens == 0


def test_max_new_zero_is_an_empty_result(tiny, params):
    """Satellite: explicit max_new=0 is honored (not treated as the config
    default) by both engines."""
    prompt = _prompt(5)
    eng = Engine(tiny, params, ServeConfig(cache_len=64, max_new_tokens=4))
    out, stats = eng.generate({"tokens": prompt}, max_new=0)
    assert out.shape == (1, 0)
    assert stats["decode_total_s"] == 0.0 and stats["decode_p50_s"] == 0.0
    assert eng.step_times == {}  # no timing bucket was touched
    ceng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, n_lanes=2, steps_per_commit=2))
    r0 = ceng.submit(prompt, max_new=0)
    r1 = ceng.submit(prompt, max_new=3)
    res = ceng.run()
    assert res[r0].tokens.shape == (0,) and res[r0].lane == -1
    want, _ = _serial(tiny, params, prompt, max_new=3)
    np.testing.assert_array_equal(res[r1].tokens, want)
    # an empty-only workload dispatches nothing
    ceng2 = ContinuousEngine(
        tiny, params, ServeConfig(cache_len=64, n_lanes=2),
        spec=ceng.spec)
    r2 = ceng2.submit(prompt, max_new=0)
    res2 = ceng2.run()
    assert res2[r2].tokens.shape == (0,)
    assert ceng2.stats["megasteps"] == 0 and ceng2.stats["prefills"] == 0


def test_decode_p50_keyed_by_shape_and_resettable(tiny, params):
    """Satellite: per-token decode timings bucket by (batch, max_new) so
    medians of different regimes never mix, and reset_stats() drops them."""
    eng = Engine(tiny, params, ServeConfig(cache_len=64, max_new_tokens=4))
    p1 = _prompt(30, s=8)
    p2 = jnp.concatenate([_prompt(31, s=8)] * 2, axis=0)  # batch of 2
    _, s1 = eng.generate({"tokens": p1})
    _, s2 = eng.generate({"tokens": p2})
    assert set(eng.step_times) == {(1, 4), (2, 4)}
    assert s1["decode_p50_s"] == eng.step_times[(1, 4)][0]
    assert s2["decode_p50_s"] == eng.step_times[(2, 4)][0]
    _, s3 = eng.generate({"tokens": p1})
    assert len(eng.step_times[(1, 4)]) == 2
    assert s3["decode_p50_s"] == pytest.approx(
        float(np.median(eng.step_times[(1, 4)])))
    # a different max_new is a different bucket too
    eng.generate({"tokens": p1}, max_new=2)
    assert (1, 2) in eng.step_times
    eng.reset_stats()
    assert eng.step_times == {}


def test_bucketed_prefill_mixed_lengths_exact_and_bounded_traces(
        tiny, params):
    """Prompt-length bucketing: mixed lengths 3..16 pad to pow2 buckets
    {8, 16}; greedy tokens stay exactly serial (mask-correct prefill), the
    prefill program compiles once per BUCKET (not per length), and the
    scheduler accounts the pad waste."""
    lengths = [3, 5, 8, 11, 13, 16]
    prompts = [_prompt(50 + i, s=s) for i, s in enumerate(lengths)]
    eng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, max_new_tokens=5, n_lanes=3,
                    steps_per_commit=4))
    assert eng._buckets == (8, 16, 32, 64)
    rids = [eng.submit(p, max_new=5) for p in prompts]
    res = eng.run()
    for rid, prompt in zip(rids, prompts):
        want, _ = _serial(tiny, params, prompt, max_new=5)
        np.testing.assert_array_equal(res[rid].tokens, want)
    cs = eng.compile_stats()
    assert cs["buckets_used"] == [8, 16]
    # the bucketing win: 6 distinct lengths, TWO prefill traces
    assert cs["prefill_traces"] == 2, cs
    assert cs["admission_traces"] == 1 and cs["megastep_traces"] == 1
    pad = sum(8 - s if s <= 8 else 16 - s for s in lengths)
    assert cs["pad_waste_frac"] == pytest.approx(
        pad / (pad + sum(lengths)))
    assert "pad_waste_frac" in eng.report()


def test_bucketed_prefill_kv_family_exact(params):
    """Bucketing on the KV-slab family: pad K/V slots sit past ``pos`` and
    are overwritten/masked by decode — tokens stay exactly serial."""
    arch = Arch(model_config("mistral_nemo_12b", smoke=True))
    tparams = arch.init(jax.random.PRNGKey(1))
    prompts = [jax.random.randint(jax.random.PRNGKey(60 + i), (1, s), 0,
                                  arch.cfg.vocab) for i, s in
               enumerate([5, 12])]
    eng = ContinuousEngine(
        arch, tparams,
        ServeConfig(cache_len=64, max_new_tokens=4, n_lanes=2,
                    steps_per_commit=2))
    rids = [eng.submit(p) for p in prompts]
    res = eng.run()
    for rid, prompt in zip(rids, prompts):
        want, _ = _serial(arch, tparams, prompt, max_new=4)
        np.testing.assert_array_equal(res[rid].tokens, want)
    assert eng.compile_stats()["buckets_used"] == [8, 16]
    assert eng.compile_stats()["prefill_traces"] == 2


def test_unbucketed_prefill_warns_on_per_length_retrace(tiny, params):
    """Satellite: with bucketing disabled, the third distinct prompt
    length trips the one-shot compile-churn warning pointing at
    ServeConfig.prefill_buckets."""
    eng = ContinuousEngine(
        tiny, params,
        ServeConfig(cache_len=64, max_new_tokens=2, n_lanes=3,
                    steps_per_commit=2, prefill_buckets=None))
    assert eng._buckets is None
    for i, s in enumerate([4, 6, 9]):
        eng.submit(_prompt(70 + i, s=s), max_new=2)
    with pytest.warns(RuntimeWarning, match="prefill_buckets"):
        res = eng.run()
    assert eng.compile_stats()["prefill_traces"] == 3  # one per length
    assert eng.compile_stats()["pad_waste_frac"] == 0.0
    assert len(res) == 3
    # the warning is one-shot: another retracing admission stays silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        eng.submit(_prompt(73, s=11), max_new=2)
        eng.run()


def test_transformer_kv_slab_family(params):
    """The KV-cache slab path (dense/transformer family): position-indexed
    dynamic_update_slice per lane under vmap still matches serial."""
    arch = Arch(model_config("mistral_nemo_12b", smoke=True))
    tparams = arch.init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(40), (1, 8), 0,
                                arch.cfg.vocab)
    eng = ContinuousEngine(
        arch, tparams,
        ServeConfig(cache_len=64, max_new_tokens=4, n_lanes=2,
                    steps_per_commit=2))
    r0 = eng.submit(prompt)
    r1 = eng.submit(prompt)
    res = eng.run()
    want, _ = _serial(arch, tparams, prompt, max_new=4)
    np.testing.assert_array_equal(res[r0].tokens, want)
    np.testing.assert_array_equal(res[r1].tokens, want)
