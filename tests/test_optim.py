"""Optimizer tiers: f32 / int8-quantized / factored; schedule; clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    OptConfig,
    apply_updates,
    global_norm,
    init_opt_state,
    lr_at,
    opt_state_axes,
)
from repro.optim.adamw import _dequant, _quant


def _rosenbrock_params():
    return {"w": jnp.array([1.5, -0.5], jnp.float32),
            "b": jnp.zeros((3, 4), jnp.float32)}


def _quad_loss(p):
    return jnp.sum((p["w"] - 2.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("state", ["f32", "int8", "factored"])
def test_optimizer_converges_on_quadratic(state):
    cfg = OptConfig(lr=5e-2, warmup_steps=0, total_steps=400,
                    weight_decay=0.0, clip_norm=0.0, state=state,
                    min_lr_frac=1.0)
    params = _rosenbrock_params()
    opt = init_opt_state(cfg, params)
    loss0 = float(_quad_loss(params))

    @jax.jit
    def step(p, o):
        g = jax.grad(_quad_loss)(p)
        return apply_updates(cfg, o, p, g)

    for _ in range(300):
        params, opt, stats = step(params, opt)
    assert float(_quad_loss(params)) < 0.05 * loss0, state


def test_int8_quant_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)) * 0.01
    q = _quant(x)
    assert q["q"].dtype == jnp.int8
    back = _dequant(q)
    # quadratic code: relative error small near the row max, tiny near zero
    err = np.abs(np.asarray(back - x))
    scale = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    assert (err / scale).max() < 0.02


def test_int8_state_memory_is_int8():
    cfg = OptConfig(state="int8")
    params = {"w": jnp.zeros((16, 32), jnp.float32)}
    st = init_opt_state(cfg, params)
    assert st.m["w"]["q"].dtype == jnp.int8
    assert st.v["w"]["q"].dtype == jnp.int8


def test_factored_second_moment_shapes():
    cfg = OptConfig(state="factored")
    params = {"w": jnp.zeros((16, 32), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    st = init_opt_state(cfg, params)
    assert st.v["w"]["vr"].shape == (16,)
    assert st.v["w"]["vc"].shape == (32,)
    assert st.v["b"].shape == (8,)  # 1-D leaves stay unfactored


@pytest.mark.parametrize("state", ["f32", "int8", "factored"])
def test_opt_state_axes_structure_matches(state):
    cfg = OptConfig(state=state)
    params = {"w": jnp.zeros((16, 32), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    axes = {"w": ("embed", "mlp"), "b": (None,)}
    st = init_opt_state(cfg, params)
    ax = opt_state_axes(cfg, axes)
    jax.tree.map(
        lambda a, b: None, st, ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )  # raises on structure mismatch


def test_lr_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 5)) == pytest.approx(0.5)
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 110)) == pytest.approx(0.1, rel=1e-3)


def test_grad_clipping_caps_update_norm():
    cfg = OptConfig(lr=1.0, warmup_steps=0, clip_norm=1e-3,
                    weight_decay=0.0, min_lr_frac=1.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    opt = init_opt_state(cfg, params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, stats = apply_updates(cfg, opt, params, g)
    assert float(stats["clip_scale"]) == pytest.approx(
        1e-3 / float(global_norm(g)), rel=1e-4)


def test_master_weights_keep_precision_with_bf16_params():
    cfg = OptConfig(lr=1e-4, warmup_steps=0, weight_decay=0.0,
                    min_lr_frac=1.0)
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    opt = init_opt_state(cfg, params)
    # one tiny step: bf16 params could not represent the delta, master must
    g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    p2, o2, _ = apply_updates(cfg, opt, params, g)
    assert p2["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(o2.master["w"] - 1.0))) > 0.0
    # master moved even though bf16 param may round back to 1.0
    assert not np.array_equal(
        np.asarray(o2.master["w"]), np.ones(8, np.float32)
    )
