"""Telemetry plane: device-side snapshot ring, background drain, sinks,
and runtime reconfiguration through the plane.

Covers the async-monitoring contract: ring appends are cond-guarded device
work at a *dynamic* cadence (changing it never re-traces — asserted via
jax.jit cache stats), drained snapshots are delta-decoded and value-equal
to synchronous snapshots, and the drain thread flushes everything on
shutdown.
"""
import json
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import report as report_lib
from repro.core import telemetry as T
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams


def _spec():
    return MonitorSpec.of([
        ScopeContext.exhaustive("f", [EventSpec("MEAN", "x"),
                                      EventSpec("NUMEL", "x")]),
        ScopeContext.exhaustive("g", [EventSpec("MEAN", "x")]),
    ])


def _bump(cs: CounterState, v: float = 1.0) -> CounterState:
    return CounterState(calls=cs.calls + 1, values=cs.values + v,
                        samples=cs.samples + 1)


def _run_steps(spec, params, state, values):
    for v in values:
        with scalpel.collecting(spec, params, state) as col:
            with scalpel.function("f"):
                scalpel.probe(x=jnp.full((4,), v))
            with scalpel.function("g"):
                scalpel.probe(x=jnp.full((2,), v))
        state = state.add(col.delta)
    return state


# ---------------------------------------------------------------------------
# device side: ring semantics
# ---------------------------------------------------------------------------

def test_ring_append_cadence_and_stamp():
    spec = _spec()
    ring = T.SnapshotRing.zeros(spec, depth=4)
    cs = CounterState.zeros(spec)
    for step in range(1, 7):
        cs = _bump(cs)
        ring = T.ring_append(ring, cs, T.TelemetryParams.of(2), step)
    assert int(ring.head) == 3
    written = sorted(int(s) for s in np.asarray(ring.steps) if s >= 0)
    assert written == [2, 4, 6]
    # slot for step 6 holds the cumulative counters at step 6
    slot = (int(ring.head) - 1) % ring.depth
    assert int(ring.calls[slot][0]) == 6


def test_ring_append_wraps_and_zero_cadence_disables():
    spec = _spec()
    ring = T.SnapshotRing.zeros(spec, depth=2)
    cs = CounterState.zeros(spec)
    for step in range(1, 6):
        cs = _bump(cs)
        ring = T.ring_append(ring, cs, T.TelemetryParams.of(1), step)
    assert int(ring.head) == 5          # monotonic, beyond depth
    assert sorted(np.asarray(ring.steps).tolist()) == [4, 5]  # last two
    off = T.ring_append(ring, cs, T.TelemetryParams.of(0), 6)
    assert int(off.head) == 5           # cadence 0: never writes


def test_ring_append_cadence_is_dynamic_no_retrace():
    """Cadence changes ride a dynamic input — the jitted append never
    re-traces (asserted with jax.jit cache stats AND a trace counter)."""
    spec = _spec()
    traces = []

    def append(ring, cs, tp, step):
        traces.append(1)
        return T.ring_append(ring, cs, tp, step)

    f = jax.jit(append)
    ring = T.SnapshotRing.zeros(spec, depth=4)
    cs = _bump(CounterState.zeros(spec))
    for step, cadence in enumerate([1, 1, 2, 5, 0, 3], start=1):
        ring = f(ring, cs, T.TelemetryParams.of(cadence),
                 jnp.asarray(step, jnp.int32))
    assert len(traces) == 1
    assert f._cache_size() == 1


# ---------------------------------------------------------------------------
# host side: drain, delta decode, sinks
# ---------------------------------------------------------------------------

def test_plane_drains_and_delta_decodes():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=8, cadence=1)
    got = []
    plane.add_sink(T.CallbackSink(got.append))
    cs = CounterState.zeros(spec)
    for step in range(1, 5):
        cs = _bump(cs, v=2.0)
        plane.append(cs, step=step)
    plane.flush()
    assert [s.step for s in got] == [1, 2, 3, 4]
    # cumulative state at step k has calls == k; delta is one step's worth
    for k, s in enumerate(got, start=1):
        assert int(s.state.calls[0]) == k
        assert int(s.delta.calls[0]) == 1
        assert float(s.delta.values[0, 0]) == pytest.approx(2.0)
    plane.close()


def test_plane_counts_dropped_snapshots_on_overrun():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=2, cadence=1)
    seen = []
    plane.add_sink(T.CallbackSink(lambda s: seen.append(s.step)))
    cs = CounterState.zeros(spec)
    ring = plane.make_ring()
    for step in range(1, 6):   # 5 appends into a depth-2 ring, no drain
        cs = _bump(cs)
        ring = T.ring_append(ring, cs, plane.params, step)
    plane.publish(ring)
    plane.flush()
    assert seen == [4, 5]                  # only the surviving slots
    assert plane.dropped_snapshots == 3    # the overwritten ones are counted
    plane.close()


def test_make_ring_starts_new_epoch():
    """A fresh ring restarts head at 0 — make_ring() must reset the drain
    cursor and delta base, or the plane silently stops draining (the drain
    loop also self-heals if a restarted ring is published directly)."""
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=8, cadence=1, interval_s=60.0)
    got = []
    plane.add_sink(T.CallbackSink(
        lambda s: got.append((s.step, int(s.state.calls[0]),
                              int(s.delta.calls[0])))))
    for _ in range(2):
        ring = plane.make_ring()
        cs = CounterState.zeros(spec)
        for step in range(1, 4):
            cs = _bump(cs)
            ring = T.ring_append(ring, cs, plane.params, step)
        plane.publish(ring)
        plane.flush()
    # the second epoch drains again, with its delta base reset (first
    # snapshot's delta == its cumulative state, not state - old epoch)
    assert got == [(1, 1, 1), (2, 2, 1), (3, 3, 1)] * 2
    # self-heal: a shorter restarted ring published without make_ring()
    ring = T.SnapshotRing.zeros(spec, plane.depth)
    cs = _bump(CounterState.zeros(spec))
    ring = T.ring_append(ring, cs, plane.params, 1)
    plane.publish(ring)
    plane.flush()
    assert got[-1] == (1, 1, 1)
    plane.close()


def test_drain_copies_one_slot_when_caught_up():
    """Incremental drain: a drain that kept up (one new slot since the
    cursor) copies the ring's O(1) ``last`` mirror — one slot's worth of
    transfer regardless of ring depth — and an idle flush copies none.
    Only a multi-slot catch-up pays a stacked-ring copy."""
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=16, cadence=1, interval_s=60.0)
    got = []
    plane.add_sink(T.CallbackSink(lambda s: got.append(s.step)))
    cs = CounterState.zeros(spec)
    ring = plane.make_ring()
    for step in (1, 2, 3):                 # keeping up: one append per drain
        cs = _bump(cs)
        ring = T.ring_append(ring, cs, plane.params, step)
        plane.publish(ring)
        plane.flush()
    assert got == [1, 2, 3]
    assert plane.slots_copied == 3          # one mirror copy each, not 3*16
    plane.flush()                           # idle: head probe only
    assert plane.slots_copied == 3
    # falling behind: 3 new slots → one stacked-ring copy (depth slots)
    for step in range(4, 7):
        cs = _bump(cs)
        ring = T.ring_append(ring, cs, plane.params, step)
    plane.publish(ring)
    plane.flush()
    assert got == [1, 2, 3, 4, 5, 6]
    assert plane.slots_copied == 3 + 16
    # overrun still decodes the surviving slots and counts the drops
    for step in range(7, 27):
        cs = _bump(cs)
        ring = T.ring_append(ring, cs, plane.params, step)
    plane.publish(ring)
    plane.flush()
    assert got[-1] == 26 and plane.slots_copied == 3 + 16 + 16
    assert plane.dropped_snapshots == 4
    # drained deltas stayed exact across both copy paths
    assert int(plane.last_state.calls[0]) == 26
    plane.close()


def test_token_drain_is_pure_transfer_with_exact_accounting(monkeypatch):
    """The token-egress drain NEVER dispatches device computation — only
    the scalar head probe plus buffer copies (the ROADMAP drain
    invariant, extended to the serve path).  Attested by swapping the
    module's ``jnp`` for a guard that raises on ANY op, and by the same
    slots-copied accounting the counter drain uses."""
    plane = T.TelemetryPlane(_spec(), depth=16, cadence=1, interval_s=60.0)
    ring = plane.make_token_ring(3, depth=4)
    append = jax.jit(T.token_ring_append)
    toks = jnp.asarray([5, 6, 7], jnp.int32)
    live = jnp.asarray([1, 0, 1], jnp.int32)
    ring = append(ring, toks, live, jnp.asarray(1, jnp.int32))
    ring = append(ring, toks + 1, live, jnp.asarray(2, jnp.int32))
    plane.publish_tokens(ring)

    class _NoDeviceOps:
        def __getattr__(self, name):
            raise AssertionError(
                f"token drain dispatched a device op: jnp.{name}")

    monkeypatch.setattr(T, "jnp", _NoDeviceOps())
    out = plane.drain_tokens()
    assert [(seq, step) for seq, step, _, _ in out] == [(0, 1), (1, 2)]
    np.testing.assert_array_equal(out[0][2], [5, 6, 7])
    np.testing.assert_array_equal(out[0][3], [1, 0, 1])
    np.testing.assert_array_equal(out[1][2], [6, 7, 8])
    assert plane.tok_slots_copied == 4      # one stacked copy, depth slots
    assert plane.token_drains == 1
    # idle drain: scalar head probe only — no slot copy
    assert plane.drain_tokens() == []
    assert plane.tok_slots_copied == 4 and plane.token_drains == 2
    assert plane.dropped_tokens == 0
    plane.close()


def test_token_ring_overrun_counts_losses_and_epoch_resets():
    """Tokens are outputs, not samples: slots lost to an overrun are
    counted loudly (the engine raises on any).  A fresh lineage via
    make_token_ring restarts the cursor at head 0."""
    plane = T.TelemetryPlane(_spec(), depth=16, cadence=1, interval_s=60.0)
    ring = plane.make_token_ring(2, depth=4)
    live = jnp.asarray([1, 1], jnp.int32)
    for step in range(1, 7):               # 6 appends into a depth-4 ring
        ring = T.token_ring_append(
            ring, jnp.asarray([step, -step], jnp.int32), live,
            jnp.asarray(step, jnp.int32))
    plane.publish_tokens(ring)
    out = plane.drain_tokens()
    assert plane.dropped_tokens == 2       # seqs 0-1 overwritten
    assert [seq for seq, _, _, _ in out] == [2, 3, 4, 5]
    np.testing.assert_array_equal(out[-1][2], [6, -6])
    # new lineage: cursor self-resets to the fresh ring's head
    ring2 = plane.make_token_ring(2, depth=4)
    ring2 = T.token_ring_append(
        ring2, jnp.asarray([9, 9], jnp.int32), live,
        jnp.asarray(1, jnp.int32))
    plane.publish_tokens(ring2)
    out2 = plane.drain_tokens()
    assert [seq for seq, _, _, _ in out2] == [0]
    np.testing.assert_array_equal(out2[0][2], [9, 9])
    assert plane.dropped_tokens == 2       # unchanged by the new epoch
    plane.close()


def test_background_drain_thread_runs_without_flush():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=8, cadence=1, interval_s=0.005)
    done = threading.Event()
    plane.add_sink(T.CallbackSink(lambda s: done.set()))
    plane.append(_bump(CounterState.zeros(spec)), step=1)
    assert done.wait(timeout=5.0), "drain thread never delivered snapshot"
    plane.close()


def test_jsonl_sink_buffers_and_flushes(tmp_path):
    spec = _spec()
    path = str(tmp_path / "t.jsonl")
    plane = T.TelemetryPlane(spec, depth=8, cadence=1)
    plane.add_sink(T.JsonlSink(path, buffer_lines=10_000))
    state = _run_steps(spec, MonitorParams.all_on(spec),
                       CounterState.zeros(spec), [1.0, 2.0])
    plane.append(state, step=1)
    plane.flush()  # buffered writer must hit the disk on flush
    lines = [json.loads(x) for x in open(path).read().splitlines()]
    assert {ln["scope"] for ln in lines} == {"f", "g"}
    assert all(ln["step"] == 1 for ln in lines)
    plane.close()


def test_plane_close_flushes_pending(tmp_path):
    """Shutdown semantics: close() drains un-drained slots + closes sinks."""
    spec = _spec()
    path = str(tmp_path / "t.jsonl")
    plane = T.TelemetryPlane(spec, depth=8, cadence=1, interval_s=60.0)
    plane.add_sink(T.JsonlSink(path, buffer_lines=10_000))
    cs = _bump(CounterState.zeros(spec))
    plane.append(cs, step=1)
    plane.close()   # no explicit flush: close must deliver + write
    lines = open(path).read().splitlines()
    assert lines and json.loads(lines[0])["step"] == 1
    # close is idempotent and further flushes are harmless
    plane.close()
    assert plane.flush() == []


def test_text_sink_prints_reports(capsys):
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=4, cadence=1)
    plane.add_sink(T.TextSink(title="probe"))
    state = _run_steps(spec, MonitorParams.all_on(spec),
                       CounterState.zeros(spec), [3.0])
    plane.append(state, step=9)
    plane.flush()
    out = capsys.readouterr().out
    assert "probe @ step 9" in out and "MEAN:x" in out
    plane.close()


# ---------------------------------------------------------------------------
# runtime reconfiguration through the plane
# ---------------------------------------------------------------------------

CONFIG_A = """
BINARY=test
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=f
NO_EVENTS=0
[/FUNCTION]
"""

CONFIG_B = """
BINARY=test
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=g
NO_EVENTS=0
[/FUNCTION]
"""


def test_runtime_reload_and_cadence_swap_never_retrace(tmp_path):
    """Config reload() AND telemetry cadence changes are dynamic-input
    swaps: one trace, one jit cache entry, across both reconfigurations."""
    spec = _spec()
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(CONFIG_A)
    rt = scalpel.ScalpelRuntime(spec, config_path=str(cfgp), hook_every=1)
    traces = []

    def step(state, mparams, tparams, ring, step_no):
        traces.append(1)
        with scalpel.collecting(spec, mparams, state) as col:
            with scalpel.function("f"):
                scalpel.probe(x=jnp.ones(3))
            with scalpel.function("g"):
                scalpel.probe(x=jnp.ones(3))
        new = state.add(col.delta)
        return new, T.ring_append(ring, new, tparams, step_no)

    f = jax.jit(step)
    s = CounterState.zeros(spec)
    ring = rt.telemetry.make_ring()
    for i in range(1, 3):
        s, ring = f(s, rt.params, rt.telemetry.params, ring,
                    jnp.asarray(i, jnp.int32))
    cfgp.write_text(CONFIG_B)
    rt.reload()                      # mask swap
    rt.hook_every = 3                # cadence swap through the plane
    assert rt.telemetry.cadence == 3
    for i in range(3, 7):
        s, ring = f(s, rt.params, rt.telemetry.params, ring,
                    jnp.asarray(i, jnp.int32))
    assert len(traces) == 1
    assert f._cache_size() == 1
    # ring reflects the live cadence: steps 1,2 at cadence 1, then 3,6
    rt.telemetry.publish(ring)
    snaps = rt.flush()
    assert [sn.step for sn in snaps] == [1, 2, 3, 6]
    rt.close()


def test_runtime_sigusr1_direct_handler_call(tmp_path):
    """The SIGUSR1 path, exercised by invoking the installed handler
    directly (what the OS would do on os.kill)."""
    spec = _spec()
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(CONFIG_A)
    rt = scalpel.ScalpelRuntime(spec, config_path=str(cfgp),
                                install_signal=True)
    try:
        cfgp.write_text(CONFIG_B)
        handler = signal.getsignal(signal.SIGUSR1)
        assert callable(handler)
        handler(signal.SIGUSR1, None)   # direct call — no process signal
        assert rt.reload_count == 1
        assert float(rt.params.scope_mask[spec.scope_index("g")]) == 1.0
        # and the real-signal path still works on top of it
        os.kill(os.getpid(), signal.SIGUSR1)
        assert rt.reload_count == 2
    finally:
        signal.signal(signal.SIGUSR1, signal.SIG_DFL)
        rt.close()


def test_runtime_hooks_run_on_drained_snapshots():
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec, hook_every=2)
    seen = []
    rt.add_hook(lambda r, reports: seen.append(reports))
    state = _run_steps(spec, rt.params, CounterState.zeros(spec), [1.0, 2.0])
    rt.on_step(state)   # step 1: below cadence, no ring write
    rt.on_step(state)   # step 2: ring write
    rt.flush()
    assert len(seen) == 1
    assert {r.scope for r in seen[0]} == {"f", "g"}
    rt.close()


def test_hook_may_reenter_flush_without_deadlock():
    """A hook that calls runtime.report()/snapshot() (which flush, hence
    re-enter the drain) must not deadlock on the drain lock."""
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec, hook_every=1)
    texts = []
    rt.add_hook(lambda r, reports: texts.append(r.report()))
    state = _run_steps(spec, rt.params, CounterState.zeros(spec), [1.0])
    rt.on_step(state)
    done = threading.Event()

    def _flush():
        rt.flush()
        done.set()

    t = threading.Thread(target=_flush, daemon=True)
    t.start()
    assert done.wait(timeout=20.0), "flush deadlocked on re-entrant hook"
    assert texts and "ScALPEL report" in texts[0]
    rt.close()


def test_drained_reports_value_equal_to_sync_snapshot():
    """Acceptance: ring-drained reports == synchronous snapshots (allclose),
    driven through the real jitted train step — now a wrapped Monitor step
    threading one MonitorState pytree with a COMPACT telemetry ring."""
    from repro.configs import model_config
    from repro.data import DataConfig, SyntheticLM
    from repro.models.registry import Arch
    from repro.optim import OptConfig
    from repro.train.step import TrainState, build_monitor_spec, \
        make_train_step

    arch = Arch(model_config("xlstm_125m", smoke=True))
    data = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    spec = build_monitor_spec(arch, batch)
    rt = scalpel.ScalpelRuntime(spec, hook_every=1, ring_depth=8)
    mon = scalpel.Monitor(spec, telemetry=rt.telemetry)
    step_fn = make_train_step(arch, OptConfig(lr=1e-3, warmup_steps=0), spec,
                              monitor=mon)
    jit_step = jax.jit(step_fn)   # no donation: we compare states below
    tstate = TrainState.create(arch, OptConfig(lr=1e-3, warmup_steps=0),
                               jax.random.PRNGKey(0))
    mstate = mon.init()
    drained = {}
    rt.telemetry.add_sink(T.CallbackSink(lambda s: drained.setdefault(
        s.step, s)))
    sync_states = []
    for _ in range(3):
        tstate, out, mstate = jit_step(tstate, batch, mstate)
        rt.on_step(mstate.counters, ring=mstate.ring)
        sync_states.append(jax.tree.map(jax.device_get, mstate.counters))
    rt.flush()
    assert sorted(drained) == [1, 2, 3]
    for k, sync in enumerate(sync_states, start=1):
        ring_state = drained[k].state
        # drained snapshots are COMPACT (dense slot layout) end-to-end
        assert np.asarray(ring_state.values).ndim == 1
        np.testing.assert_allclose(np.asarray(ring_state.values),
                                   np.asarray(sync.values),
                                   rtol=1e-6, atol=1e-8)
        np.testing.assert_array_equal(np.asarray(ring_state.calls),
                                      np.asarray(sync.calls))
        np.testing.assert_array_equal(np.asarray(ring_state.samples),
                                      np.asarray(sync.samples))
        # drained reports match reports built from the sync snapshot
        a = report_lib.estimates(spec, ring_state)
        b = report_lib.estimates(spec, sync)
        for scope in b:
            for slot, v in b[scope].items():
                np.testing.assert_allclose(a[scope][slot], v, rtol=1e-6,
                                           equal_nan=True)
    rt.close()


def test_jsonl_writer_single_open_buffered(tmp_path):
    p = str(tmp_path / "w.jsonl")
    spec = _spec()
    state = _run_steps(spec, MonitorParams.all_on(spec),
                       CounterState.zeros(spec), [1.0])
    reports = report_lib.build(spec, state)
    with report_lib.JsonlWriter(p, buffer_lines=10_000) as w:
        w.write(1, reports)
        w.write(2, reports)
        assert open(p).read() == ""     # buffered: nothing on disk yet
        w.flush()
        n = len(open(p).read().splitlines())
        assert n == 2 * len(reports)
        w.write(3, reports)
    # context exit closes (and flushes the tail)
    assert len(open(p).read().splitlines()) == 3 * len(reports)


def test_counterstate_sub_delta():
    spec = _spec()
    a = _bump(_bump(CounterState.zeros(spec), 2.0), 3.0)
    b = _bump(CounterState.zeros(spec), 2.0)
    d = a.sub(b)
    assert int(d.calls[0]) == 1
    assert float(d.values[0, 0]) == pytest.approx(3.0)


def test_plane_hot_loop_never_blocks_long():
    """publish() is a ref swap: a burst of publishes returns quickly even
    with a slow sink on the drain side."""
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=4, cadence=1)
    plane.add_sink(T.CallbackSink(lambda s: time.sleep(0.05)))
    cs = _bump(CounterState.zeros(spec))
    ring = plane.make_ring()
    ring = T.ring_append(ring, cs, plane.params, 1)
    jax.block_until_ready(ring.head)
    t0 = time.perf_counter()
    for _ in range(50):
        plane.publish(ring)
    assert time.perf_counter() - t0 < 1.0
    plane.close()
