"""Fleet aggregation tier integration (repro.telemetry) — PR 10 tentpole.

The acceptance tests ISSUE 10 names:

* 3 subprocess "hosts" over localhost sockets → 1 aggregator → head:
  fleet counter sums exactly equal the sum of per-host drained deltas
  (int lanes exact, float lanes at f64 tolerance against the agents' own
  f64 shipped-sum oracles), percentiles match a merged-reservoir oracle,
  and the straggler host is flagged.
* A killed host degrades gracefully — no hang, accounting intact.
* The agent NEVER dispatches device work: raising sys.modules guard
  around emit/flush/close (same technique as the token-drain tests).
* Double close never double-sends the shutdown frame; the runtime's
  graceful-shutdown path emits it exactly once.
* Drop accounting is uniform: bounded-buffer drops, reconnects, sink
  errors all surface through ``TelemetryPlane.stats()``.
"""
import json
import os
import socket
import subprocess
import sys
import time
import types

import numpy as np
import pytest

from repro.telemetry import wire
from repro.telemetry.agent import FleetAgent
from repro.telemetry.aggregator import Aggregator
from repro.telemetry.head import FleetHead

FP = "ab" * 20


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return env


def _fake_snap(step=4, n_scopes=3, total=12, seed=0, fingerprint=FP):
    """A TelemetrySnapshot stand-in (compact delta, host numpy)."""
    rng = np.random.default_rng(seed)
    delta = types.SimpleNamespace(
        calls=rng.integers(0, 50, n_scopes).astype(np.int32),
        values=rng.normal(size=total).astype(np.float32),
        samples=rng.integers(0, 20, total).astype(np.int32),
    )
    spec = types.SimpleNamespace(fingerprint=fingerprint, contexts=())
    return types.SimpleNamespace(step=step, seq=0, delta=delta, spec=spec)


def _wait(pred, timeout=10.0, every=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(every)
    return False


# ---------------------------------------------------------------------------
# the multi-process acceptance test
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_three_subprocess_hosts_exact_sums_percentiles_straggler(tmp_path):
    from repro.core import plan as plan_lib
    from repro.telemetry.simhost import build_spec

    agg = Aggregator(("127.0.0.1", 0), node_id="root", reservoir_k=256,
                     seed=7).serve()
    _, port = agg.address
    procs = []
    for i in range(3):
        cmd = [sys.executable, "-m", "repro.telemetry.simhost",
               "--host-id", f"h{i}", "--port", str(port),
               "--steps", "20", "--cadence", "2", "--seed", str(i),
               "--pace-s", "0.004"]
        if i == 2:
            cmd += ["--straggle-s", "0.06"]   # ~15x slower than its peers
        procs.append(subprocess.Popen(cmd, env=_env(),
                                      stdout=subprocess.PIPE,
                                      stderr=subprocess.PIPE, text=True))
    oracles = {}
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err[-3000:]
        line = [ln for ln in out.splitlines()
                if ln.startswith("FLEET-ORACLE: ")][-1]
        o = json.loads(line[len("FLEET-ORACLE: "):])
        oracles[o["host_id"]] = o

    assert _wait(lambda: all(r.shutdown
                             for r in agg.merged().hosts.values())
                 and len(agg.merged().hosts) == 3)
    spec = build_spec()
    head = FleetHead(agg, spec=spec, jsonl_path=str(tmp_path / "fleet.jsonl"))
    snap = head.write_report()

    # every host compiled the same plans — and the wire agrees
    fps = {o["fingerprint"] for o in oracles.values()}
    assert fps == {spec.fingerprint} == {snap["fingerprint"]}
    assert snap["n_hosts"] == 3
    assert snap["dropped"] == 0

    # exact fleet sums == sum of per-host drained deltas (agent oracles)
    oracle_calls = np.sum([o["shipped_calls"] for o in oracles.values()],
                          axis=0)
    assert snap["calls"] == [int(c) for c in oracle_calls]
    oracle_vals = np.sum([o["shipped_values"] for o in oracles.values()],
                         axis=0)
    fleet_vals = np.array([ln["sum"] for ln in snap["lanes"]])
    np.testing.assert_allclose(fleet_vals, oracle_vals, rtol=1e-9)
    oracle_samp = np.sum([o["shipped_samples"] for o in oracles.values()],
                         axis=0)
    assert [ln["samples"] for ln in snap["lanes"]] == \
        [int(s) for s in oracle_samp]

    # percentiles match the merged-reservoir oracle (all interval means fit
    # in k=256, so the reservoir is exhaustive — only f32 wire rounding)
    labels = plan_lib.lane_slot_ids(spec)
    checked = 0
    for i, lane in enumerate(snap["lanes"]):
        merged = np.concatenate([
            np.asarray(o["lane_means"][i], np.float64)
            for o in oracles.values() if o["lane_means"]])
        if not lane["reservoir_n"] or not len(merged):
            continue
        assert lane["reservoir_seen"] == len(merged), labels[i]
        got = [lane["p50"], lane["p95"], lane["p99"]]
        want = np.percentile(merged, [50, 95, 99])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6,
                                   err_msg=str(labels[i]))
        checked += 1
    assert checked >= 8        # 12 lanes; NaN/Inf lanes may be all-zero

    # the straggler is flagged — and only the straggler
    assert snap["stragglers"] == ["h2"], snap["hosts"]
    assert oracles["h2"]["straggler_fired"]

    # per-host frame accounting agrees end to end
    for hid, o in oracles.items():
        assert snap["hosts"][hid]["frames"] == o["agent"]["frames_sent"]
        assert snap["hosts"][hid]["lost_frames"] == 0
        assert snap["hosts"][hid]["shutdown"] is True

    # the JSONL fleet report parses back
    lines = (tmp_path / "fleet.jsonl").read_text().splitlines()
    assert len(lines) == 1
    assert json.loads(lines[0])["n_hosts"] == 3
    agg.close()


@pytest.mark.slow
def test_killed_host_degrades_gracefully():
    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    _, port = agg.address
    victim = subprocess.Popen(
        [sys.executable, "-m", "repro.telemetry.simhost",
         "--host-id", "victim", "--port", str(port),
         "--steps", "100000", "--cadence", "1", "--pace-s", "0.02"],
        env=_env(), stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    survivor = subprocess.Popen(
        [sys.executable, "-m", "repro.telemetry.simhost",
         "--host-id", "survivor", "--port", str(port),
         "--steps", "20", "--cadence", "2", "--pace-s", "0.004"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True)
    try:
        # wait until the victim has shipped at least a few frames, then
        # kill it mid-run — no shutdown frame, connection drops hard
        assert _wait(lambda: agg.merged().hosts.get("victim") is not None
                     and agg.merged().hosts["victim"].frames >= 3,
                     timeout=180)
        victim.kill()
        victim.wait(timeout=30)
        out, err = survivor.communicate(timeout=300)
        assert survivor.returncode == 0, err[-3000:]

        # no hang: the head still answers, the survivor completed cleanly
        head = FleetHead(agg)
        snap = head.snapshot()
        assert snap["hosts"]["survivor"]["shutdown"] is True
        assert snap["hosts"]["victim"]["shutdown"] is False   # died silently
        assert snap["hosts"]["victim"]["frames"] >= 3
        assert snap["n_hosts"] == 2
        # counters remain a consistent prefix — everything that arrived
        assert sum(snap["calls"]) > 0
    finally:
        victim.kill()
        survivor.kill()
        agg.close()


# ---------------------------------------------------------------------------
# device-freedom attestation (runtime half; static half in test_wire.py)
# ---------------------------------------------------------------------------

class _NoDeviceOps:
    """Raising guard: ANY attribute access means device work was attempted."""

    def __getattr__(self, name):
        raise AssertionError(
            f"fleet agent touched jax.{name} on the drain path")


def test_agent_emit_never_dispatches_device_work(monkeypatch):
    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    agent = FleetAgent("h0", agg.address, fingerprint=FP)
    guard = _NoDeviceOps()
    for mod in ("jax", "jax.numpy", "jaxlib"):
        monkeypatch.setitem(sys.modules, mod, guard)
    # emit / flush / close all run with jax unusable — pure host numpy
    for i in range(5):
        agent.emit(_fake_snap(step=2 * i + 2, seed=i))
    agent.flush(2.0)
    agent.close()
    assert agent.frames_encoded == 5
    assert _wait(lambda: agg.merged().frames_in == 6)   # 5 deltas + shutdown
    agg.close()


# ---------------------------------------------------------------------------
# shutdown semantics
# ---------------------------------------------------------------------------

def test_double_close_never_double_sends():
    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    agent = FleetAgent("h0", agg.address, fingerprint=FP)
    agent.emit(_fake_snap())
    agent.close()
    sent = agent.stats()["frames_sent"]
    assert sent == 2                       # one delta + one shutdown frame
    agent.close()                          # second close: no-op
    agent.close()
    assert agent.stats()["frames_sent"] == sent
    assert _wait(lambda: agg.merged().hosts["h0"].shutdown)
    rec = agg.merged().hosts["h0"]
    assert rec.frames == 2 and rec.lost_frames == 0
    # emits after close are dropped with accounting, never sent
    agent.emit(_fake_snap(step=99))
    assert agent.stats()["frames_sent"] == sent
    agg.close()


def test_runtime_graceful_shutdown_flushes_and_sends_final_frame(capsys):
    from repro import core as scalpel
    from repro.telemetry.simhost import build_spec

    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    spec = build_spec()
    rt = scalpel.ScalpelRuntime(spec, hook_every=1, graceful_shutdown=True)
    agent = rt.attach_fleet_agent("h0", agg.address)
    assert rt.fleet_agent is agent
    state = scalpel.CounterState.zeros(spec)
    for _ in range(3):
        rt.on_step(state)
    rt.flush()
    rt.shutdown()                          # report + close: flush + final
    sent = agent.stats()["frames_sent"]
    rt.shutdown()                          # idempotent with close()
    rt.close()
    assert agent.stats()["frames_sent"] == sent
    assert _wait(lambda: agg.merged().hosts.get("h0") is not None
                 and agg.merged().hosts["h0"].shutdown)
    assert agg.merged().hosts["h0"].frames == sent
    # the shutdown report carries the telemetry-health footer
    out = capsys.readouterr().out
    assert "telemetry:" in out and "fleet[sent=" in out
    agg.close()


# ---------------------------------------------------------------------------
# drop accounting: bounded buffer, reconnects, plane surface
# ---------------------------------------------------------------------------

def test_bounded_buffer_drops_oldest_with_accounting():
    # no listener on this port: every frame queues; the buffer bounds it
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    port = sock.getsockname()[1]
    sock.close()                           # nothing listens here now
    agent = FleetAgent("h0", ("127.0.0.1", port), fingerprint=FP,
                       max_buffer=2, connect_timeout=0.1, backoff_s=0.01,
                       backoff_max_s=0.05)
    for i in range(10):
        agent.emit(_fake_snap(step=i + 1, seed=i))
    assert agent.frames_encoded == 10
    assert agent.dropped_frames >= 7       # bounded at 2 (+1 in flight)
    agent.close(flush_timeout=0.2)
    st = agent.stats()
    # everything encoded was either sent (it can't be) or accounted dropped
    assert st["frames_sent"] == 0
    assert st["dropped_frames"] == 11      # 10 deltas + the shutdown frame
    assert st["connected"] is False


def test_seq_gaps_from_buffer_drops_visible_at_aggregator():
    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    agent = FleetAgent("h0", agg.address, fingerprint=FP)
    # simulate loss: encode seqs 0..5 but only deliver 0, 3, 5
    frames = []
    orig_send = agent._link.send
    agent._link.send = lambda b, force=False: frames.append(b)
    for i in range(6):
        agent.emit(_fake_snap(step=i + 1, seed=i))
    agent._link.send = orig_send
    for i in (0, 3, 5):
        agent._link.send(frames[i])
    agent._link.flush(5.0)
    assert _wait(lambda: agg.merged().hosts.get("h0") is not None
                 and agg.merged().hosts["h0"].frames == 3)
    assert agg.merged().hosts["h0"].lost_frames == 3
    assert agg.merged().dropped == 3
    agent._link.close(1.0)
    agg.close()


def test_plane_stats_surfaces_sink_and_agent_accounting():
    from repro import core as scalpel
    from repro.testing.faults import FailingSink
    from repro.telemetry.simhost import build_spec

    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    spec = build_spec()
    rt = scalpel.ScalpelRuntime(spec, hook_every=1)
    rt.attach_fleet_agent("h0", agg.address)
    failing = rt.telemetry.add_sink(FailingSink(fail_first=1))
    state = scalpel.CounterState.zeros(spec)
    rt.on_step(state)
    rt.flush()
    st = rt.telemetry.stats()
    # uniform surface: drain counters, per-sink errors, agent extras
    assert st["drain_count"] >= 1
    assert any(v >= 1 for v in st["sink_errors"].values()), st
    agent_entries = [v for v in st["sinks"].values()
                     if v.get("host_id") == "h0"]
    assert len(agent_entries) == 1
    a = agent_entries[0]
    assert {"frames_sent", "dropped_frames", "reconnects"} <= set(a)
    assert failing.attempts >= 1
    footer = rt._telemetry_footer()
    assert "sink_errors=" in footer and "fleet[" in footer
    rt.close()
    agg.close()


def test_reconnect_backoff_recovers_and_counts():
    # an aggregator that appears only after the agent started sending
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    port = sock.getsockname()[1]
    sock.close()
    agent = FleetAgent("h0", ("127.0.0.1", port), fingerprint=FP,
                       connect_timeout=0.2, backoff_s=0.02,
                       backoff_max_s=0.1)
    agent.emit(_fake_snap(step=2))
    time.sleep(0.3)                        # a few failed connect rounds
    agg = Aggregator(("127.0.0.1", port), node_id="late").serve()
    assert _wait(lambda: agent.stats()["frames_sent"] == 1)
    agent.close()
    assert _wait(lambda: agg.merged().hosts.get("h0") is not None
                 and agg.merged().hosts["h0"].shutdown)
    assert agg.merged().hosts["h0"].lost_frames == 0   # nothing was lost
    agg.close()


# ---------------------------------------------------------------------------
# tree composition + hints
# ---------------------------------------------------------------------------

def test_tree_child_push_is_cumulative_not_double_counted():
    root = Aggregator(("127.0.0.1", 0), node_id="root", seed=1).serve()
    child = Aggregator(("127.0.0.1", 0), node_id="child0",
                       parent=root.address, seed=2).serve()
    a0 = FleetAgent("h0", child.address, fingerprint=FP)
    a1 = FleetAgent("h1", root.address, fingerprint=FP)
    for i in range(4):
        a0.emit(_fake_snap(step=i + 1, seed=i))
        a1.emit(_fake_snap(step=i + 1, seed=100 + i))
    a0.flush(5.0)
    a1.flush(5.0)
    assert _wait(lambda: child.merged().frames_in == 4
                 and len(root.merged().hosts) >= 1)
    child.push()
    assert _wait(lambda: root.merged().n_hosts == 2)
    want_calls = sum(
        np.asarray(_fake_snap(seed=s).delta.calls, np.int64)
        for s in [0, 1, 2, 3, 100, 101, 102, 103])
    view = root.merged()
    np.testing.assert_array_equal(view.calls, want_calls)
    assert view.frames_in == 8
    # cumulative re-push: totals must NOT change
    child.push()
    child.push()
    time.sleep(0.3)
    np.testing.assert_array_equal(root.merged().calls, want_calls)
    # reservoirs carried through the tree, weighted by seen
    assert view.reservoirs[0].seen == sum(
        1 for s in [0, 1, 2, 3, 100, 101, 102, 103]
        if _fake_snap(seed=s).delta.samples[0] > 0)
    a0.close()
    a1.close()
    child.close()
    root.close()


def test_hint_downlink_reaches_controller_through_tree():
    from repro.core.adaptive import SENTINEL, AdaptiveConfig, \
        AdaptiveController
    from repro.core.telemetry import TelemetryPlane
    from repro.telemetry.simhost import build_spec

    root = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    child = Aggregator(("127.0.0.1", 0), node_id="child0",
                       parent=root.address).serve()
    spec = build_spec()
    plane = TelemetryPlane(spec, cadence=1)
    ctl = AdaptiveController(spec=spec, telemetry=plane,
                             config=AdaptiveConfig()).install()
    agent = FleetAgent("h0", child.address, fingerprint=spec.fingerprint,
                       controller=ctl)
    agent.emit(_fake_snap(step=2, fingerprint=spec.fingerprint))
    agent.flush(5.0)
    assert _wait(lambda: child.merged().frames_in == 1)
    child.push()       # opens the child→root uplink (hints ride it back)
    assert _wait(lambda: len(root.merged().hosts) == 1)

    head = FleetHead(root, spec=spec)
    head.broadcast_hint("layer/mlp", "fleet:nan_count", tripwire=True)
    assert _wait(lambda: ctl.stats["fleet_hints"] >= 1), ctl.stats
    assert ctl.levels["layer/mlp"] == "wide"

    # a global hint wakes sentinel scopes (the step-time-wake move)
    ctl._level[0] = SENTINEL
    head.broadcast_hint("", "fleet:step_time", tripwire=True)
    assert _wait(lambda: ctl.stats["fleet_hints"] >= 2), ctl.stats
    assert ctl.levels[spec.scopes[0]] == "configured"
    agent.close()
    child.close()
    root.close()
    plane.close()


def test_apply_fleet_hint_gating():
    from repro.core.adaptive import AdaptiveConfig, AdaptiveController
    from repro.core.telemetry import TelemetryPlane
    from repro.telemetry.simhost import build_spec

    spec = build_spec()
    plane = TelemetryPlane(spec, cadence=1)
    ctl = AdaptiveController(
        spec=spec, telemetry=plane,
        config=AdaptiveConfig(accept_fleet_hints=False))
    assert not ctl.apply_fleet_hint("layer/mlp", reason="x", tripwire=True)
    assert ctl.stats["fleet_hints"] == 0
    assert ctl.stats["fleet_hints_ignored"] == 1
    assert ctl.levels["layer/mlp"] == "configured"    # unchanged

    ctl2 = AdaptiveController(spec=spec, telemetry=plane,
                              config=AdaptiveConfig())
    # a scope this process doesn't monitor: ignored, not an error
    assert not ctl2.apply_fleet_hint("no/such/scope", reason="x")
    assert ctl2.stats["fleet_hints_ignored"] == 1
    assert ctl2.apply_fleet_hint("layer/attn", reason="y", tripwire=True)
    assert ctl2.levels["layer/attn"] == "wide"
    plane.close()


def test_auto_hints_fire_once_per_tripwire_tick():
    from repro.telemetry.simhost import build_spec

    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    spec = build_spec()
    agent = FleetAgent("h0", agg.address, fingerprint=spec.fingerprint)
    snap = _fake_snap(step=2, fingerprint=spec.fingerprint)
    # lane 2 of scope 0 is layer/attn NAN_COUNT (EVENTS order in simhost)
    snap.delta.values[:] = 0.0
    snap.delta.samples[:] = 1
    snap.delta.values[2] = 3.0             # 3 NaN ticks this interval
    agent.emit(snap)
    agent.flush(5.0)
    assert _wait(lambda: agg.merged().frames_in == 1)
    head = FleetHead(agg, spec=spec)
    sent = head.auto_hints()
    assert sent == [("layer/attn", "fleet:nan_count")]
    assert head.auto_hints() == []         # same tick: no re-broadcast
    agent.close()
    agg.close()


# ---------------------------------------------------------------------------
# socket-level rejection accounting
# ---------------------------------------------------------------------------

def test_version_skew_on_stream_accounted_and_connection_dropped():
    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    buf = bytearray(wire.encode_delta(
        [1], [1.0], [1], host_id="h9", seq=0, fingerprint=FP,
        step_lo=-1, step_hi=1))
    buf[2] = wire.WIRE_VERSION + 1         # a sender from the future
    with socket.create_connection(agg.address, timeout=5) as s:
        s.sendall(wire.pack_frame(bytes(buf)))
        assert _wait(lambda: agg.stats()["rejected_version"] == 1)
        assert s.recv(1) == b""            # aggregator dropped the conn
    assert agg.merged().frames_in == 0
    assert agg.dropped == 1
    agg.close()


def test_corrupt_stream_accounted():
    agg = Aggregator(("127.0.0.1", 0), node_id="root").serve()
    good = wire.encode_delta([1], [1.0], [1], host_id="h9", seq=0,
                             fingerprint=FP, step_lo=-1, step_hi=1)
    bad = bytearray(good)
    bad[-6] ^= 0x55                        # payload tamper: CRC fails
    with socket.create_connection(agg.address, timeout=5) as s:
        s.sendall(wire.pack_frame(bytes(bad)))
        assert _wait(lambda: agg.stats()["rejected_corrupt"] == 1)
    assert agg.dropped == 1
    agg.close()
