"""Event registry: every in-graph event vs a numpy reference."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events
from repro.core.context import EventSpec


@pytest.fixture()
def x():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(4, 8)).astype(np.float32)
    a[1, 2] = 0.0
    return a


def _ev(name, tensors, sub=""):
    key = next(iter(tensors))
    return float(
        events.compute(EventSpec(name, tensor=key, subevent=sub), {
            k: jnp.asarray(v) for k, v in tensors.items()
        })
    )


def test_act_rms(x):
    assert _ev("ACT_RMS", {"x": x}) == pytest.approx(
        np.sqrt((x ** 2).mean()), rel=1e-5)


def test_act_mean_abs(x):
    assert _ev("ACT_MEAN_ABS", {"x": x}) == pytest.approx(
        np.abs(x).mean(), rel=1e-5)


def test_act_max_abs(x):
    assert _ev("ACT_MAX_ABS", {"x": x}) == pytest.approx(
        np.abs(x).max(), rel=1e-6)


def test_zero_frac(x):
    assert _ev("ACT_ZERO_FRAC", {"x": x}) == pytest.approx(
        (x == 0).mean(), abs=1e-7)


def test_nan_inf_count():
    a = np.array([[np.nan, 1.0, np.inf], [-np.inf, 2.0, np.nan]], np.float32)
    assert _ev("NAN_COUNT", {"x": a}) == 2.0
    assert _ev("INF_COUNT", {"x": a}) == 2.0


def test_numel(x):
    assert _ev("NUMEL", {"x": x}) == x.size


def test_l2norm_mean(x):
    assert _ev("L2NORM", {"x": x}) == pytest.approx(
        np.linalg.norm(x), rel=1e-5)
    assert _ev("MEAN", {"x": x}) == pytest.approx(x.mean(), abs=1e-6)


def test_attn_entropy_uniform():
    p = np.full((2, 3, 4), 0.25, np.float32)  # uniform over last axis
    assert _ev("ATTN_ENTROPY", {"p": p}) == pytest.approx(
        np.log(4.0), rel=1e-4)


def test_moe_load_subevents():
    probs = np.array(
        [[0.7, 0.2, 0.1], [0.6, 0.3, 0.1], [0.5, 0.4, 0.1]], np.float32
    )
    t = {"router_probs": jnp.asarray(probs)}
    load = probs.mean(0)
    spec = lambda s: EventSpec("MOE_LOAD", subevent=s)
    assert float(events.compute(spec("MAX_FRAC"), t)) == pytest.approx(
        load.max() * 3, rel=1e-5)
    assert float(events.compute(spec("MIN_FRAC"), t)) == pytest.approx(
        load.min() * 3, rel=1e-5)
    assert float(events.compute(spec("CV"), t)) == pytest.approx(
        load.std() / load.mean(), rel=1e-4)


def test_moe_load_with_expert_mask():
    probs = np.full((4, 2), 0.5, np.float32)
    mask = np.array([[1, 0], [1, 0], [1, 0], [0, 1]], np.float32)
    t = {"router_probs": jnp.asarray(probs), "expert_mask": jnp.asarray(mask)}
    v = float(events.compute(EventSpec("MOE_LOAD", subevent="MAX_FRAC"), t))
    assert v == pytest.approx(0.75 * 2, rel=1e-5)


def test_extensive_vs_intensive_tags():
    assert events.kind_of(EventSpec("NAN_COUNT")) == events.EXTENSIVE
    assert events.kind_of(EventSpec("ACT_RMS")) == events.INTENSIVE


def test_computable_logic():
    # tensor-bound slot needs its tensor present
    assert events.computable(EventSpec("ACT_RMS", "out"), {"out"})
    assert not events.computable(EventSpec("ACT_RMS", "out"), {"x"})
    # unbound slot only computable from a single-tensor probe
    assert events.computable(EventSpec("ACT_RMS"), {"x"})
    assert not events.computable(EventSpec("ACT_RMS"), {"x", "y"})
    # dict event requires its named tensors
    assert events.computable(
        EventSpec("MOE_LOAD", subevent="CV"), {"router_probs"})
    assert not events.computable(
        EventSpec("MOE_LOAD", subevent="CV"), {"out"})


def test_unknown_event_raises():
    with pytest.raises(KeyError, match="unknown event"):
        events.lookup("NOPE")


def test_compute_requires_tensor_qualifier_when_ambiguous():
    with pytest.raises(KeyError, match="qualifier"):
        events.compute(
            EventSpec("ACT_RMS"), {"a": jnp.ones(3), "b": jnp.ones(3)}
        )
