"""Functional Monitor transformation: one MonitorState pytree, compact
counters end-to-end, plan dedup, checkpoint attestation, deprecation shim."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import core as scalpel
from repro.core import plan as plan_lib
from repro.core import report as report_lib
from repro.core import telemetry as T
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams


def _spec():
    return MonitorSpec.of([
        ScopeContext.multiplexed("hot", [
            [EventSpec("MEAN", "x")],
            [EventSpec("L2NORM", "x")],
        ]),
        ScopeContext.exhaustive("cold", [EventSpec("ACT_RMS", "x"),
                                         EventSpec("NUMEL", "x")]),
    ])


def _work(x):
    for i in range(4):
        with scalpel.function("hot"):
            scalpel.probe(x=x * (i + 1))
    with scalpel.function("cold"):
        scalpel.probe(x=x + 1)
    return x * 2


def _manual_state(spec, params, x, steps=1):
    """The deprecated hand-threaded baseline (shim keeps it working)."""
    s = CounterState.zeros(spec)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)

        @jax.jit
        def step(s, params, x):
            with scalpel.collecting(spec, params, s) as col:
                _work(x)
            return s.add(col.delta)

        for _ in range(steps):
            s = step(s, params, x)
    return s


# ---------------------------------------------------------------------------
# wrap: the functional transformation
# ---------------------------------------------------------------------------

def test_wrap_matches_manual_collecting_path():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    step = jax.jit(mon.wrap(_work))
    ms = mon.init()
    x = jnp.arange(6.0)
    for _ in range(3):
        out, ms = step(ms, x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x * 2))
    want = _manual_state(spec, mon.params, x, steps=3)
    got = mon.counter_state(ms)
    np.testing.assert_array_equal(np.asarray(got.calls),
                                  np.asarray(want.calls))
    np.testing.assert_allclose(np.asarray(got.values),
                               np.asarray(want.values), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got.samples),
                                  np.asarray(want.samples))
    assert int(ms.step) == 3


def test_wrap_state_is_compact_not_padded():
    # uneven scope widths: the padded block would be 3x6=18 lanes; the
    # MonitorState carries exactly the 7 live lanes
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("wide", [
            EventSpec(e, "x") for e in
            ("MEAN", "L2NORM", "ACT_RMS", "ACT_MAX_ABS", "NAN_COUNT",
             "INF_COUNT")
        ]),
        ScopeContext.exhaustive("narrow", [EventSpec("MEAN", "x")]),
        ScopeContext.exhaustive("dark", []),
    ])
    lay = plan_lib.spec_layout(spec)
    mon = scalpel.Monitor(spec, counter_axes=())
    ms = mon.init()
    assert ms.values.shape == (lay.total,)
    assert ms.samples.shape == (lay.total,)
    assert lay.total == 7
    assert lay.total < spec.n_scopes * spec.max_slots
    assert ms.fingerprint == spec.fingerprint


def test_wrap_param_swap_in_state_never_retraces():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    traces = []

    def fn(x):
        traces.append(1)
        return _work(x)

    step = jax.jit(mon.wrap(fn))
    ms = mon.init()
    x = jnp.ones(4)
    _, ms = step(ms, x)
    # flip the monitored subset INSIDE the state pytree: same compiled step
    ms = mon.sync(ms, params=MonitorParams.selective(spec, ["cold"]))
    _, ms = step(ms, x)
    ms = mon.sync(ms, params=MonitorParams.all_off(spec))
    _, ms = step(ms, x)
    assert len(traces) == 1
    assert step._cache_size() == 1
    # the masked-off step intercepted but sampled nothing new
    est = mon.estimates(ms)
    assert int(ms.calls[0]) == 12      # 4 hot calls x 3 steps
    assert np.isfinite(est["cold"]["ACT_RMS:x"])


def test_wrap_multiplex_schedule_continues_across_steps():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    step = jax.jit(mon.wrap(_work))
    ms = mon.init()
    for _ in range(2):
        _, ms = step(ms, jnp.ones(4))
    # 8 hot calls alternate sets exactly: 4 MEAN samples, 4 L2NORM samples
    lane = spec.slot_lane
    assert int(ms.samples[lane("hot", "MEAN:x")]) == 4
    assert int(ms.samples[lane("hot", "L2NORM:x")]) == 4


def test_wrap_threads_scan_with_counters():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())

    def fn(xs):
        def body(c, x):
            with scalpel.function("hot"):
                scalpel.probe(x=x)
            return c + 1.0, x

        c, _ = scalpel.scan_with_counters(body, jnp.zeros(()), xs)
        return c

    step = jax.jit(mon.wrap(fn))
    ms = mon.init()
    out, ms = step(ms, jnp.ones((6, 2)))
    assert float(out) == 6.0
    assert int(ms.calls[0]) == 6
    assert int(ms.samples[0] + ms.samples[1]) == 6


def test_monitor_jit_matches_wrap_and_reuses_knob_objects():
    """Monitor.jit == jax.jit(wrap) semantically, but the runtime knobs
    (params/tparams) come back as the caller's SAME objects — they never
    round-trip the compiled graph as outputs."""
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    jstep = mon.jit(_work)
    wstep = jax.jit(mon.wrap(_work))
    a, b = mon.init(), mon.init()
    x = jnp.arange(4.0)
    for _ in range(2):
        out_j, a = jstep(a, x)
        out_w, b = wstep(b, x)
    np.testing.assert_array_equal(np.asarray(out_j), np.asarray(out_w))
    for la, lb in zip((a.calls, a.values, a.samples),
                      (b.calls, b.values, b.samples)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6)
    assert int(a.step) == int(b.step) == 2
    # identity: the knob objects pass through the host-side wrapper
    ms0 = mon.init()
    _, ms1 = jstep(ms0, x)
    assert ms1.params is ms0.params
    assert ms1.tparams is ms0.tparams


def test_monitored_decorator():
    spec = _spec()

    @scalpel.monitored(spec, counter_axes=())
    def step(x):
        return _work(x)

    ms = step.init()
    out, ms = jax.jit(step)(ms, jnp.ones(3))
    assert int(ms.calls[1]) == 1
    assert step.monitor.spec is spec


def test_wrap_with_telemetry_ring_drains_compact_snapshots():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=4, cadence=1, interval_s=60.0)
    drained = []
    plane.add_sink(T.CallbackSink(drained.append))
    mon = scalpel.Monitor(spec, telemetry=plane, counter_axes=())
    step = jax.jit(mon.wrap(_work))
    ms = mon.init()
    assert ms.ring is not None
    for _ in range(3):
        _, ms = step(ms, jnp.ones(4))
        plane.publish(ms.ring)
        plane.flush()
    assert [s.step for s in drained] == [1, 2, 3]
    # snapshots are compact and reports read them directly
    last = drained[-1]
    assert np.asarray(last.state.values).ndim == 1
    est = report_lib.estimates(spec, last.state)
    # NUMEL is extensive: 4 elements/call x 3 calls, exhaustively covered
    assert est["cold"]["NUMEL:x"] == pytest.approx(12.0)
    # delta decoding works on the compact layout too
    assert int(last.delta.calls[0]) == 4
    plane.close()


def test_wrap_cadence_rides_in_state_no_retrace():
    spec = _spec()
    plane = T.TelemetryPlane(spec, depth=8, cadence=1, interval_s=60.0)
    mon = scalpel.Monitor(spec, telemetry=plane, counter_axes=())
    traces = []

    def fn(x):
        traces.append(1)
        return x

    step = jax.jit(mon.wrap(fn))
    ms = mon.init()
    for i in range(2):
        _, ms = step(ms, jnp.ones(2))
    plane.set_cadence(3)
    ms = mon.sync(ms, tparams=plane.params)
    for i in range(4):
        _, ms = step(ms, jnp.ones(2))
    assert len(traces) == 1 and step._cache_size() == 1
    plane.publish(ms.ring)
    steps = sorted(s.step for s in plane.flush())
    assert steps == [1, 2, 3, 6]
    plane.close()


# ---------------------------------------------------------------------------
# plan deduplication (identical sweeps share a switch branch body)
# ---------------------------------------------------------------------------

def test_identical_sets_share_branch_body():
    ctx = ScopeContext.multiplexed("s", [
        [EventSpec("ACT_RMS", "x")],
        [EventSpec("ACT_RMS", "x")],
        [EventSpec("ACT_MAX_ABS", "x")],
        [EventSpec("ACT_RMS", "x")],
    ])
    sp = plan_lib.compile_scope_plans(ctx, frozenset({"x"}))
    assert sp.n_sets == 4
    assert sp.n_branches == 2
    assert sp.plans_deduped == 2
    assert sp.branch_index == (0, 0, 1, 0)
    # the member table still points every set at its own scatter lane
    assert [p.members for p in sp.plans] == [(0,), (1,), (2,), (3,)]


def test_deduped_plans_count_in_describe():
    spec = MonitorSpec.of([ScopeContext.multiplexed("s", [
        [EventSpec("MEAN", "x")], [EventSpec("MEAN", "x")],
    ])])
    text = plan_lib.describe_plans(spec)
    assert "plans_deduped: 1" in text
    assert "1 branch bodies" in text


def test_deduped_execution_matches_schedule():
    """Sets sharing one branch body must still scatter into their OWN slots
    on the exact multiplex schedule."""
    spec = MonitorSpec.of([ScopeContext.multiplexed("s", [
        [EventSpec("MEAN", "x")],
        [EventSpec("MEAN", "x")],
        [EventSpec("MEAN", "x")],
    ])])
    mon = scalpel.Monitor(spec, counter_axes=())

    def fn(x):
        for i in range(7):
            with scalpel.function("s"):
                scalpel.probe(x=x * (i + 1))
        return x

    _, ms = jax.jit(mon.wrap(fn))(mon.init(), jnp.ones(2))
    # call c lands in set c % 3; MEAN of x*(c+1) over ones is c+1
    want = [[1.0, 4.0, 7.0], [2.0, 5.0], [3.0, 6.0]]
    for k in range(3):
        assert float(ms.values[k]) == pytest.approx(sum(want[k]))
        assert int(ms.samples[k]) == len(want[k])


def test_dedup_table_is_part_of_plan_identity():
    """Two specs that differ only in whether their sets dedup must not
    collide (the fingerprint hashes the branch-body table)."""
    dup = MonitorSpec.of([ScopeContext.multiplexed("s", [
        [EventSpec("MEAN", "x")], [EventSpec("MEAN", "x")],
    ])])
    distinct = MonitorSpec.of([ScopeContext.multiplexed("s", [
        [EventSpec("MEAN", "x")], [EventSpec("L2NORM", "x")],
    ])])
    assert dup.fingerprint != distinct.fingerprint


# ---------------------------------------------------------------------------
# compact layout round-trips (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(0, 4), min_size=1, max_size=5),  # slots per scope
    st.integers(0, 2 ** 31 - 1),                          # value seed
)
def test_compact_roundtrip_property(widths, seed):
    """CounterState -> compact -> CounterState is the identity for ANY
    scope-width profile (including empty scopes)."""
    events = ["MEAN", "L2NORM", "ACT_RMS", "ACT_MAX_ABS"]
    ctxs = [
        ScopeContext.exhaustive(
            f"s{i}", [EventSpec(events[j % len(events)], f"t{j}")
                      for j in range(w)]
        )
        for i, w in enumerate(widths)
    ]
    spec = MonitorSpec.of(ctxs)
    rng = np.random.RandomState(seed % (2 ** 32 - 1))
    n, m = spec.n_scopes, spec.max_slots
    state = CounterState(
        calls=jnp.asarray(rng.randint(0, 100, (n,)), jnp.int32),
        values=jnp.asarray(rng.randn(n, m), jnp.float32),
        samples=jnp.asarray(rng.randint(0, 50, (n, m)), jnp.int32),
    )
    # zero the padding lanes: they are not representable compactly (and the
    # probe path never writes them)
    lay = plan_lib.spec_layout(spec)
    mask = np.zeros((n, m), np.float32)
    for i, w in enumerate(lay.widths):
        mask[i, :w] = 1.0
    state = CounterState(
        calls=state.calls,
        values=state.values * mask,
        samples=(state.samples * mask).astype(jnp.int32),
    )
    compact = state.compact(spec)
    assert compact.values.shape == (lay.total,)
    back = CounterState.from_compact(spec, compact)
    np.testing.assert_array_equal(np.asarray(back.calls),
                                  np.asarray(state.calls))
    np.testing.assert_allclose(np.asarray(back.values),
                               np.asarray(state.values))
    np.testing.assert_array_equal(np.asarray(back.samples),
                                  np.asarray(state.samples))
    # and reports built from either carrier agree slot-for-slot
    a = report_lib.estimates(spec, state)
    b = report_lib.estimates(spec, compact)
    for scope in a:
        for slot, v in a[scope].items():
            np.testing.assert_allclose(b[scope][slot], v, rtol=1e-6,
                                       equal_nan=True)


def test_monitorstate_roundtrips_through_legacy_counterstate():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    _, ms = jax.jit(mon.wrap(_work))(mon.init(), jnp.ones(4))
    padded = mon.counter_state(ms)
    again = padded.compact(spec)
    np.testing.assert_allclose(np.asarray(again.values),
                               np.asarray(ms.values))
    np.testing.assert_array_equal(np.asarray(again.samples),
                                  np.asarray(ms.samples))
    np.testing.assert_array_equal(np.asarray(again.calls),
                                  np.asarray(ms.calls))


# ---------------------------------------------------------------------------
# checkpoint attestation + runtime close semantics (satellites)
# ---------------------------------------------------------------------------

def test_sched_calls_base_and_checkpoint_roundtrip():
    """A non-reducing monitor needs no separate schedule base (``calls``
    IS per-shard); a reducible one carries ``sched_calls``, equal to
    ``calls`` when no axis ends up bound — and either way the checkpoint
    payload resumes the multiplex phase exactly."""
    spec = _spec()
    # no reduction: calls doubles as the base, no redundant lanes carried
    mon0 = scalpel.Monitor(spec, counter_axes=())
    assert mon0.init().sched_calls is None
    # reducible monitor on an unbound axis: sched tracks calls exactly
    mon = scalpel.Monitor(spec, counter_axes=("data",))
    step = jax.jit(mon.wrap(_work))
    ms = mon.init()
    for _ in range(3):
        _, ms = step(ms, jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(ms.sched_calls),
                                  np.asarray(ms.calls))
    payload = mon.checkpoint_payload(ms)
    assert "sched_calls" in payload
    back = mon.restore(mon.init(), payload)
    np.testing.assert_array_equal(np.asarray(back.sched_calls),
                                  np.asarray(ms.sched_calls))
    # resumed schedule continues exactly where the original left off
    _, a = step(ms, jnp.ones(4))
    _, b = step(back, jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))


def test_save_metadata_and_check_resume():
    spec = _spec()
    mon = scalpel.Monitor(spec, counter_axes=())
    ms = mon.init()
    meta = ms.save_metadata()
    assert meta["plan_fingerprint"] == spec.fingerprint
    assert mon.check_resume(meta) is True
    assert mon.check_resume({}) is None          # pre-fingerprint ckpt
    bad = dict(meta, plan_fingerprint="0" * 40)
    with pytest.raises(RuntimeError, match="plan"):
        mon.check_resume(bad)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert mon.check_resume(bad, strict=False) is False
    assert any("plan" in str(x.message) for x in w)


def test_runtime_resume_metadata_check():
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec)
    meta = rt.save_metadata()
    assert rt.check_resume_metadata(meta) is True
    assert rt.check_resume_metadata(None) is None
    with pytest.raises(RuntimeError, match="plan mismatch"):
        rt.check_resume_metadata({"plan_fingerprint": "f" * 40})
    rt.close()


def test_runtime_close_idempotent_and_exit_report_skips(capsys):
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec, report_at_exit=True)
    rt.on_step(CounterState.zeros(spec))
    rt.close()
    assert rt.closed
    rt.close()                    # second close: no-op, no error
    capsys.readouterr()
    rt._exit_report()             # the atexit pass after an explicit close
    assert capsys.readouterr().out == ""   # ...prints nothing (no re-flush)


def test_exit_report_still_prints_without_close(capsys):
    spec = _spec()
    rt = scalpel.ScalpelRuntime(spec, report_at_exit=True)
    rt.on_step(CounterState.zeros(spec))
    capsys.readouterr()
    rt._exit_report()
    assert "ScALPEL report" in capsys.readouterr().out
    rt.close()


# ---------------------------------------------------------------------------
# the deprecation shim
# ---------------------------------------------------------------------------

def test_collecting_shim_warns_and_still_works():
    spec = _spec()
    params = MonitorParams.all_on(spec)
    state = CounterState.zeros(spec)
    with pytest.warns(DeprecationWarning, match="Monitor"):
        with scalpel.collecting(spec, params, state) as col:
            with scalpel.function("cold"):
                scalpel.probe(x=jnp.ones(3))
        state = state.add(col.delta)
    assert int(state.calls[spec.scope_index("cold")]) == 1


def test_gated_trees_free_of_deprecated_calls():
    """The CI grep-gate, run in-process: src/ and examples/ must not call
    collecting() outside the shim's own definition."""
    import pathlib
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_deprecated
        assert check_deprecated.violations(root) == []
    finally:
        sys.path.pop(0)
