"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 CPU device;
only launch/dryrun.py (its own process) forces 512 placeholder devices."""
import importlib.util
import os
import sys

try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"),
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"], sys.modules["hypothesis.strategies"] = (
        _stub.build_modules()
    )

import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


@pytest.fixture()
def rng():
    return jax.random.PRNGKey(0)
