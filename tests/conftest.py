"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 CPU device;
only launch/dryrun.py (its own process) forces 512 placeholder devices."""
import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


@pytest.fixture()
def rng():
    return jax.random.PRNGKey(0)
