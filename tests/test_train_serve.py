"""Integration: training loop (fit), fault-tolerant restart, serving engine.

The restart test is the fault-tolerance contract: kill after step k, resume
from the checkpoint, and the final state must be IDENTICAL to an
uninterrupted run (deterministic data pipeline + exact counter carry).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import model_config
from repro.data import DataConfig
from repro.models.registry import Arch
from repro.optim import OptConfig
from repro.serve.engine import Engine, ServeConfig
from repro.train.loop import TrainLoopConfig, fit


@pytest.fixture(scope="module")
def tiny():
    cfg = model_config("xlstm_125m", smoke=True)
    return Arch(cfg)


def _cfgs(steps=8, **kw):
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=200,
                    weight_decay=0.01)
    data = DataConfig(vocab=512, seq_len=32, global_batch=4)
    loop = TrainLoopConfig(steps=steps, log_every=0, ckpt_every=0,
                           hook_every=4, **kw)
    return opt, data, loop


def test_fit_loss_decreases(tiny):
    opt, data, loop = _cfgs(steps=30)
    out = fit(tiny, opt, data, loop)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.05, (first, last)
    assert np.isfinite(out["final_loss"])
    # ScALPEL counters saw every step
    rep = out["runtime"].snapshot()
    assert all(r.calls > 0 for r in rep)
    assert "ScALPEL report" in out["report"]


def test_fit_restart_bitwise_identical(tiny, tmp_path):
    """Fault tolerance: crash at step 6/12 + resume == uninterrupted run."""
    opt, data, _ = _cfgs()
    d1 = str(tmp_path / "a")
    # uninterrupted run: 12 steps
    full = fit(tiny, opt, data,
               TrainLoopConfig(steps=12, log_every=0, ckpt_every=0,
                               ckpt_dir=None))
    # interrupted: run 6 (checkpointing), then resume to 12
    fit(tiny, opt, data,
        TrainLoopConfig(steps=6, log_every=0, ckpt_every=6, ckpt_dir=d1))
    resumed = fit(tiny, opt, data,
                  TrainLoopConfig(steps=12, log_every=0, ckpt_every=6,
                                  ckpt_dir=d1))
    assert any("restored from step 6" in e for e in resumed["events"])
    for a, b in zip(jax.tree.leaves(full["state"].params),
                    jax.tree.leaves(resumed["state"].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # counters carried exactly too (multiplex schedule unbroken); the
    # MonitorState checkpoints its compact lanes + step stamp
    np.testing.assert_array_equal(
        np.asarray(full["monitor"].calls),
        np.asarray(resumed["monitor"].calls),
    )
    np.testing.assert_allclose(
        np.asarray(full["monitor"].values),
        np.asarray(resumed["monitor"].values), rtol=1e-6)
    assert int(full["monitor"].step) == int(resumed["monitor"].step)
    assert float(full["final_loss"]) == pytest.approx(
        float(resumed["final_loss"]), abs=1e-6)


def test_fit_with_monitor_config_and_jsonl(tiny, tmp_path):
    opt, data, _ = _cfgs()
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(
        "NO_FUNCTIONS=1\n[FUNCTION]\nFUNC_NAME=grads\nNO_EVENTS=0\n"
        "[/FUNCTION]\n"
    )
    jl = tmp_path / "log.jsonl"
    out = fit(tiny, opt, data,
              TrainLoopConfig(steps=4, log_every=0, ckpt_every=0,
                              monitor_config_path=str(cfgp),
                              jsonl_path=str(jl), hook_every=2))
    est = out["runtime"].estimates()
    # only 'grads' monitored; everything else intercept-only
    assert np.isfinite(est["grads"]["MEAN:gnorm"])
    other = [s for s in est if s != "grads"]
    assert all(
        all(np.isnan(v) for v in est[s].values()) for s in other
        if est[s]
    )
    assert jl.exists() and jl.read_text().strip()


def test_microbatched_step_matches_loss_scale(tiny):
    """Gradient accumulation: micro=2 equals micro=1 on the same batch."""
    from repro import core as scalpel
    from repro.data import SyntheticLM
    from repro.train.step import TrainState, build_monitor_spec, \
        make_train_step

    opt = OptConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0,
                    min_lr_frac=1.0)
    data = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    spec = build_monitor_spec(tiny, batch)
    mon = scalpel.Monitor(spec)
    t0 = TrainState.create(tiny, opt, jax.random.PRNGKey(0))
    s1 = jax.jit(make_train_step(tiny, opt, spec, microbatches=1,
                                 monitor=mon))
    s2 = jax.jit(make_train_step(tiny, opt, spec, microbatches=2,
                                 monitor=mon))
    t1, o1, m1 = s1(t0, batch, mon.init())
    t0b = TrainState.create(tiny, opt, jax.random.PRNGKey(0))
    t2, o2, m2 = s2(t0b, batch, mon.init())
    assert float(o1["loss"]) == pytest.approx(float(o2["loss"]), rel=1e-4)
    gn1, gn2 = float(o1["grad_norm"]), float(o2["grad_norm"])
    assert gn1 == pytest.approx(gn2, rel=2e-2)
    # params close (not bitwise: f32 accumulation order differs)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-3, rtol=5e-2)
    # counters: each microbatch is a real call — model scopes fire twice,
    # the step-level 'grads' scope once
    c1 = np.asarray(m1.calls)
    c2 = np.asarray(m2.calls)
    gi = spec.scope_index("grads")
    for i in range(spec.n_scopes):
        assert c2[i] == (c1[i] if i == gi else 2 * c1[i]), (i, c1, c2)


def test_serve_engine_generate(tiny):
    params = tiny.init(jax.random.PRNGKey(0))
    eng = Engine(tiny, params, ServeConfig(cache_len=64, max_new_tokens=6))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              tiny.cfg.vocab)
    out, stats = eng.generate({"tokens": toks})
    assert out.shape == (2, 6)
    assert stats["prefill_s"] > 0
    # counters: decode scopes called >= 6 times
    rep = {r.scope: r for r in eng.runtime.snapshot()}
    assert max(r.calls for r in rep.values()) >= 6
    # greedy decoding is deterministic
    eng2 = Engine(tiny, params, ServeConfig(cache_len=64, max_new_tokens=6))
    out2, _ = eng2.generate({"tokens": toks})
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_serve_seeded_rng_independent(tiny):
    """The RNG strategy Engine.generate documents: a seeded request's
    sampling stream comes from PRNGKey(seed) alone — independent of how
    many unseeded requests advanced the engine RNG in between, and of any
    monitoring plan swap mid-decode (MonitorParams are data-flow-disjoint
    from logits and sampling keys)."""
    from repro.core.counters import MonitorParams

    params = tiny.init(jax.random.PRNGKey(0))
    cfg = ServeConfig(cache_len=64, max_new_tokens=6, temperature=0.8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              tiny.cfg.vocab)
    eng = Engine(tiny, params, cfg)
    a, _ = eng.generate({"tokens": toks}, seed=7)
    # advance the engine's carried RNG with unseeded requests...
    u1, _ = eng.generate({"tokens": toks})
    u2, _ = eng.generate({"tokens": toks})
    # ...and swap the monitoring plan + cadence mid-flight
    eng.runtime.set_params(MonitorParams.all_off(eng.spec))
    eng.runtime.hook_every = 3
    b, _ = eng.generate({"tokens": toks}, seed=7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sampled (temperature) unseeded requests do differ run to run
    assert not np.array_equal(np.asarray(u1), np.asarray(u2))
    # a different seed gives a different stream
    c, _ = eng.generate({"tokens": toks}, seed=8)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_serve_runtime_reconfig_between_steps(tiny, tmp_path):
    params = tiny.init(jax.random.PRNGKey(0))
    eng = Engine(tiny, params, ServeConfig(cache_len=64, max_new_tokens=2))
    # mask everything off mid-flight: next generate still runs, no counters
    from repro.core.counters import MonitorParams

    eng.runtime.set_params(MonitorParams.all_off(eng.spec))
    toks = jnp.ones((1, 8), jnp.int32)
    before = np.asarray(eng.counters.samples).sum()
    out, _ = eng.generate({"tokens": toks})
    after_state = eng.counters
    assert np.asarray(after_state.samples).sum() == before  # no new samples
    assert np.asarray(after_state.calls).sum() > 0          # still counted
