"""Custom-VJP flash attention: forward and gradients vs the reference
(memory-optimal backward — §Perf memory iteration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import model_config
from repro.kernels import ref
from repro.models import layers as L


@pytest.fixture(scope="module")
def cfg():
    return model_config("qwen3_14b", smoke=True).replace(
        flash_block_q=64, flash_block_kv=64, attn_impl="flash_xla"
    )


CASES = [
    # b, sq, sk, h, kvh, d, causal, window
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 128, 256, 2, 2, 32, True, 0),      # kv prefix
    (2, 128, 128, 4, 4, 64, False, 0),     # bidirectional
    (1, 256, 256, 2, 1, 64, True, 64),     # window + MQA
]


def _mk(b, sq, sk, h, kvh, d):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, kvh, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, kvh, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,sq,sk,h,kvh,d,causal,win", CASES)
def test_cvjp_forward(cfg, b, sq, sk, h, kvh, d, causal, win):
    q, k, v = _mk(b, sq, sk, h, kvh, d)
    kr, vr = jnp.repeat(k, h // kvh, 2), jnp.repeat(v, h // kvh, 2)
    want = ref.attention(q, kr, vr, causal=causal, window=win)
    got = L.flash_attention_cvjp(cfg, q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6)


@pytest.mark.parametrize("b,sq,sk,h,kvh,d,causal,win", CASES)
def test_cvjp_grads(cfg, b, sq, sk, h, kvh, d, causal, win):
    q, k, v = _mk(b, sq, sk, h, kvh, d)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.attention(
            q, jnp.repeat(k, h // kvh, 2), jnp.repeat(v, h // kvh, 2),
            causal=causal, window=win)))

    def loss_new(q, k, v):
        return jnp.sum(jnp.sin(L.flash_attention_cvjp(
            cfg, q, k, v, causal=causal, window=win)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(loss_new, argnums=(0, 1, 2))(q, k, v)
    for name, a, b_ in zip("qkv", g_ref, g_new):
        np.testing.assert_allclose(
            np.asarray(b_), np.asarray(a), atol=2e-5,
            err_msg=f"d{name} mismatch",
        )


def test_cvjp_block_shape_invariance(cfg):
    q, k, v = _mk(1, 256, 256, 2, 2, 64)
    outs = []
    for bq, bkv in [(64, 64), (128, 64), (256, 128)]:
        c = cfg.replace(flash_block_q=bq, flash_block_kv=bkv)
        outs.append(L.flash_attention_cvjp(c, q, k, v, causal=True))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=3e-6)


def test_run_attention_head_padding_slices_back(cfg):
    """Padded heads (TP divisibility) must not change the result."""
    from repro.dist.partition import sharding_ctx

    q, k, v = _mk(1, 128, 128, 5, 5, 32)  # 5 heads: never divides 2
    want = ref.attention(q, k, v, causal=True)
    mesh = jax.make_mesh((1,), ("model",))
    with sharding_ctx(mesh):  # tp=1 -> no pad; sanity
        got = L.run_attention(cfg, q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6)
    # force the padded path directly (hpad > h)
    hpad = 8
    padh = ((0, 0), (0, 0), (0, hpad - 5), (0, 0))
    out_pad = L.flash_attention_cvjp(
        cfg, jnp.pad(q, padh), jnp.pad(k, padh), jnp.pad(v, padh),
        causal=True,
    )[:, :, :5]
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(want),
                               atol=3e-6)
