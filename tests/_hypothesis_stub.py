"""Minimal deterministic stand-in for `hypothesis` (not installed here).

Registered by conftest.py into sys.modules only when the real library is
missing.  Implements just the surface the test-suite uses — ``@given`` over
``strategies.{integers, sampled_from, text, lists, composite}`` plus a
no-op ``settings`` — drawing examples from a fixed-seed PRNG so runs are
reproducible.  Shrinking, databases and the rest of hypothesis are out of
scope: on failure you simply see the drawn arguments in the traceback.
"""
from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, gen):
        self._gen = gen

    def example(self, rng: random.Random):
        return self._gen(rng)

    def filter(self, pred, _tries: int = 100):
        def gen(r):
            for _ in range(_tries):
                v = self._gen(r)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return _Strategy(gen)

    def map(self, fn):
        return _Strategy(lambda r: fn(self._gen(r)))


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: seq[r.randrange(len(seq))])


def floats(min_value=0.0, max_value=1.0, **_):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def text(alphabet=None, min_size=0, max_size=10):
    def gen(r):
        n = r.randint(min_size, max_size)
        if isinstance(alphabet, _Strategy):
            chars = [alphabet.example(r) for _ in range(n)]
        elif alphabet:
            chars = [r.choice(list(alphabet)) for _ in range(n)]
        else:
            chars = [chr(r.randint(97, 122)) for _ in range(n)]
        return "".join(chars)

    return _Strategy(gen)


def lists(elements, min_size=0, max_size=10, unique=False):
    def gen(r):
        n = r.randint(min_size, max_size)
        out, tries = [], 0
        while len(out) < n and tries < 50 * (n + 1):
            v = elements.example(r)
            tries += 1
            if unique and v in out:
                continue
            out.append(v)
        return out

    return _Strategy(gen)


def composite(fn):
    def builder(*args, **kwargs):
        def gen(r):
            return fn((lambda s: s.example(r)), *args, **kwargs)

        return _Strategy(gen)

    return builder


def given(*strats, **kwstrats):
    def deco(fn):
        def wrapper():
            rng = random.Random(1234)
            n = getattr(wrapper, "_max_examples", 10)
            for _ in range(n):
                args = [s.example(rng) for s in strats]
                kw = {k: s.example(rng) for k, s in kwstrats.items()}
                fn(*args, **kw)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def settings(max_examples: int = 10, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def build_modules() -> tuple[types.ModuleType, types.ModuleType]:
    """(hypothesis, hypothesis.strategies) module objects for sys.modules."""
    st = types.ModuleType("hypothesis.strategies")
    for f in (integers, sampled_from, floats, booleans, text, lists,
              composite):
        setattr(st, f.__name__, f)
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__stub__ = True
    return hyp, st
