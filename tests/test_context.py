"""Unit tests: monitoring contexts (the compile-time set, paper C2/C3)."""
import pytest

from repro.core.context import (
    EventSpec,
    MonitorSpec,
    ScopeContext,
    spec_from_mapping,
)


def test_eventspec_slot_id_roundtrip():
    for sid in ["ACT_RMS", "ACT_RMS:out", "MOE_LOAD:router_probs/CV",
                "FLOPS/SUB"]:
        assert EventSpec.parse(sid).slot_id == sid


def test_eventspec_parse_fields():
    e = EventSpec.parse("MOE_LOAD:router_probs/MAX_FRAC")
    assert e.event == "MOE_LOAD"
    assert e.tensor == "router_probs"
    assert e.subevent == "MAX_FRAC"


def test_exhaustive_context_single_set():
    ctx = ScopeContext.exhaustive(
        "attn", [EventSpec("ACT_RMS", "out"), EventSpec("NAN_COUNT", "out")]
    )
    assert ctx.n_sets == 1
    assert ctx.event_sets == ((0, 1),)


def test_multiplexed_context_sets_partition_slots():
    ctx = ScopeContext.multiplexed(
        "mlp",
        [[EventSpec("ACT_RMS", "out")],
         [EventSpec("NAN_COUNT", "out"), EventSpec("INF_COUNT", "out")]],
        period=100,
    )
    assert ctx.n_sets == 2
    assert ctx.event_sets == ((0,), (1, 2))
    assert ctx.default_period == 100


def test_event_set_overlap_rejected():
    with pytest.raises(ValueError, match="more than one event set"):
        ScopeContext(
            scope="s",
            slots=(EventSpec("ACT_RMS", "x"), EventSpec("MEAN", "x")),
            event_sets=((0, 1), (1,)),
        )


def test_event_set_must_cover_all_slots():
    with pytest.raises(ValueError, match="cover every slot"):
        ScopeContext(
            scope="s",
            slots=(EventSpec("ACT_RMS", "x"), EventSpec("MEAN", "x")),
            event_sets=((0,),),
        )


def test_event_set_index_out_of_range():
    with pytest.raises(ValueError, match="out of range"):
        ScopeContext(
            scope="s", slots=(EventSpec("ACT_RMS", "x"),), event_sets=((3,),)
        )


def test_monitor_spec_lookup_and_membership():
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("a", [EventSpec("ACT_RMS", "x")]),
        ScopeContext.exhaustive("b", []),
    ])
    assert spec.n_scopes == 2
    assert "a" in spec and "c" not in spec
    assert spec.scope_index("b") == 1
    assert spec.slot_index("a", "ACT_RMS:x") == 0
    with pytest.raises(KeyError):
        spec.scope_index("missing")
    with pytest.raises(KeyError):
        spec.slot_index("a", "nope")


def test_monitor_spec_duplicate_scopes_rejected():
    ctx = ScopeContext.exhaustive("a", [])
    with pytest.raises(ValueError, match="duplicate"):
        MonitorSpec.of([ctx, ctx])


def test_with_context_replaces():
    spec = MonitorSpec.of([ScopeContext.exhaustive("a", [])])
    spec2 = spec.with_context(
        ScopeContext.exhaustive("a", [EventSpec("MEAN", "x")])
    )
    assert spec2.n_scopes == 1
    assert len(spec2.context("a").slots) == 1


def test_spec_from_mapping_exhaustive_and_multiplexed():
    spec = spec_from_mapping(
        {
            "attn": ["ACT_RMS:out", "NAN_COUNT:out"],
            "mlp": [["ACT_RMS:out"], ["MEAN:out"]],
        },
        periods={"mlp": 7},
    )
    assert spec.context("attn").n_sets == 1
    assert spec.context("mlp").n_sets == 2
    assert spec.context("mlp").default_period == 7


def test_max_slots():
    spec = spec_from_mapping({"a": ["ACT_RMS:x"], "b": ["ACT_RMS:x",
                                                        "MEAN:x",
                                                        "L2NORM:x"]})
    assert spec.max_slots == 3
