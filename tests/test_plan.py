"""Probe-plan compiler: per-(scope, event set) moment plans, the dense
slot layout / compact scan carry, spec fingerprints, and runtime event-set
hot-swap through the plan layer without re-tracing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import plan as plan_lib
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams

SIX = ("ACT_RMS", "ACT_MEAN_ABS", "ACT_MAX_ABS", "ACT_ZERO_FRAC",
       "NAN_COUNT", "INF_COUNT")


def _sparse_ctx(scope="hot", period=1):
    """A multiplexed scope whose every set needs a strict SUBSET of the
    union: the workload per-set plans exist for."""
    return ScopeContext.multiplexed(scope, [
        [EventSpec("ACT_MAX_ABS", "x")],
        [EventSpec("ACT_ZERO_FRAC", "x")],
        [EventSpec("ACT_RMS", "x"), EventSpec("MEAN", "x")],
    ], period=period)


# ---------------------------------------------------------------------------
# plan compilation
# ---------------------------------------------------------------------------

def test_per_set_plans_sweep_exact_subsets():
    sp = plan_lib.compile_scope_plans(_sparse_ctx(), frozenset({"x"}))
    assert sp.n_sets == 3 and sp.width == 4
    chans = [p.sweeps[0].channels for p in sp.plans]
    assert chans[0] == ("max_abs",)
    assert chans[1] == ("zero_count", "numel")
    assert chans[2] == ("sum", "sum_sq", "numel")
    assert [p.members for p in sp.plans] == [(0,), (1,), (2, 3)]
    # sweep_channel_count excludes the free static channels
    assert [p.sweep_channel_count for p in sp.plans] == [1, 1, 2]


def test_union_plans_widen_every_set():
    sp = plan_lib.compile_scope_plans(_sparse_ctx(), frozenset({"x"}),
                                      True)
    union = ("sum", "sum_sq", "max_abs", "zero_count", "numel")
    for p in sp.plans:
        assert p.sweeps[0].channels == union
    # membership (and therefore the scatter footprint) is still per-set
    assert [p.members for p in sp.plans] == [(0,), (1,), (2, 3)]


def test_plans_split_fused_and_bespoke_slots():
    ctx = ScopeContext.exhaustive("g", [
        EventSpec("ACT_RMS", "y"),
        EventSpec("ATTN_ENTROPY", "p"),          # fused via ent_sum channel
        EventSpec("MOE_LOAD", subevent="CV"),    # bespoke (dict event)
    ])
    sp = plan_lib.compile_scope_plans(
        ctx, frozenset({"y", "p", "router_probs"})
    )
    (p0,) = sp.plans
    kinds = {s.index: s.fused for s in p0.slots}
    assert kinds == {0: True, 1: True, 2: False}
    sweeps = {sw.tensor: sw.channels for sw in p0.sweeps}
    assert sweeps == {"y": ("sum_sq", "numel"), "p": ("ent_sum", "rows")}


def test_plans_respect_available_tensors():
    ctx = _sparse_ctx()
    sp = plan_lib.compile_scope_plans(ctx, frozenset({"other"}))
    assert not sp.any_live
    # and the cache keys on availability, not just the context
    sp2 = plan_lib.compile_scope_plans(ctx, frozenset({"x"}))
    assert sp2.any_live


# ---------------------------------------------------------------------------
# dense slot layout + compact scan carry
# ---------------------------------------------------------------------------

def _spec_uneven():
    return MonitorSpec.of([
        ScopeContext.exhaustive("wide", [EventSpec(e, "x") for e in SIX]),
        ScopeContext.exhaustive("narrow", [EventSpec("MEAN", "x")]),
        ScopeContext.exhaustive("dark", []),
    ])


def test_slot_layout_packs_scopes_contiguously():
    lay = plan_lib.spec_layout(_spec_uneven())
    assert lay.widths == (6, 1, 0)
    assert lay.offsets == (0, 6, 7)
    assert lay.total == 7
    sids, slids = lay.scatter_indices
    assert sids.tolist() == [0] * 6 + [1]
    assert slids.tolist() == [0, 1, 2, 3, 4, 5, 0]


def test_compact_delta_roundtrip():
    spec = _spec_uneven()
    state = CounterState.zeros(spec)
    state = CounterState(
        calls=state.calls.at[0].set(3),
        values=state.values.at[0, 2].set(5.0).at[1, 0].set(7.0),
        samples=state.samples.at[0, 2].set(2).at[1, 0].set(1),
    )
    compact = plan_lib.CompactDelta.compress(spec, state)
    assert compact.values.shape == (7,)
    back = compact.expand(spec)
    np.testing.assert_array_equal(np.asarray(back.values),
                                  np.asarray(state.values))
    np.testing.assert_array_equal(np.asarray(back.samples),
                                  np.asarray(state.samples))
    np.testing.assert_array_equal(np.asarray(back.calls),
                                  np.asarray(state.calls))


def test_scan_carries_compact_footprint_and_matches_unrolled():
    """The scan carry is [total] wide (the live footprint), not
    [n_scopes, max_slots]; the result is identical to an unrolled loop."""
    spec = _spec_uneven()
    params = MonitorParams.all_on(spec)
    xs = jnp.arange(8.0).reshape(8, 1)
    lay = plan_lib.spec_layout(spec)
    assert lay.total < spec.n_scopes * spec.max_slots  # 7 vs 18

    def body(c, x):
        with scalpel.function("wide"):
            scalpel.probe(x=x + c)
        with scalpel.function("narrow"):
            scalpel.probe(x=x * 2)
        return c + 1.0, x

    state = CounterState.zeros(spec)
    with scalpel.collecting(spec, params, state) as col:
        scalpel.scan_with_counters(body, jnp.zeros(()), xs)
    scanned = state.add(col.delta)

    state2 = CounterState.zeros(spec)
    with scalpel.collecting(spec, params, state2) as col2:
        c = jnp.zeros(())
        for i in range(8):
            c, _ = body(c, xs[i])
    unrolled = state2.add(col2.delta)

    np.testing.assert_array_equal(np.asarray(scanned.calls),
                                  np.asarray(unrolled.calls))
    np.testing.assert_allclose(np.asarray(scanned.values),
                               np.asarray(unrolled.values), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(scanned.samples),
                                  np.asarray(unrolled.samples))


# ---------------------------------------------------------------------------
# spec fingerprint
# ---------------------------------------------------------------------------

def test_fingerprint_stable_and_plan_sensitive():
    a = MonitorSpec.of([_sparse_ctx()])
    b = MonitorSpec.of([_sparse_ctx()])
    assert a.fingerprint == b.fingerprint          # structural, not id-based
    c = MonitorSpec.of([_sparse_ctx(period=5)])
    assert a.fingerprint == c.fingerprint          # period is runtime-dynamic
    d = a.with_context(
        ScopeContext.exhaustive("hot", [EventSpec("MEAN", "x")])
    )
    assert a.fingerprint != d.fingerprint          # different compiled plans


def test_fingerprint_distinguishes_bespoke_events():
    """Two bespoke slots both compile to empty sweeps — the fingerprint
    must still tell them apart (it hashes slot identities, not just the
    sweep table), or telemetry would attribute two different traced probe
    graphs to the same plan."""
    a = MonitorSpec.of(
        [ScopeContext.exhaustive("s", [EventSpec("SSM_STATE_RMS", "h")])]
    )
    b = MonitorSpec.of(
        [ScopeContext.exhaustive("s", [EventSpec("MOE_LOAD",
                                                 subevent="CV")])]
    )
    assert a.fingerprint != b.fingerprint


def test_describe_plans_lists_sets_and_footprint():
    text = plan_lib.describe_plans(_spec_uneven())
    assert "wide: width 6" in text
    assert "ACT_RMS:x" in text            # slot identities are spelled out
    assert "total live footprint: 7 slot(s)" in text


# ---------------------------------------------------------------------------
# runtime event-set hot-swap through the plan layer (paper SIGUSR1 reload)
# ---------------------------------------------------------------------------

CONFIG_SET_A = """
BINARY=test
NO_FUNCTIONS=1
[FUNCTION]
FUNC_NAME=hot
MULTIPLEX_PERIOD=1
NO_EVENTS=0
[/FUNCTION]
"""

CONFIG_SET_B = """
BINARY=test
NO_FUNCTIONS=2
[FUNCTION]
FUNC_NAME=hot
MULTIPLEX_PERIOD=3
NO_EVENTS=1
[EVENT]
ID=ACT_MAX_ABS:x
NO_SUBEVENTS=0
[/EVENT]
[/FUNCTION]
[FUNCTION]
FUNC_NAME=cold
NO_EVENTS=0
[/FUNCTION]
"""


def test_config_hot_swap_switches_plans_without_retrace(tmp_path):
    """A config-file reload (the SIGUSR1 path) re-selects among the compiled
    per-set plans — masks/periods swap as dynamic inputs, the jitted step
    never re-traces, untouched sets keep their plans (one jit cache entry,
    fingerprint constant), and the counters follow the new selection."""
    spec = MonitorSpec.of([
        _sparse_ctx("hot"),
        ScopeContext.exhaustive("cold", [EventSpec("MEAN", "x")]),
    ])
    cfgp = tmp_path / "mon.cfg"
    cfgp.write_text(CONFIG_SET_A)
    rt = scalpel.ScalpelRuntime(spec, config_path=str(cfgp))
    fp0 = rt.plan_fingerprint
    traces = []

    def step(state, mparams, x):
        traces.append(1)
        with scalpel.collecting(spec, mparams, state) as col:
            with scalpel.function("hot"):
                scalpel.probe(x=x)
            with scalpel.function("cold"):
                scalpel.probe(x=x)
        return state.add(col.delta)

    f = jax.jit(step)
    x = jnp.ones((64,)) * 2.0
    s = CounterState.zeros(spec)
    for _ in range(6):
        s = f(s, rt.params, x)
    # config A: hot fully on, 6 calls cycle sets 0,1,2,0,1,2
    assert np.asarray(s.samples)[0, :4].tolist() == [2, 2, 2, 2]
    assert int(s.samples[1, 0]) == 0          # cold not in config A

    cfgp.write_text(CONFIG_SET_B)
    rt.reload()                               # the paper's SIGUSR1 swap
    assert rt.plan_fingerprint == fp0         # plans: compiled, re-selected
    for _ in range(6):
        s = f(s, rt.params, x)
    assert len(traces) == 1                   # ONE trace across both configs
    assert f._cache_size() == 1
    # config B: only ACT_MAX_ABS live in hot (slot 0), cold fully on
    smp = np.asarray(s.samples)
    assert smp[0, 0] > 2                      # set-0 slot kept sampling
    assert smp[0, 1:4].tolist() == [2, 2, 2]  # other sets' slots masked off
    assert smp[1, 0] == 6                     # cold now monitored
    # the max-abs slot's estimate follows its own per-set plan (1 channel)
    est = scalpel.estimates(spec, s)
    assert est["hot"]["ACT_MAX_ABS:x"] == pytest.approx(2.0)
    rt.close()


def test_plan_mode_inherited_by_scan_children():
    """capture()/scan children compile against the parent's plan mode."""
    spec = MonitorSpec.of([_sparse_ctx("hot")])
    params = MonitorParams.all_on(spec)
    xs = jnp.ones((6, 8))

    def body(c, x):
        with scalpel.function("hot"):
            scalpel.probe(x=x)
        return c, x

    outs = {}
    for mode in ("per_set", "union"):
        state = CounterState.zeros(spec)
        with scalpel.collecting(spec, params, state,
                                plan_mode=mode) as col:
            assert col.plan_mode == mode
            scalpel.scan_with_counters(body, jnp.zeros(()), xs)
        outs[mode] = state.add(col.delta)
    np.testing.assert_allclose(np.asarray(outs["per_set"].values),
                               np.asarray(outs["union"].values),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(outs["per_set"].samples),
                                  np.asarray(outs["union"].samples))
