"""Fused probe reductions: Pallas moment kernel vs jnp reference, and
fused vs legacy event evaluation through a real collecting() region."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import events
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams
from repro.kernels import ops, probe_reduce as pr

MOMENT_EVENTS = (
    "ACT_RMS", "ACT_MEAN_ABS", "ACT_MAX_ABS", "ACT_ZERO_FRAC",
    "NAN_COUNT", "INF_COUNT", "NUMEL", "L2NORM", "MEAN",
)


def test_moment_vocabulary_in_sync():
    assert pr.MOMENTS == events.MOMENTS


# ---------------------------------------------------------------------------
# stage 1: the kernel vs the unfused jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1024,),          # 1-D, tile-aligned
    (1000,),          # 1-D, non-tile-aligned
    (64, 129),        # 2-D, ragged lanes
    (7, 33, 65),      # 3-D, nothing aligned
    (1, 1),           # degenerate
])
def test_pallas_moments_match_reference(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**32)
    x = rng.normal(size=shape).astype(np.float32)
    x.flat[:: max(1, x.size // 17)] = 0.0  # some exact zeros
    xj = jnp.asarray(x).astype(dtype)
    got = np.asarray(ops.probe_moments(xj, block_rows=8, interpret=True))
    want = np.asarray(pr.moments_ref(xj))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)
    # numel is exact (static constant, never a rounded f32 accumulation);
    # zero_count doubles as the mask check — zero padding would inflate it
    assert got[pr.M_NUMEL] == x.size
    assert got[pr.M_ZERO] == want[pr.M_ZERO]


def test_pallas_moments_nan_inf_propagation():
    a = np.array([np.nan, 1.5, np.inf, -np.inf, 0.0] * 64, np.float32)
    got = np.asarray(ops.probe_moments(jnp.asarray(a), block_rows=1,
                                       interpret=True))
    want = np.asarray(pr.moments_ref(jnp.asarray(a)))
    np.testing.assert_allclose(got, want, equal_nan=True)
    assert got[pr.M_NAN] == 64 and got[pr.M_INF] == 128


def test_named_moments_jnp_subset_matches_reference():
    x = jax.random.normal(jax.random.PRNGKey(3), (513,))
    ref = pr.moments_ref(x)
    d = ops.tensor_moments(x, ("sum_sq", "max_abs", "zero_count"),
                           use_pallas=False)
    for name in ("sum_sq", "max_abs", "zero_count", "numel"):
        np.testing.assert_allclose(
            float(d[name]), float(ref[pr.MOMENTS.index(name)]), rtol=1e-5
        )
    assert "sum_abs" not in d  # only the union that was asked for


# ---------------------------------------------------------------------------
# stage 2: finalizers reproduce every moment-derived event
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", MOMENT_EVENTS)
def test_finalizer_matches_direct_event(name):
    x = jax.random.normal(jax.random.PRNGKey(7), (37, 11))
    x = x.at[0, 0].set(0.0)
    spec = EventSpec(name, tensor="x")
    assert events.moment_based(spec)
    moms = ops.tensor_moments(x, events.required_moments([spec]),
                              use_pallas=False)
    got = float(events.finalize_event(spec, moms))
    want = float(events.compute(spec, {"x": x}))
    assert got == pytest.approx(want, rel=1e-5, abs=1e-7)


def test_bespoke_events_not_moment_based():
    for name in ("ATTN_ENTROPY", "MOE_LOAD", "SSM_STATE_RMS"):
        assert not events.moment_based(EventSpec(name))


# ---------------------------------------------------------------------------
# end to end: fused vs legacy under a real collecting() region
# ---------------------------------------------------------------------------

def _run(spec, params, prog, *args, fused):
    state = CounterState.zeros(spec)
    with scalpel.collecting(spec, params, state, fused=fused) as col:
        prog(*args)
    return state.add(col.delta)


def test_fused_equals_legacy_exhaustive_scope():
    slots = [EventSpec(e, "x") for e in MOMENT_EVENTS]
    spec = MonitorSpec.of([ScopeContext.exhaustive("f", slots)])
    params = MonitorParams.all_on(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 33))
    x = x.at[0, 0].set(0.0).at[1, 1].set(jnp.inf)

    def prog(x):
        for i in range(4):
            with scalpel.function("f"):
                scalpel.probe(x=x * (i + 1))

    a = _run(spec, params, prog, x, fused=True)
    b = _run(spec, params, prog, x, fused=False)
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))


def test_fused_equals_legacy_multiplexed_mixed_events():
    """Moment-derived and bespoke slots interleaved across event sets."""
    spec = MonitorSpec.of([
        ScopeContext.multiplexed("g", [
            [EventSpec("ACT_RMS", "y"), EventSpec("ACT_MAX_ABS", "y")],
            [EventSpec("ATTN_ENTROPY", "p"), EventSpec("MEAN", "y")],
        ], period=2),
    ])
    params = MonitorParams.all_on(spec)
    y = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (8, 16)), -1)

    def prog(y, p):
        for _ in range(7):
            with scalpel.function("g"):
                scalpel.probe(y=y, p=p)

    a = _run(spec, params, prog, y, p, fused=True)
    b = _run(spec, params, prog, y, p, fused=False)
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))


def test_fused_equals_legacy_under_jit_and_masks():
    slots = [EventSpec(e, "x") for e in ("ACT_RMS", "ACT_ZERO_FRAC",
                                         "NAN_COUNT")]
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("hot", slots),
        ScopeContext.exhaustive("cold", slots),
    ])
    params = MonitorParams.selective(spec, ["hot"]).set_slot(
        spec, "hot", "ACT_ZERO_FRAC:x", False
    )
    x = jax.random.normal(jax.random.PRNGKey(5), (256,))

    def make(fused):
        def step(x, s, mp):
            with scalpel.collecting(spec, mp, s, fused=fused) as col:
                with scalpel.function("hot"):
                    scalpel.probe(x=x)
                with scalpel.function("cold"):
                    scalpel.probe(x=x * 2)
            return s.add(col.delta)

        return jax.jit(step)

    s0 = CounterState.zeros(spec)
    a = make(True)(x, s0, params)
    b = make(False)(x, s0, params)
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))
    # masked slot stayed dark, un-monitored scope stayed dark
    assert int(a.samples[0, 1]) == 0
    assert not np.any(np.asarray(a.values[1]))
