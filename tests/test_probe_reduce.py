"""Fused probe reductions: Pallas moment kernel (incl. the optional entropy
channel) vs jnp reference, and per-set-planned vs union-planned event
evaluation through a real collecting() region."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as scalpel
from repro.core import events
from repro.core.context import EventSpec, MonitorSpec, ScopeContext
from repro.core.counters import CounterState, MonitorParams
from repro.kernels import ops, probe_reduce as pr

MOMENT_EVENTS = (
    "ACT_RMS", "ACT_MEAN_ABS", "ACT_MAX_ABS", "ACT_ZERO_FRAC",
    "NAN_COUNT", "INF_COUNT", "NUMEL", "L2NORM", "MEAN",
)


def test_channel_vocabulary_in_sync():
    # kernel dense vector = sweep channels (minus static) + numel slot
    assert pr.MOMENTS[:7] == events.SWEEP_CHANNELS[:7]
    assert pr.MOMENTS_ENT == pr.MOMENTS + ("ent_sum",)
    assert set(events.CHANNELS) == set(pr.MOMENTS_ENT) | set(
        pr.STATIC_CHANNELS
    )
    assert events.CHANNELS == events.SWEEP_CHANNELS + events.STATIC_CHANNELS


# ---------------------------------------------------------------------------
# stage 1: the kernel vs the unfused jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1024,),          # 1-D, tile-aligned
    (1000,),          # 1-D, non-tile-aligned
    (64, 129),        # 2-D, ragged lanes
    (7, 33, 65),      # 3-D, nothing aligned
    (1, 1),           # degenerate
])
def test_pallas_moments_match_reference(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**32)
    x = rng.normal(size=shape).astype(np.float32)
    x.flat[:: max(1, x.size // 17)] = 0.0  # some exact zeros
    xj = jnp.asarray(x).astype(dtype)
    got = np.asarray(ops.probe_moments(xj, block_rows=8, interpret=True))
    want = np.asarray(pr.moments_ref(xj))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)
    # numel is exact (static constant, never a rounded f32 accumulation);
    # zero_count doubles as the mask check — zero padding would inflate it
    assert got[pr.M_NUMEL] == x.size
    assert got[pr.M_ZERO] == want[pr.M_ZERO]


@pytest.mark.parametrize("shape", [(128,), (5, 33), (3, 7, 17)])
def test_pallas_entropy_channel_matches_reference(shape):
    """The optional ent_sum channel rides the same masked sweep."""
    rng = np.random.default_rng(11)
    p = jax.nn.softmax(jnp.asarray(rng.normal(size=shape), jnp.float32), -1)
    got = np.asarray(
        ops.probe_moments(p, block_rows=1, interpret=True, with_entropy=True)
    )
    want = np.asarray(pr.moments_ref(p, with_entropy=True))
    assert got.shape == (len(pr.MOMENTS_ENT),)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)
    # without the flag the vector stays 8 wide — plans only pay on request
    assert ops.probe_moments(p, interpret=True).shape == (len(pr.MOMENTS),)


def test_pallas_moments_nan_inf_propagation():
    a = np.array([np.nan, 1.5, np.inf, -np.inf, 0.0] * 64, np.float32)
    got = np.asarray(ops.probe_moments(jnp.asarray(a), block_rows=1,
                                       interpret=True))
    want = np.asarray(pr.moments_ref(jnp.asarray(a)))
    np.testing.assert_allclose(got, want, equal_nan=True)
    assert got[pr.M_NAN] == 64 and got[pr.M_INF] == 128


def test_named_moments_jnp_subset_matches_reference():
    x = jax.random.normal(jax.random.PRNGKey(3), (513,))
    ref = pr.moments_ref(x)
    d = ops.tensor_moments(x, ("sum_sq", "max_abs", "zero_count"),
                           use_pallas=False)
    for name in ("sum_sq", "max_abs", "zero_count", "numel"):
        np.testing.assert_allclose(
            float(d[name]), float(ref[pr.MOMENTS.index(name)]), rtol=1e-5
        )
    assert "sum_abs" not in d  # only the exact plan channels, nothing more
    # static channels ride along for free: one row along the last axis
    assert float(d["rows"]) == 1.0
    d2 = ops.tensor_moments(jnp.ones((4, 5, 8)), ("sum",), use_pallas=False)
    assert float(d2["rows"]) == 20.0 and float(d2["numel"]) == 160.0


# ---------------------------------------------------------------------------
# stage 2: finalizers reproduce every moment-derived event
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", MOMENT_EVENTS)
def test_finalizer_matches_direct_event(name):
    x = jax.random.normal(jax.random.PRNGKey(7), (37, 11))
    x = x.at[0, 0].set(0.0)
    spec = EventSpec(name, tensor="x")
    assert events.moment_based(spec)
    moms = ops.tensor_moments(x, events.channels_for([spec]),
                              use_pallas=False)
    got = float(events.finalize_event(spec, moms))
    want = float(events.compute(spec, {"x": x}))
    assert got == pytest.approx(want, rel=1e-5, abs=1e-7)


def test_entropy_finalizer_matches_direct_event():
    """ATTN_ENTROPY is moment-derived now: ent_sum/rows off the shared sweep."""
    p = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(9), (6, 5, 32)), -1
    )
    spec = EventSpec("ATTN_ENTROPY", tensor="p")
    assert events.moment_based(spec)
    assert events.channels_for([spec]) == ("ent_sum", "rows")
    moms = ops.tensor_moments(p, ("ent_sum", "rows"), use_pallas=False)
    got = float(events.finalize_event(spec, moms))
    want = float(events.compute(spec, {"p": p}))
    assert got == pytest.approx(want, rel=1e-5)


def test_bespoke_events_not_moment_based():
    for name in ("MOE_LOAD", "SSM_STATE_RMS"):
        assert not events.moment_based(EventSpec(name))


def test_channels_for_is_per_group_not_per_registry():
    a = events.channels_for([EventSpec("ACT_MAX_ABS", "x")])
    b = events.channels_for([EventSpec("ACT_RMS", "x"),
                             EventSpec("MEAN", "x")])
    assert a == ("max_abs",)
    assert b == ("sum", "sum_sq", "numel")


# ---------------------------------------------------------------------------
# end to end: per-set plans vs the union baseline under collecting()
# ---------------------------------------------------------------------------

def _run(spec, params, prog, *args, plan_mode):
    state = CounterState.zeros(spec)
    with scalpel.collecting(spec, params, state, plan_mode=plan_mode) as col:
        prog(*args)
    return state.add(col.delta)


def test_per_set_equals_union_exhaustive_scope():
    slots = [EventSpec(e, "x") for e in MOMENT_EVENTS]
    spec = MonitorSpec.of([ScopeContext.exhaustive("f", slots)])
    params = MonitorParams.all_on(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 33))
    x = x.at[0, 0].set(0.0).at[1, 1].set(jnp.inf)

    def prog(x):
        for i in range(4):
            with scalpel.function("f"):
                scalpel.probe(x=x * (i + 1))

    a = _run(spec, params, prog, x, plan_mode="per_set")
    b = _run(spec, params, prog, x, plan_mode="union")
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))


def test_per_set_equals_union_multiplexed_mixed_events():
    """Moment-derived, entropy-channel and bespoke slots across event sets."""
    spec = MonitorSpec.of([
        ScopeContext.multiplexed("g", [
            [EventSpec("ACT_RMS", "y"), EventSpec("ACT_MAX_ABS", "y")],
            [EventSpec("ATTN_ENTROPY", "p"), EventSpec("MEAN", "y")],
            [EventSpec("SSM_STATE_RMS", "y")],
        ], period=2),
    ])
    params = MonitorParams.all_on(spec)
    y = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (8, 16)), -1)

    def prog(y, p):
        for _ in range(9):
            with scalpel.function("g"):
                scalpel.probe(y=y, p=p)

    a = _run(spec, params, prog, y, p, plan_mode="per_set")
    b = _run(spec, params, prog, y, p, plan_mode="union")
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))
    # and both match the unfused direct reference on the entropy slot
    # (every sampled call probed the same p, so value/samples == one call)
    want = float(events.compute(EventSpec("ATTN_ENTROPY", "p"), {"p": p}))
    got = float(a.values[0, 2]) / max(1, int(a.samples[0, 2]))
    assert got == pytest.approx(want, rel=1e-5)


def test_per_set_equals_union_under_jit_and_masks():
    slots = [EventSpec(e, "x") for e in ("ACT_RMS", "ACT_ZERO_FRAC",
                                         "NAN_COUNT")]
    spec = MonitorSpec.of([
        ScopeContext.exhaustive("hot", slots),
        ScopeContext.exhaustive("cold", slots),
    ])
    params = MonitorParams.selective(spec, ["hot"]).set_slot(
        spec, "hot", "ACT_ZERO_FRAC:x", False
    )
    x = jax.random.normal(jax.random.PRNGKey(5), (256,))

    def make(plan_mode):
        def step(x, s, mp):
            with scalpel.collecting(spec, mp, s, plan_mode=plan_mode) as col:
                with scalpel.function("hot"):
                    scalpel.probe(x=x)
                with scalpel.function("cold"):
                    scalpel.probe(x=x * 2)
            return s.add(col.delta)

        return jax.jit(step)

    s0 = CounterState.zeros(spec)
    a = make("per_set")(x, s0, params)
    b = make("union")(x, s0, params)
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.samples),
                                  np.asarray(b.samples))
    # masked slot stayed dark, un-monitored scope stayed dark
    assert int(a.samples[0, 1]) == 0
    assert not np.any(np.asarray(a.values[1]))


def test_unknown_plan_mode_rejected():
    spec = MonitorSpec.of(
        [ScopeContext.exhaustive("f", [EventSpec("MEAN", "x")])]
    )
    with pytest.raises(ValueError, match="plan_mode"):
        with scalpel.collecting(spec, MonitorParams.all_on(spec),
                                CounterState.zeros(spec),
                                plan_mode="legacy"):
            pass
