"""Pallas chunked linear-recurrence scan (mLSTM / Mamba2 state update).

The recurrence h_t = a_t * h_{t-1} + b_t (diagonal gate, elementwise over
channels) is the state-update hot-spot of the SSM archs (xlstm-125m,
zamba2-7b).  GPU implementations block it over SMs with warp-level prefix
products; the TPU adaptation:

  * grid (B, D/bd, S/chunk), chunk axis innermost — Pallas executes the grid
    sequentially on a core, so the carried state lives in VMEM scratch and
    flows across chunk iterations for free (no HBM round-trip per chunk);
  * within a chunk the recurrence is evaluated with a vectorized
    ``associative_scan`` in log-gate space on the [chunk, bd] VMEM tile:
    (la1,b1)∘(la2,b2) = (la1+la2, exp(la2)·b1 + b2) — O(log chunk) VPU
    passes, no sequential inner loop;
  * the carried state enters as h_t = exp(cumsum la)·h0 + scan_b.

Gates are passed in log space (log_a <= 0 for decay gates) which keeps
exp() bounded.  f32 throughout (state quality matters more than bytes here).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(la_ref, b_ref, o_ref, h_ref):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _reset():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[0].astype(jnp.float32)   # [chunk, bd]
    bb = b_ref[0].astype(jnp.float32)    # [chunk, bd]

    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, jnp.exp(la2) * b1 + b2

    cum_la, scan_b = jax.lax.associative_scan(combine, (la, bb), axis=0)
    h0 = h_ref[0]                         # [bd]
    h = jnp.exp(cum_la) * h0[None, :] + scan_b
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[0] = h[-1]


def ssm_scan_chunked(log_a, b_in, *, chunk: int = 256, bd: int = 512,
                     interpret: bool = False):
    """log_a, b_in: [B, S, D] -> h: [B, S, D] (h_0 = b_0, zero init state)."""
    B, S, D = log_a.shape
    chunk = min(chunk, S)
    bd = min(bd, D)
    assert S % chunk == 0, (S, chunk)
    assert D % bd == 0, (D, bd)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        _ssm_kernel,
        grid=(B, D // bd, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, jd, c: (i, c, jd)),
            pl.BlockSpec((1, chunk, bd), lambda i, jd, c: (i, c, jd)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda i, jd, c: (i, c, jd)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(log_a, b_in)
