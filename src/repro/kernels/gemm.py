"""Pallas GEMM with two schedules — the subjects of the paper's case study.

The paper compares ATLAS (cache-blocked GEMM) against GotoBLAS (TLB-driven
panel streaming) *through hardware counters*, not through their source.  We
adapt both schedules to the TPU memory hierarchy (HBM -> VMEM -> MXU) and
expose per-schedule cost counters so the reproduced case study can make the
same argument: the two schedules do identical FLOPs but move very different
numbers of bytes between memory levels.

Schedules
---------
cache_blocked (≙ ATLAS)
    grid (M/bm, N/bn, K/bk), square-ish VMEM tiles, K innermost with an f32
    VMEM accumulator.  Both A and B tiles are re-fetched along their
    non-contracted grid axis: HBM traffic ≈ MK·(N/bn) + KN·(M/bm).

panel_streaming (≙ GotoBLAS)
    grid (M/bm, N/bn), the full A panel [bm, K] made VMEM-resident (the
    TPU analogue of "fill most of the TLB-addressable memory with A"), B
    streamed in [K, bn] panels with N innermost.  Pallas's pipelining skips
    the A copy while the block index is unchanged, so A is fetched exactly
    once: HBM traffic ≈ MK + KN·(M/bm).  The trade-off is a much larger
    VMEM working set (bm·K), limiting bm — exactly Goto's trade-off.

Both kernels compute identical C = A @ B (f32 accumulate), so allclose
against ref.matmul; only the counters differ.  ops.py exposes the analytical
counter model (schedule_cost) used as ScALPEL FLOPS/HBM_BYTES/... probes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# cache_blocked: (M/bm, N/bn, K/bk) grid, f32 VMEM accumulator
# ---------------------------------------------------------------------------

def _cache_blocked_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def cache_blocked_matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 256,
                         out_dtype=jnp.float32, interpret: bool = False):
    """ATLAS-like blocked GEMM. a: [M,K], b: [K,N] -> [M,N]."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_cache_blocked_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------------------
# panel_streaming: (M/bm, N/bn) grid, A panel resident across the N loop
# ---------------------------------------------------------------------------

def _panel_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def panel_streaming_matmul(a, b, *, bm: int = 128, bn: int = 256,
                           out_dtype=jnp.float32, interpret: bool = False):
    """GotoBLAS-like GEMM: A panel [bm, K] VMEM-resident, B streamed.

    N is the innermost grid axis, and the A BlockSpec's index map does not
    depend on it — Pallas's pipelining elides the re-copy, so each A panel
    crosses HBM->VMEM exactly once (the Goto property).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, ((m, n, k), (bm, bn))
    return pl.pallas_call(
        _panel_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # resident panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),   # streamed
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# analytical schedule counters (the case-study "hardware counters")
# ---------------------------------------------------------------------------

# TPU v5e constants (per chip) — single source for the roofline too.
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
CLOCK_HZ = 940e6  # v5e core clock (approx.)
MXU_DIM = 128


def schedule_cost(schedule: str, m: int, n: int, k: int,
                  bm: int, bn: int, bk: int, dtype_bytes: int = 2) -> dict:
    """Analytical per-call counters for a GEMM schedule.

    Returns the ScALPEL case-study events:
      FLOPS            — 2*M*N*K (identical across schedules)
      HBM_BYTES        — schedule-dependent HBM->VMEM traffic (≙ L2_LINES_IN)
      VMEM_TILE_REFILLS— number of HBM->VMEM tile copies (≙ DTLB_MISSES)
      MXU_PASSES       — 128x128x128 systolic passes (≙ SIMD_INST_RETIRED)
      EST_STALL_CYCLES — max(0, mem_time - compute_time) * clock
                         (≙ RESOURCE_STALLS)
    """
    flops = 2.0 * m * n * k
    gm, gn = m // bm, n // bn
    if schedule == "cache_blocked":
        gk = k // bk
        a_bytes = gm * gk * (bm * bk) * gn * dtype_bytes   # A refetched per j
        b_bytes = gk * gn * (bk * bn) * gm * dtype_bytes   # B refetched per i
        refills = gm * gn * gk * 2
    elif schedule == "panel_streaming":
        a_bytes = m * k * dtype_bytes                      # A once (resident)
        b_bytes = k * n * gm * dtype_bytes                 # B per A-panel
        refills = gm + gm * gn                             # A panels + B tiles
    else:
        raise KeyError(schedule)
    c_bytes = m * n * 4  # f32 out written once by both schedules
    hbm = a_bytes + b_bytes + c_bytes
    mxu = (
        ((m + MXU_DIM - 1) // MXU_DIM)
        * ((n + MXU_DIM - 1) // MXU_DIM)
        * ((k + MXU_DIM - 1) // MXU_DIM)
    )
    t_compute = flops / PEAK_FLOPS_BF16
    t_mem = hbm / HBM_BW
    stall = max(0.0, t_mem - t_compute) * CLOCK_HZ
    return {
        "FLOPS": flops,
        "HBM_BYTES": float(hbm),
        "VMEM_TILE_REFILLS": float(refills),
        "MXU_PASSES": float(mxu),
        "EST_STALL_CYCLES": stall,
        "vmem_working_set_bytes": float(
            (bm * bk + bk * bn + bm * bn * 2) * dtype_bytes
            if schedule == "cache_blocked"
            else (bm * k + k * bn + bm * bn * 2) * dtype_bytes
        ),
        "arithmetic_intensity": flops / hbm,
    }
