"""Pure-jnp oracles for every Pallas kernel (the allclose reference).

No Pallas, no control-flow tricks — the numerically obvious formulation.
Tests sweep shapes/dtypes and assert the kernels match these within dtype
tolerance (kernels run in interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jnp.ndarray, b: jnp.ndarray,
           out_dtype=jnp.float32) -> jnp.ndarray:
    """C = A @ B with f32 accumulation. a: [M,K], b: [K,N]."""
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def attention(q, k, v, causal: bool = True, window: int = 0,
              scale: float | None = None):
    """Materialized-probs attention. q,k,v: [b,s,h,d] (same h: MHA view).

    GQA is handled by the caller repeating KV heads; the kernel contract is
    plain multi-head attention.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k, v, lengths=None, scale: float | None = None):
    """One-token decode oracle. q: [b,1,h,d]; k,v: [b,S,h,d];
    lengths: [b] int32 — number of valid cache positions (None: all)."""
    b, _, h, d = q.shape
    S = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if lengths is not None:
        valid = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
        s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssm_scan(x, log_a, b_in):
    """Diagonal linear recurrence oracle: h_t = a_t * h_{t-1} + b_t.

    x is unused shape anchor kept for API parity; inputs:
      log_a: [B, S, D] f32 — log of the decay gate per step/channel
      b_in:  [B, S, D] f32 — the driven input (already gated)
    Returns h: [B, S, D] f32, h_0 = b_0.
    """
    del x

    def step(h, ab):
        la, bb = ab
        h = jnp.exp(la) * h + bb
        return h, h

    la = jnp.moveaxis(log_a.astype(jnp.float32), 1, 0)
    bb = jnp.moveaxis(b_in.astype(jnp.float32), 1, 0)
    h0 = jnp.zeros(la.shape[1:], jnp.float32)
    _, hs = jax.lax.scan(step, h0, (la, bb))
    return jnp.moveaxis(hs, 0, 1)
