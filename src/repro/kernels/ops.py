"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) every kernel runs in interpret mode — the kernel
body executes in Python for correctness validation; on a TPU backend the
same calls lower to Mosaic.  Wrappers also adapt model-layer calling
conventions (GQA [b,s,h,d]) to the kernel contracts ([bh,s,d]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attn as _fa
from . import gemm as _gemm
from . import probe_reduce as _pr
from . import ssm_scan as _ssm

SCHEDULES = ("cache_blocked", "panel_streaming")


def _interpret(flag: bool | None) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# fused probe-moment reduction (the monitoring hot path)
# ---------------------------------------------------------------------------

# Below this many elements the grid/pad bookkeeping outweighs the fused
# sweep; the probe path uses the jnp fallback instead.
MIN_PALLAS_MOMENT_NUMEL = 1 << 15


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "with_entropy")
)
def probe_moments(x, *, block_rows: int = 256, interpret: bool | None = None,
                  with_entropy: bool = False):
    """Raw probe-moment vector f32[8] (f32[9] with the plan-requested
    ``ent_sum`` channel; see probe_reduce.MOMENTS/MOMENTS_ENT) of ``x``.

    Single tiled pass over the tensor: interpret mode on CPU, Mosaic on TPU.
    """
    return _pr.moments_pallas(
        x, block_rows=block_rows, interpret=_interpret(interpret),
        with_entropy=with_entropy,
    )


def tensor_moments(x, names, *, use_pallas: bool | None = None) -> dict:
    """{channel: f32 scalar} for the probe path — the ONE sweep per tensor.

    ``names`` is the exact channel tuple a MomentPlan (core/plan.py) compiled
    for the active event set — the sweep computes nothing outside it (plus
    the free trace-time constants ``numel``/``rows``).

    Policy: the Pallas kernel on TPU for large float tensors; the fused-jnp
    fallback for tiny/oddly-shaped/non-float tensors and on CPU, where
    interpret-mode Pallas would be a correctness tool, not a fast path.
    """
    if use_pallas is None:
        use_pallas = (
            jax.default_backend() == "tpu"
            and jnp.issubdtype(x.dtype, jnp.floating)
            and x.size >= MIN_PALLAS_MOMENT_NUMEL
        )
    if use_pallas:
        with_entropy = "ent_sum" in set(names)
        vec = probe_moments(x, with_entropy=with_entropy)
        chans = _pr.MOMENTS_ENT if with_entropy else _pr.MOMENTS
        out = dict(zip(chans, vec))
        out.update(_pr.static_channel_values(x.shape))  # exact numel + rows
        return out
    return _pr.named_moments_jnp(x, names)


# ---------------------------------------------------------------------------
# GEMM (case-study subject)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("schedule", "bm", "bn", "bk", "interpret")
)
def matmul(a, b, schedule: str = "panel_streaming", *, bm: int = 256,
           bn: int = 256, bk: int = 256, interpret: bool | None = None):
    """C = A @ B via the named Pallas schedule (f32 out)."""
    interp = _interpret(interpret)
    if schedule == "cache_blocked":
        return _gemm.cache_blocked_matmul(
            a, b, bm=bm, bn=bn, bk=bk, interpret=interp
        )
    if schedule == "panel_streaming":
        return _gemm.panel_streaming_matmul(
            a, b, bm=bm, bn=bn, interpret=interp
        )
    raise KeyError(f"unknown schedule {schedule!r}; have {SCHEDULES}")


def matmul_cost(schedule: str, m: int, n: int, k: int, *, bm: int = 256,
                bn: int = 256, bk: int = 256, dtype_bytes: int = 2) -> dict:
    """Analytical counters for one matmul call (the case-study events)."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    return _gemm.schedule_cost(schedule, m, n, k, bm, bn, bk, dtype_bytes)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 1024,
                    interpret: bool | None = None):
    """Model-layer convention: q [b,sq,h,d]; k,v [b,sk,kvh,d] (GQA ok)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    out = _fa.flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=_interpret(interpret),
    )
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def flash_attention_cost(b, sq, sk, h, d, *, causal=True, block_q=512,
                         block_kv=1024, dtype_bytes=2) -> dict:
    """Analytical counters: tiles actually computed after causal skipping."""
    bq, bkv = min(block_q, sq), min(block_kv, sk)
    nq, nk = sq // bq, (sk + bkv - 1) // bkv
    offs = sk - sq
    live = 0
    for i in range(nq):
        for j in range(nk):
            if not causal or j * bkv <= i * bq + bq - 1 + offs:
                live += 1
    flops = 4.0 * b * h * live * bq * bkv * d  # qk^T + pv
    hbm = (
        b * h * (sq * d * dtype_bytes                # q read once
                 + live * bkv * d * 2 * dtype_bytes  # k+v per live tile
                 + sq * d * dtype_bytes)             # out write
    )
    return {
        "FLOPS": flops,
        "HBM_BYTES": float(hbm),
        "VMEM_TILE_REFILLS": float(b * h * (nq + 2 * live)),
        "MXU_PASSES": float(
            b * h * live * (bq // 128 or 1) * (bkv // 128 or 1)
            * 2 * max(1, d // 128)
        ),
        "live_tiles": live,
        "total_tiles": nq * nk,
    }


# ---------------------------------------------------------------------------
# chunked SSM scan
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("chunk", "bd", "interpret")
)
def ssm_scan(log_a, b_in, *, chunk: int = 256, bd: int = 512,
             interpret: bool | None = None):
    """h_t = exp(log_a_t)*h_{t-1} + b_t over axis 1. [B,S,D] -> [B,S,D]."""
    return _ssm.ssm_scan_chunked(
        log_a, b_in, chunk=chunk, bd=bd, interpret=_interpret(interpret)
    )
