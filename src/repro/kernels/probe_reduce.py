"""Fused single-pass probe reduction — the monitoring hot path's kernel.

A scope probing ACT_RMS, ACT_MEAN_ABS, ACT_MAX_ABS, ACT_ZERO_FRAC, NAN_COUNT
and INF_COUNT used to sweep the same activation once *per event*: six HBM
reads of one tensor to produce six scalars.  This kernel computes the raw
*moment vector*

    [sum, sum_sq, sum_abs, max_abs, zero_count, nan_count, inf_count, numel]

in ONE tiled sweep with a VMEM accumulator; every moment-derived event is
then a cheap scalar finalizer over this vector (events.py stage 2).  The
probe-plan layer (core/plan.py) may additionally request the optional
``ent_sum`` channel (sum of x*log(x+eps), the raw accumulator behind
ATTN_ENTROPY) — a static kernel variant with one extra lane of the same
sweep, so even entropy-bearing scopes read their tensor exactly once.  The
same batching-of-counter-collection argument appears in Scaler and LIKWID:
monitoring stays lightweight only if counter reads share their passes over
the data.

Layout: the input is flattened (no copy) and a 1-D grid walks flat blocks
of block_rows*128 elements, retiled to (sublanes, lanes) in-kernel; partial
moments accumulate into a (1, 8) f32 output block that every grid step maps
to (revisiting semantics keep it VMEM-resident).  The last block may run
ragged past the end of the array; out-of-bounds lanes are masked via the
global element index, so non-tile-aligned shapes are exact — and never pay
a pad copy.  NaNs propagate through sum/sum_sq/sum_abs/max_abs exactly as
they do through the unfused ``jnp`` reductions, so fused and legacy event
values agree even on poisoned tensors.

``jax.experimental.pallas`` is imported lazily so this module (which owns
the moment-vector contract) stays importable from the core event registry
without dragging the full kernel stack in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Canonical moment order — the contract between this kernel, the jnp
# reference/fallback, and the event finalizers in core/events.py.
MOMENTS = (
    "sum",
    "sum_sq",
    "sum_abs",
    "max_abs",
    "zero_count",
    "nan_count",
    "inf_count",
    "numel",
)
(
    M_SUM,
    M_SUM_SQ,
    M_SUM_ABS,
    M_MAX_ABS,
    M_ZERO,
    M_NAN,
    M_INF,
    M_NUMEL,
) = range(len(MOMENTS))

# Optional fused channel (probe-plan layer): sum of x*log(x+eps), the raw
# accumulator behind ATTN_ENTROPY.  Appended AFTER the base vector so every
# M_* index above stays valid whether or not a plan requests entropy.
ENT_EPS = 1e-9
MOMENTS_ENT = MOMENTS + ("ent_sum",)
M_ENT = len(MOMENTS)

# Trace-time-constant channels the sweep never has to compute: element count
# and last-axis row count (prod(shape[:-1]) — the divisor of a row-mean such
# as attention entropy).  core/events.CHANNELS = sweep channels + these.
STATIC_CHANNELS = ("numel", "rows")

LANES = 128  # TPU vector lane count; last-axis tile width


def static_channel_values(shape) -> dict:
    """{static channel: f32 constant} for a tensor of ``shape`` (free)."""
    import numpy as np

    numel = int(np.prod(shape)) if shape else 1
    last = shape[-1] if shape else 1
    rows = numel // last if last else 0
    return {"numel": jnp.float32(numel), "rows": jnp.float32(rows)}


def _moment_kernel(x_ref, o_ref, *, numel: int, block_rows: int,
                   with_entropy: bool):
    """One grid step: fold a block_rows*LANES flat block into the accumulator.

    The final grid step may run past the end of the input (ragged tail) —
    out-of-bounds lanes carry unspecified values, so every use of ``x`` is
    select-masked by the global element index before any reduction.
    """
    import jax.experimental.pallas as pl

    n_chan = len(MOMENTS_ENT) if with_entropy else len(MOMENTS)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # retile the flat block to (sublanes, lanes) — TPU wants 2-D iota
    x = x_ref[...].reshape(block_rows, LANES).astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    idx = (i * block_rows + rows) * LANES + cols
    valid = idx < numel

    xm = jnp.where(valid, x, 0.0)  # NaN/Inf survive in valid lanes
    ax = jnp.abs(xm)
    one = jnp.float32(1.0)
    zero = jnp.float32(0.0)
    channels = [
        jnp.sum(xm),
        jnp.sum(xm * xm),
        jnp.sum(ax),
        zero,  # max channel handled below (max, not add)
        jnp.sum(jnp.where(valid & (x == 0), one, zero)),
        jnp.sum(jnp.where(valid & jnp.isnan(x), one, zero)),
        jnp.sum(jnp.where(valid & jnp.isinf(x), one, zero)),
        zero,  # numel is a trace-time constant, written by the wrapper:
        # accumulating the mask sum in f32 would round above 2^24 elements
    ]
    if with_entropy:
        # masked lanes contribute 0*log(eps) == 0; NaN/-x propagate exactly
        # like the unfused reference p*log(p+eps)
        channels.append(jnp.sum(xm * jnp.log(xm + jnp.float32(ENT_EPS))))
    part = jnp.stack(channels).reshape(1, n_chan)

    acc = o_ref[...]
    chan = jax.lax.broadcasted_iota(jnp.int32, (1, n_chan), 1)
    new_max = jnp.maximum(acc[0, M_MAX_ABS], jnp.max(ax))
    o_ref[...] = jnp.where(chan == M_MAX_ABS, new_max, acc + part)


def moments_pallas(x, *, block_rows: int = 256, interpret: bool = False,
                   with_entropy: bool = False):
    """Raw moment vector f32[8] (f32[9] with entropy) in a single tiled pass.

    The input is only flattened (a layout-preserving reshape, not a copy);
    non-aligned sizes are handled by letting the LAST grid step run ragged
    past the end of the array and masking in-kernel — no ``jnp.pad``, which
    would re-materialize the whole tensor and double the HBM traffic the
    kernel exists to remove.  ``with_entropy`` (static, plan-driven) appends
    the ``ent_sum`` channel to the same sweep.
    """
    n = int(x.size)
    if n == 0:
        return moments_ref(x, with_entropy=with_entropy)
    n_chan = len(MOMENTS_ENT) if with_entropy else len(MOMENTS)
    xf = x.reshape(-1)
    block = block_rows * LANES
    grid = (n + block - 1) // block

    import jax.experimental.pallas as pl

    out = pl.pallas_call(
        functools.partial(_moment_kernel, numel=n, block_rows=block_rows,
                          with_entropy=with_entropy),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, n_chan), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_chan), jnp.float32),
        interpret=interpret,
    )(xf)
    return out[0].at[M_NUMEL].set(jnp.float32(n))


def moments_ref(x, *, with_entropy: bool = False):
    """Pure-jnp oracle: the same moment vector from unfused reductions."""
    xf = x.astype(jnp.float32).reshape(-1)
    ax = jnp.abs(xf)
    n = xf.size
    chans = [
        jnp.sum(xf),
        jnp.sum(xf * xf),
        jnp.sum(ax),
        jnp.max(ax) if n else jnp.float32(0.0),
        jnp.sum((xf == 0).astype(jnp.float32)),
        jnp.sum(jnp.isnan(xf).astype(jnp.float32)),
        jnp.sum(jnp.isinf(xf).astype(jnp.float32)),
        jnp.float32(n),
    ]
    if with_entropy:
        chans.append(jnp.sum(xf * jnp.log(xf + jnp.float32(ENT_EPS))))
    return jnp.stack(chans)


def named_moments_jnp(x, names) -> dict:
    """Only the requested channels, as a {name: f32 scalar} dict.

    The fallback the probe path uses off-TPU.  The probe-plan layer hands in
    the EXACT per-event-set channel tuple, so the sweep computes nothing an
    inactive slot would need.  All requested accumulators ride ONE variadic
    ``lax.reduce`` — XLA:CPU lowers this to a single loop over the data with
    k accumulator updates (measured ~3x faster than k sibling ``jnp``
    reductions at 1 MiB), so the single-pass property holds even where the
    Pallas kernel doesn't run.  ``numel``/``rows`` are trace-time constants
    and cost nothing (always included).
    """
    sweep = MOMENTS_ENT[:M_NUMEL] + ("ent_sum",)
    need = [n for n in sweep if n in set(names)]
    out: dict = dict(static_channel_values(x.shape))  # constants, free
    if not need:
        return out
    if x.size == 0:
        ref = moments_ref(x, with_entropy=True)
        out.update((n, ref[MOMENTS_ENT.index(n)]) for n in need)
        return out
    xf = x.astype(jnp.float32).reshape(-1)
    ax = jnp.abs(xf)  # shared producer; fused into the reduce by XLA
    producers = {
        "sum": lambda: xf,
        "sum_sq": lambda: xf * xf,
        "sum_abs": lambda: ax,
        "max_abs": lambda: ax,
        "zero_count": lambda: (xf == 0).astype(jnp.float32),
        "nan_count": lambda: jnp.isnan(xf).astype(jnp.float32),
        "inf_count": lambda: jnp.isinf(xf).astype(jnp.float32),
        "ent_sum": lambda: xf * jnp.log(xf + jnp.float32(ENT_EPS)),
    }
    operands = tuple(producers[n]() for n in need)
    inits = tuple(jnp.float32(0.0) for _ in need)
    is_max = tuple(n == "max_abs" for n in need)

    def combine(acc, val):
        return tuple(
            jnp.maximum(a, v) if mx else a + v
            for a, v, mx in zip(acc, val, is_max)
        )

    res = jax.lax.reduce(operands, inits, combine, (0,))
    out.update(zip(need, res))
    return out
