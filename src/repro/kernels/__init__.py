"""Pallas TPU kernels for the perf-critical hot spots.

gemm       — blocked matmul, two schedules (the paper's case-study subjects)
flash_attn — tiled online-softmax attention (long-context cells)
ssm_scan   — chunked linear-recurrence scan (xlstm / zamba2 state updates)

ops.py is the public jit'd surface; ref.py the pure-jnp oracles the tests
sweep against (interpret=True on CPU).
"""
from . import ops, ref  # noqa: F401
from .ops import flash_attention, matmul, matmul_cost, ssm_scan  # noqa: F401
