"""Pallas TPU kernels for the perf-critical hot spots.

gemm         — blocked matmul, two schedules (the paper's case-study subjects)
flash_attn   — tiled online-softmax attention (long-context cells)
ssm_scan     — chunked linear-recurrence scan (xlstm / zamba2 state updates)
probe_reduce — fused single-pass probe-moment reduction (monitoring hot path)

ops.py is the public jit'd surface; ref.py the pure-jnp oracles the tests
sweep against (interpret=True on CPU).
"""
from . import ops, probe_reduce, ref  # noqa: F401
from .ops import (  # noqa: F401
    flash_attention,
    matmul,
    matmul_cost,
    probe_moments,
    ssm_scan,
    tensor_moments,
)
