"""Pallas flash attention (tiled online-softmax) for TPU.

Adaptation notes (GPU flash-attention -> TPU):
  * the unit of tiling is the VMEM block, not an SM's shared-memory tile;
    block shapes default to (block_q=512, block_kv=1024) so the score tile
    [bq, bkv] and the f32 accumulator [bq, d] stay well inside ~16 MB VMEM
    while keeping the MXU contraction dims >= 128;
  * there are no warps; the grid is (batch*heads, q_blocks, kv_blocks) with
    the KV axis innermost — Pallas pipelines the HBM->VMEM streams, and the
    running (acc, m, l) state lives in VMEM scratch across KV iterations;
  * causal block-skipping: fully-masked (q,kv) tiles are skipped with
    pl.when — the TPU analogue of flash attention's early exit.

Contract: plain MHA — q,k,v [bh, s, d] (GQA callers repeat KV heads in the
ops.py wrapper).  Accumulation in f32, output in q.dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sq: int, sk: int, bq: int, bkv: int, n_kv: int,
                  causal: bool, window: int, scale: float):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions (offs aligns the causal diagonal when sq != sk)
    offs = sk - sq
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + offs
    kpos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # tile-level skip: is any (q,k) pair in this tile live?
    q_hi = iq * bq + (bq - 1) + offs
    k_lo = jk * bkv
    live = True
    if causal:
        live = k_lo <= q_hi
    if window:
        k_hi = jk * bkv + (bkv - 1)
        q_lo = iq * bq + offs
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _work():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # [bq, bkv]
        mask = kpos < sk                           # pad keys masked off
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)

        v = v_ref[0].astype(jnp.float32)           # [bkv, d]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                          # [bq, d]
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new

    @pl.when(jk == n_kv - 1)
    def _flush():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 512, block_kv: int = 1024,
                         scale: float | None = None,
                         interpret: bool = False):
    """q: [bh, sq, d]; k, v: [bh, sk, d] (sk may exceed sq: KV prefix)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    assert sq % bq == 0, (sq, bq)
    # pad keys to a bkv multiple; padded positions are masked by kpos < sk
    sk_pad = ((sk + bkv - 1) // bkv) * bkv
    if sk_pad != sk:
        pad = ((0, 0), (0, sk_pad - sk), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    n_kv = sk_pad // bkv

    kernel = functools.partial(
        _flash_kernel, sq=sq, sk=sk, bq=bq, bkv=bkv, n_kv=n_kv,
        causal=causal, window=window, scale=scale,
    )
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
