"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

40 heads do not divide the 16-way model axis: attention runs in the
batch-parallel (Ulysses-style) fallback; MLP/vocab keep standard TP.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat="none",
)

CELLS = {
    "default": {"opt_state": "f32"},
    "train_4k": {"microbatches": 2},
}
