"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

Memory plan (16 GB/chip): fp32 params updated in place (no separate
master), Adafactor-style factored second moment, no momentum,
microbatches=1 (no fp32 accumulation buffer), per-leaf f32 grad casts;
experts 8/chip under 16-way expert parallelism.  Measured bytes in
EXPERIMENTS.md §Dry-run.
"""
from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual=True, dense_ff=4864),
)

SMOKE = CONFIG.replace(
    name="arctic-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=512, param_dtype="float32", compute_dtype="float32",
    remat="none",
    moe=MoEConfig(n_experts=8, top_k=2, dense_residual=True, dense_ff=96),
)

CELLS = {
    "default": {"opt_state": "factored", "opt_momentum": False,
                "opt_master": False},
    "train_4k": {"microbatches": 1,
                 "model_overrides": {"param_dtype": "float32"}},
    "prefill_32k": {"microbatches": 1},
}
