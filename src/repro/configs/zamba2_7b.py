"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified].

81 layers = 13 groups of (6 Mamba2 + shared attention at 2*d_model) + 3
trailing Mamba2 layers; the attention/MLP block weights are shared across
all 13 application sites (Zamba2's parameter-sharing trick).
"""
from repro.models import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid=HybridConfig(attn_every=6, concat_embedding=True),
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke", n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, param_dtype="float32", compute_dtype="float32",
    remat="none", ssm=SSMConfig(chunk=16, head_dim=16),
    hybrid=HybridConfig(attn_every=2),
)

CELLS = {
    "default": {"opt_state": "f32"},
    "train_4k": {"microbatches": 2},
}
