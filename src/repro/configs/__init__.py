"""Assigned-architecture configs (--arch <id>).

Each module defines CONFIG (exact assigned hyperparameters), SMOKE (reduced
same-family config for CPU tests) and CELLS (per-shape execution policy:
microbatches, optimizer tier — chosen to fit the 16 GB/chip v5e budget; see
EXPERIMENTS.md §Dry-run for the measured bytes).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "xlstm_125m",
    "command_r_plus_104b",
    "mistral_nemo_12b",
    "qwen3_14b",
    "qwen3_32b",
    "zamba2_7b",
    "dbrx_132b",
    "arctic_480b",
    "seamless_m4t_medium",
    "pixtral_12b",
]

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(arch_id: str) -> str:
    arch_id = arch_id.replace("-", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return arch_id


def load(arch_id: str):
    """Returns the config module for an arch id (accepts - or _ forms)."""
    return importlib.import_module(
        f"repro.configs.{canonical(arch_id)}"
    )


def model_config(arch_id: str, smoke: bool = False, **overrides):
    mod = load(arch_id)
    cfg = mod.SMOKE if smoke else mod.CONFIG
    return cfg.replace(**overrides) if overrides else cfg


def cell_policy(arch_id: str, shape_name: str) -> dict:
    mod = load(arch_id)
    cells = getattr(mod, "CELLS", {})
    out = dict(cells.get("default", {}))
    out.update(cells.get(shape_name, {}))
    return out
