"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified].
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000,
    use_bias=False, tie_embeddings=True, rope_theta=75e6,
)

SMOKE = CONFIG.replace(
    name="command-r-plus-smoke", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=256, vocab=512, param_dtype="float32",
    compute_dtype="float32", remat="none",
)

CELLS = {
    "default": {"opt_state": "int8"},
    "train_4k": {"microbatches": 8},
    "prefill_32k": {"microbatches": 1},
}
