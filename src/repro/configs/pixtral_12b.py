"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

Backbone only per the assignment: the ViT frontend is a stub (input_specs
provides precomputed patch embeddings, merged as a sequence prefix).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128, rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    name="pixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat="none",
)

CELLS = {
    "default": {"opt_state": "f32"},
    "train_4k": {"microbatches": 2},
}
