"""xlstm-125m [ssm]: 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304.

sLSTM + mLSTM blocks, alternating (6 pairs) [arXiv:2405.04517; unverified].
d_ff=0: the xLSTM cells carry their own up/down projections.
"""
from repro.models import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    ssm=SSMConfig(chunk=256, slstm_every=2),
)

SMOKE = CONFIG.replace(
    name="xlstm-125m-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, vocab=512, param_dtype="float32",
    compute_dtype="float32", remat="none", ssm=SSMConfig(chunk=16),
)

CELLS = {
    "default": {"opt_state": "f32"},
    "train_4k": {"microbatches": 1},
}
