"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only per the assignment: the speech frontend is a stub
(input_specs provides precomputed frame embeddings).  12 encoder + 12
decoder layers.  vocab 256206 is not divisible by the 16-way model axis;
the unembed stays replicated on that dim (relaxed sharding) — the model is
small enough that this costs <0.6 GB/chip.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab=256206, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="seamless-smoke", n_layers=2, n_encoder_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    param_dtype="float32", compute_dtype="float32", remat="none",
)

CELLS = {
    "default": {"opt_state": "f32"},
    "train_4k": {"microbatches": 1},
}
