"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].
"""
from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4, capacity_factor=1.25),
)

SMOKE = CONFIG.replace(
    name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, param_dtype="float32", compute_dtype="float32",
    remat="none", moe=MoEConfig(n_experts=4, top_k=2),
)

CELLS = {
    "default": {"opt_state": "int8"},
    "train_4k": {"microbatches": 8},
}
