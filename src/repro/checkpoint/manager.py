"""Fault-tolerant checkpointing: atomic, keep-k, async, elastic re-mesh.

* Atomicity: write into ``<dir>/tmp.<step>`` then ``os.rename`` to
  ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
* keep-k GC; ``latest()`` discovery for restart-after-failure.
* Async: device->host transfer happens synchronously (cheap), file IO in a
  background thread so the train loop isn't blocked.
* Elastic: leaves are stored unsharded (by keypath) with dtype/shape
  metadata; ``restore_tree`` re-stages them under *any* mesh/sharding, so a
  job can resume on a different topology (the elastic-scaling test resizes
  the mesh between save and restore).

Format: one ``.npz`` per checkpoint + a JSON manifest.  On a real multi-pod
deployment the npz writer would be swapped for a per-process sharded writer
(same manifest contract); single-process here, as the container has one host.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(jax.device_get(leaf))
        # npz cannot round-trip ml_dtypes (bfloat16, fp8); widen to f32 —
        # exact for bf16, and restore casts back to the target leaf dtype.
        if arr.dtype.kind == "V" or str(arr.dtype) in (
            "bfloat16", "float8_e4m3fn", "float8_e5m2"
        ):
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_tree(path: str, tree, extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if extra is not None:
        with open(path + ".json", "w") as f:
            json.dump(extra, f)


def restore_tree(path: str, like, mesh=None, axes=None):
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).

    With ``mesh``+``axes`` (logical axes tree), every leaf is device_put with
    its NamedSharding — this is the elastic re-mesh path.
    """
    from repro.dist.partition import logical_to_pspec
    from jax.sharding import NamedSharding

    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    ax_flat = None
    if axes is not None:
        ax_flat = [
            leaf for _, leaf in jax.tree_util.tree_flatten_with_path(
                axes, is_leaf=lambda x: isinstance(x, tuple)
            )[0]
        ]
    out = []
    for i, (path_k, leaf) in enumerate(leaves_like):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k
        )
        arr = data[key]
        want = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
        if arr.shape != tuple(want.shape):
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != {want.shape}"
            )
        # cast via jax (numpy has no bf16 cast); exact for widened bf16
        arr = np.asarray(jax.numpy.asarray(arr).astype(want.dtype))
        if mesh is not None and ax_flat is not None:
            sh = NamedSharding(mesh, logical_to_pspec(ax_flat[i], mesh=mesh))
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, want.dtype))
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out)


_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery --------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                os.path.join(self.dir, name, "state.npz")
            ):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None,
             block: bool = False) -> None:
        flat = _flatten(tree)  # device->host now; IO later
        extra = dict(extra or {}, step=step, time=time.time())

        def write():
            tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(extra, f)
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):
                import shutil

                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            import shutil

            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def metadata(self, step: int) -> dict:
        """The checkpoint's manifest alone — readable BEFORE committing to
        a tensor restore, so resume-time validity checks (e.g. the monitor
        plan-fingerprint attestation) can fail with a real diagnostic
        instead of a shape mismatch mid-restore."""
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)

    def restore(self, step: int, like, mesh=None, axes=None):
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        tree = restore_tree(path, like, mesh=mesh, axes=axes)
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            meta = json.load(f)
        return tree, meta
