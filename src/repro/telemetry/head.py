"""Fleet head — the tree root's reporting and decision sink.

``FleetHead`` reads an ``Aggregator``'s :meth:`merged` view and turns it
into the fleet-level products ROADMAP item 2 asks for:

* **fleet percentiles** — p50/p95/p99 per (scope, event) lane from the
  merged reservoirs (per-host per-interval event means), labeled through
  ``plan.lane_slot_ids`` when the producing spec is at hand;
* **exact fleet counter sums** — the int64/f64 sums every accepted delta
  contributed to (cross-checked in tests against per-host oracles);
* **straggler flags** — per-host step rates (EWMA-smoothed with
  ``core.adaptive._Baseline``, the controller's own machinery) compared
  against the fleet median with a MAD scale and a relative floor: a host
  is a straggler when its rate sits ``sigma`` robust-deviations *below*
  the fleet, Kunafa's node-wide-outlier use case;
* **a JSONL fleet report** — one line per :meth:`write_report`, the fleet
  analogue of the per-process ``JsonlSink`` stream;
* **escalation hints** — :meth:`auto_hints` watches tripwire lanes
  (NAN_COUNT/INF_COUNT) for fresh fleet-level ticks and rebroadcasts a
  ``KIND_HINT`` down the tree so every per-process ``AdaptiveController``
  escalates together (the per-process gap noted in ROADMAP item 3).
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np

from repro.core import plan as plan_lib

from .aggregator import Aggregator, MergedView

_TRIPWIRE_EVENTS = ("NAN_COUNT", "INF_COUNT")


class FleetHead:
    """Reporting head over one (root) aggregator.

    aggregator       the tree root this head reads
    spec             optional producing ``MonitorSpec`` — labels lanes as
                     (scope, slot_id) and enables tripwire ``auto_hints``;
                     without it lanes are labeled ``lane<i>``
    percentiles      which fleet percentiles to report
    straggler_sigma  robust deviations below the fleet median that flag a
                     host (MAD scaled by 1.4826 to estimate sigma)
    straggler_floor  relative MAD floor — jitter below ``floor * median``
                     never flags (the per-process controller's rel_floor
                     idea applied fleet-wide)
    jsonl_path       optional path; ``write_report()`` appends one JSON
                     line per call
    """

    def __init__(self, aggregator: Aggregator, *, spec=None,
                 percentiles=(50.0, 95.0, 99.0),
                 straggler_sigma: float = 4.0,
                 straggler_floor: float = 0.05,
                 straggler_warmup: int = 3,
                 jsonl_path: str | None = None):
        self.aggregator = aggregator
        self.spec = spec
        self.percentiles = tuple(float(q) for q in percentiles)
        self.straggler_sigma = float(straggler_sigma)
        self.straggler_floor = float(straggler_floor)
        self.straggler_warmup = int(straggler_warmup)
        self.jsonl_path = jsonl_path
        self.reports_written = 0
        self.hints_broadcast = 0
        self._lane_labels: list[tuple[str, str]] | None = None
        self._tripwire_seen: dict[int, int] = {}
        self._lock = threading.Lock()
        if spec is not None:
            self._lane_labels = list(plan_lib.lane_slot_ids(spec))

    # -- lane naming -------------------------------------------------------
    def _labels(self, total: int) -> list[tuple[str, str]]:
        if self._lane_labels is not None and len(self._lane_labels) == total:
            return self._lane_labels
        return [("fleet", f"lane{i}") for i in range(total)]

    # -- straggler machinery -----------------------------------------------
    def straggler_flags(self, view: MergedView | None = None) -> dict:
        """host_id -> flag for every DIRECT leaf host with a known rate.

        Cross-host outlier test: median + MAD over the smoothed per-host
        step rates, flag hosts ``sigma`` robust-deviations LOW with a
        relative floor so ordinary jitter never flags.  (Rates ride the
        aggregator's per-host ``_Baseline``s; hosts folded in through
        child AGG frames carry no per-host rates — stragglers are a
        direct-attachment product, typically computed at depth-1 nodes.)
        """
        if view is None:
            view = self.aggregator.merged()
        rates = {
            hid: rec.smoothed_rate() for hid, rec in view.hosts.items()
            if rec.baseline.n >= self.straggler_warmup
        }
        if len(rates) < 2:
            return {hid: False for hid in rates}
        vals = np.asarray(list(rates.values()), np.float64)
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med)))
        scale = max(1.4826 * mad, self.straggler_floor * abs(med))
        thresh = med - self.straggler_sigma * scale
        return {hid: bool(r < thresh) for hid, r in rates.items()}

    # -- report assembly ---------------------------------------------------
    def snapshot(self) -> dict:
        """One structured fleet report (plain dict; JSON-serializable)."""
        view = self.aggregator.merged()
        labels = self._labels(view.values.shape[0])
        lanes = []
        for i, (scope, slot_id) in enumerate(labels):
            r = view.reservoirs[i] if i < len(view.reservoirs) else None
            pct = {}
            if r is not None and len(r):
                q = r.percentile(list(self.percentiles))
                pct = {f"p{g:g}": float(v)
                       for g, v in zip(self.percentiles, np.atleast_1d(q))}
            lanes.append({
                "scope": scope,
                "slot": slot_id,
                "sum": float(view.values[i]),
                "samples": int(view.samples[i]),
                "reservoir_n": 0 if r is None else len(r),
                "reservoir_seen": 0 if r is None else r.seen,
                **pct,
            })
        flags = self.straggler_flags(view)
        hosts = {
            hid: {
                "frames": rec.frames,
                "lost_frames": rec.lost_frames,
                "last_step": rec.last_step,
                "rate": None if np.isnan(rec.rate) else round(rec.rate, 3),
                "rate_smoothed": (round(rec.smoothed_rate(), 3)
                                  if rec.baseline.n else None),
                "shutdown": rec.shutdown,
                "straggler": flags.get(hid, False),
            }
            for hid, rec in view.hosts.items()
        }
        return {
            "ts": time.time(),
            "fingerprint": view.fingerprint,
            "n_hosts": view.n_hosts,
            "frames_in": view.frames_in,
            "dropped": view.dropped,
            "step_hi": view.step_hi,
            "calls": [int(c) for c in view.calls],
            "lanes": lanes,
            "hosts": hosts,
            "stragglers": sorted(h for h, f in flags.items() if f),
        }

    def write_report(self) -> dict:
        """Append one fleet snapshot line to ``jsonl_path`` (and return it)."""
        snap = self.snapshot()
        if self.jsonl_path is not None:
            line = json.dumps(snap, sort_keys=True)
            with self._lock:
                with open(self.jsonl_path, "a") as f:
                    f.write(line + "\n")
                self.reports_written += 1
        return snap

    # -- fleet-wide escalation hints ---------------------------------------
    def broadcast_hint(self, scope: str, reason: str, *,
                       tripwire: bool = False) -> int:
        """Push one escalation hint down the tree (scope "" = global)."""
        n = self.aggregator.broadcast_hint(scope, reason, tripwire=tripwire)
        self.hints_broadcast += 1
        return n

    def auto_hints(self) -> list[tuple[str, str]]:
        """Scan tripwire lanes for fresh fleet-level ticks and rebroadcast.

        Returns the (scope, reason) hints sent this call.  Needs ``spec``
        (lane labels) — without it, no lanes are recognizably tripwires.
        """
        if self._lane_labels is None:
            return []
        view = self.aggregator.merged()
        sent = []
        with self._lock:
            for i, (scope, slot_id) in enumerate(self._lane_labels):
                # slot ids read EVENT[:tensor][/subevent]; the tripwire
                # match is on the event part alone
                event = slot_id.split("/", 1)[0].split(":", 1)[0]
                if event not in _TRIPWIRE_EVENTS:
                    continue
                if i >= view.samples.shape[0]:
                    continue
                ticks = int(round(float(view.values[i])))
                if ticks > self._tripwire_seen.get(i, 0):
                    self._tripwire_seen[i] = ticks
                    reason = f"fleet:{event.lower()}"
                    sent.append((scope, reason))
        for scope, reason in sent:
            self.broadcast_hint(scope, reason, tripwire=True)
        return sent

    def __repr__(self) -> str:
        return (f"FleetHead(agg={self.aggregator.node_id!r}, "
                f"reports={self.reports_written}, "
                f"hints={self.hints_broadcast})")
