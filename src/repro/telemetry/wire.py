"""Fleet wire format — versioned binary frames for drained counter deltas.

The fleet tier (agent → aggregator tree → head) ships drained
``CompactDelta``s between hosts.  The spec-wide dense ``SlotLayout``
(core/plan.py) makes this a near-free flat-buffer pack: a delta is exactly
``calls[n_scopes] i32 + values[total] f32 + samples[total] i32`` in a lane
order that is **part of the wire contract** — see ``plan.lane_slot_ids``.
Both ends must agree on the producing spec, which is why every frame
carries the 20-byte plan fingerprint (``MonitorSpec.fingerprint``): an
aggregator REJECTS mismatched plans instead of silently merging counters
whose lanes mean different things.

Frame body layout (all multi-byte integers are LEB128 varints, zigzag for
signed; floats are little-endian IEEE):

    magic        2B   b"SC"
    version      1B   WIRE_VERSION
    kind         1B   KIND_DELTA | KIND_AGG | KIND_HINT
    flags        1B   bit 0: FLAG_SHUTDOWN (sender's final frame)
    host_id      varint length + utf-8
    seq          varint — per-sender frame counter (gap = lost frames)
    fingerprint  20B   raw sha1 of the producing plan (hex → bytes)
    step_lo      varint zigzag — first step the payload covers (exclusive)
    step_hi      varint zigzag — last step the payload covers (inclusive)
    payload      kind-specific (below)
    crc32        4B LE over magic..payload — truncation/corruption check

KIND_DELTA payload (one drained counter delta, dense layout):

    n_scopes     varint
    total        varint — SlotLayout.total (flat lane count)
    calls        n_scopes x varint zigzag
    samples      total x varint zigzag
    values       total x f32 LE (raw pack of the dense lane vector)

KIND_AGG payload (an aggregator's periodic upward downsample):

    n_hosts      varint — distinct leaf hosts below this node
    frames_in    varint — leaf frames merged below this node
    dropped      varint — frames lost below this node (seq gaps + rejects)
    n_scopes / total  varints
    calls        n_scopes x varint zigzag (int64 fleet sums)
    samples      total x varint zigzag   (int64 fleet sums)
    values       total x f64 LE          (f64 fleet sums)
    reservoirs   total x [seen varint, k varint, k x f32 LE]

KIND_HINT payload (head → agents escalation rebroadcast, downlink):

    scope        varint length + utf-8 ("" = global / wake sentinels)
    reason       varint length + utf-8
    tripwire     1B

On a stream, frames are length-prefixed (u32 LE body length); use
``FrameReader`` to incrementally split and decode.  Decoding raises
``TruncatedFrameError`` (ran out of bytes), ``CorruptFrameError`` (bad
magic/CRC/lengths) or ``VersionSkewError`` (unknown wire version) — the
aggregator accounts each class separately.

This module must stay device-free: it imports numpy only, never jax —
encode/decode run on telemetry drain threads and aggregator IO threads,
where dispatching device work would queue behind in-flight steps (the
ROADMAP drain invariant).  Tests attest it with a raising sys.modules
guard.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

MAGIC = b"SC"
WIRE_VERSION = 1

KIND_DELTA = 0
KIND_AGG = 1
KIND_HINT = 2
_KINDS = (KIND_DELTA, KIND_AGG, KIND_HINT)

FLAG_SHUTDOWN = 0x01

_FP_BYTES = 20          # sha1 — MonitorSpec.fingerprint is its hex form
_ZERO_FP = "0" * (2 * _FP_BYTES)


class WireError(ValueError):
    """Base class for frame decode failures."""


class TruncatedFrameError(WireError):
    """The buffer ended before the frame did."""


class CorruptFrameError(WireError):
    """Bad magic, CRC mismatch, or inconsistent lengths."""


class VersionSkewError(WireError):
    """The frame's wire version is not one this decoder speaks."""


# ---------------------------------------------------------------------------
# varint / zigzag primitives
# ---------------------------------------------------------------------------

def _put_uvarint(out: bytearray, v: int) -> None:
    if v < 0:
        raise ValueError(f"uvarint cannot encode negative value {v}")
    if v < 0x80:                # header fields are mostly one byte
        out.append(v)
        return
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _get_uvarint(buf: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    v = 0
    while True:
        if pos >= len(buf):
            raise TruncatedFrameError("varint ran off the end of the frame")
        if shift > 63:
            raise CorruptFrameError("varint longer than 64 bits")
        b = buf[pos]
        pos += 1
        v |= (b & 0x7F) << shift
        if not b & 0x80:
            return v, pos
        shift += 7


def _zigzag(v: int) -> int:
    return (v << 1) ^ (v >> 63) if v < 0 else v << 1


def _unzigzag(v: int) -> int:
    return (v >> 1) ^ -(v & 1)


def _put_svarint(out: bytearray, v: int) -> None:
    _put_uvarint(out, _zigzag(int(v)))


def _get_svarint(buf: bytes, pos: int) -> tuple[int, int]:
    v, pos = _get_uvarint(buf, pos)
    return _unzigzag(v), pos


def _put_bytes(out: bytearray, b: bytes) -> None:
    _put_uvarint(out, len(b))
    out.extend(b)


def _get_bytes(buf: bytes, pos: int) -> tuple[bytes, int]:
    n, pos = _get_uvarint(buf, pos)
    if pos + n > len(buf):
        raise TruncatedFrameError("length-prefixed field ran off the end")
    return buf[pos:pos + n], pos + n


def _get_raw(buf: bytes, pos: int, n: int, what: str) -> tuple[bytes, int]:
    if pos + n > len(buf):
        raise TruncatedFrameError(f"{what} ran off the end of the frame")
    return buf[pos:pos + n], pos + n


# Integer LANE ARRAYS ride a width-tagged fixed-width block instead of
# per-lane varints: one tag byte (bytes per lane: 1/2/4/8, the narrowest
# signed width spanning the array's range) followed by the lanes as
# little-endian SIGNED ints of that width.  Encode and decode are each a
# couple of whole-array numpy calls — the per-lane Python varint loop
# this replaces dominated frame codec time — and drained deltas (small
# counts) still pack to one byte per lane.  Any width that fits is a
# legal encoding; scalar header fields stay varints.
_INT_DTYPES = {1: np.dtype("<i1"), 2: np.dtype("<i2"),
               4: np.dtype("<i4"), 8: np.dtype("<i8")}
# below this many lanes a Python min/max over .tolist() beats two numpy
# reductions; monitored specs sit far under it, fleet AGG payloads above
_SMALL_BLOCK = 512


def _put_int_block(out: bytearray, arr: np.ndarray) -> None:
    n = arr.size
    if n == 0:
        out.append(1)
        return
    if n <= _SMALL_BLOCK:
        vals = arr.tolist()
        mn, mx = min(vals), max(vals)
    else:
        mn, mx = int(arr.min()), int(arr.max())
    if -(1 << 7) <= mn and mx < (1 << 7):
        width = 1
    elif -(1 << 15) <= mn and mx < (1 << 15):
        width = 2
    elif -(1 << 31) <= mn and mx < (1 << 31):
        width = 4
    else:
        width = 8
    out.append(width)
    out += arr.astype(_INT_DTYPES[width], copy=False).tobytes()


def _get_int_block(body: bytes, pos: int, n: int,
                   what: str) -> tuple[np.ndarray, int]:
    w_raw, pos = _get_raw(body, pos, 1, f"{what} width tag")
    width = w_raw[0]
    if width not in _INT_DTYPES:
        raise CorruptFrameError(f"bad {what} width tag {width}")
    raw, pos = _get_raw(body, pos, n * width, what)
    return np.frombuffer(raw, _INT_DTYPES[width]).astype(np.int64), pos


# ---------------------------------------------------------------------------
# Frame dataclass
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Frame:
    """One decoded wire frame (fields beyond the header are kind-gated)."""

    kind: int
    host_id: str
    seq: int
    fingerprint: str            # hex, like MonitorSpec.fingerprint
    step_lo: int
    step_hi: int
    shutdown: bool = False

    # KIND_DELTA / KIND_AGG counter payload
    calls: np.ndarray | None = None      # [n_scopes] i64
    values: np.ndarray | None = None     # [total] f32 (delta) / f64 (agg)
    samples: np.ndarray | None = None    # [total] i64

    # KIND_AGG extras
    n_hosts: int = 0
    frames_in: int = 0
    dropped: int = 0
    reservoirs: list | None = None       # per lane: (seen, np.ndarray f32)

    # KIND_HINT
    scope: str = ""
    reason: str = ""
    tripwire: bool = False


_FP_CACHE: dict[str, bytes] = {}


def _fp_raw(fingerprint: str) -> bytes:
    """hex → raw fingerprint, cached (one spec per process in practice)."""
    fp = fingerprint or _ZERO_FP
    raw = _FP_CACHE.get(fp)
    if raw is None:
        try:
            raw = bytes.fromhex(fp)
        except ValueError as e:
            raise ValueError(f"fingerprint must be hex, got {fp!r}") from e
        if len(raw) != _FP_BYTES:
            raise ValueError(
                f"fingerprint must be {_FP_BYTES} bytes ({2 * _FP_BYTES} "
                f"hex chars), got {len(raw)}")
        if len(_FP_CACHE) > 64:
            _FP_CACHE.clear()
        _FP_CACHE[fp] = raw
    return raw


def _header(kind: int, host_id: str, seq: int, fingerprint: str,
            step_lo: int, step_hi: int, shutdown: bool) -> bytearray:
    fp_raw = _fp_raw(fingerprint)
    out = bytearray()
    out += MAGIC
    out.append(WIRE_VERSION)
    out.append(kind)
    out.append(FLAG_SHUTDOWN if shutdown else 0)
    _put_bytes(out, host_id.encode())
    _put_uvarint(out, int(seq))
    out += fp_raw
    _put_svarint(out, int(step_lo))
    _put_svarint(out, int(step_hi))
    return out


def _seal(out: bytearray) -> bytes:
    out += struct.pack("<I", zlib.crc32(out) & 0xFFFFFFFF)
    return bytes(out)


# ---------------------------------------------------------------------------
# Encoders
# ---------------------------------------------------------------------------

def encode_delta(calls, values, samples, *, host_id: str, seq: int,
                 fingerprint: str, step_lo: int, step_hi: int,
                 shutdown: bool = False) -> bytes:
    """Pack one drained counter delta (host numpy, dense SlotLayout order).

    ``calls``: [n_scopes] ints; ``values``: [total] floats; ``samples``:
    [total] ints — exactly a drained ``CompactDelta``'s leaves.  Count
    lanes ride width-tagged fixed-width blocks (drained deltas are small
    ints, so most lanes cost one byte); values are a raw f32 pack.
    """
    calls = np.asarray(calls).reshape(-1)
    values = np.asarray(values, np.float32).reshape(-1)
    samples = np.asarray(samples).reshape(-1)
    if values.shape != samples.shape:
        raise ValueError(
            f"values/samples lane counts differ: {values.shape} vs "
            f"{samples.shape}")
    out = _header(KIND_DELTA, host_id, seq, fingerprint, step_lo, step_hi,
                  shutdown)
    _put_uvarint(out, calls.shape[0])
    _put_uvarint(out, values.shape[0])
    _put_int_block(out, calls)
    _put_int_block(out, samples)
    out += values.tobytes()
    return _seal(out)


class DeltaStreamEncoder:
    """Per-stream delta encoder with the constant header parts prebuilt.

    One fleet stream repeats (host_id, fingerprint, lane counts) on every
    frame — this precomputes those byte runs once so the per-frame work is
    only the varying fields plus the lane payloads.  Produces bytes
    identical to :func:`encode_delta`.
    """

    __slots__ = ("_pre_host", "_fp_raw", "_counts")

    def __init__(self, host_id: str, fingerprint: str):
        pre = bytearray()
        pre += MAGIC
        pre.append(WIRE_VERSION)
        pre.append(KIND_DELTA)
        pre.append(0)                     # flags slot (index 4)
        _put_bytes(pre, host_id.encode())
        self._pre_host = bytes(pre)
        self._fp_raw = _fp_raw(fingerprint)
        self._counts: dict[tuple[int, int], bytes] = {}

    def encode(self, calls, values, samples, *, seq: int, step_lo: int,
               step_hi: int, shutdown: bool = False) -> bytes:
        values = np.asarray(values, np.float32)     # no-op when f32
        out = bytearray(self._pre_host)
        if shutdown:
            out[4] = FLAG_SHUTDOWN
        _put_uvarint(out, seq)
        out += self._fp_raw
        _put_svarint(out, step_lo)
        _put_svarint(out, step_hi)
        key = (calls.shape[0], values.shape[0])
        counts = self._counts.get(key)
        if counts is None:
            cb = bytearray()
            _put_uvarint(cb, key[0])
            _put_uvarint(cb, key[1])
            counts = self._counts[key] = bytes(cb)
        out += counts
        _put_int_block(out, calls)
        _put_int_block(out, samples)
        out += values.tobytes()
        return _seal(out)


def encode_agg(calls, values, samples, reservoirs, *, host_id: str,
               seq: int, fingerprint: str, step_lo: int, step_hi: int,
               n_hosts: int, frames_in: int, dropped: int,
               shutdown: bool = False) -> bytes:
    """Pack an aggregator's merged state for its parent (tree fan-in).

    ``reservoirs``: per flat lane, ``(seen, samples_f32_array)`` — the
    per-scope reservoir this node maintains; the parent merges them
    weighted by ``seen``.
    """
    calls = np.asarray(calls, np.int64).reshape(-1)
    values = np.asarray(values, np.float64).reshape(-1)
    samples = np.asarray(samples, np.int64).reshape(-1)
    if len(reservoirs) != values.shape[0]:
        raise ValueError(
            f"need one reservoir per lane: {len(reservoirs)} vs "
            f"{values.shape[0]}")
    out = _header(KIND_AGG, host_id, seq, fingerprint, step_lo, step_hi,
                  shutdown)
    _put_uvarint(out, int(n_hosts))
    _put_uvarint(out, int(frames_in))
    _put_uvarint(out, int(dropped))
    _put_uvarint(out, calls.shape[0])
    _put_uvarint(out, values.shape[0])
    _put_int_block(out, calls)
    _put_int_block(out, samples)
    out += values.tobytes()
    for seen, samp in reservoirs:
        samp = np.asarray(samp, np.float32).reshape(-1)
        _put_uvarint(out, int(seen))
        _put_uvarint(out, samp.shape[0])
        out += samp.tobytes()
    return _seal(out)


def encode_hint(scope: str, reason: str, *, host_id: str, seq: int,
                fingerprint: str = "", tripwire: bool = False) -> bytes:
    """Pack a head-level escalation hint (downlink; scope "" = global)."""
    out = _header(KIND_HINT, host_id, seq, fingerprint or _ZERO_FP, 0, 0,
                  False)
    _put_bytes(out, scope.encode())
    _put_bytes(out, reason.encode())
    out.append(1 if tripwire else 0)
    return _seal(out)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def decode_frame(buf: bytes) -> Frame:
    """Decode one frame body (no length prefix).  Raises WireError."""
    if len(buf) < len(MAGIC) + 3 + 4:
        raise TruncatedFrameError(f"frame too short ({len(buf)} bytes)")
    if buf[:2] != MAGIC:
        raise CorruptFrameError(f"bad magic {buf[:2]!r}")
    version = buf[2]
    if version != WIRE_VERSION:
        raise VersionSkewError(
            f"wire version {version} not supported (speaking "
            f"{WIRE_VERSION})")
    body, crc_raw = buf[:-4], buf[-4:]
    (crc,) = struct.unpack("<I", crc_raw)
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise CorruptFrameError("CRC mismatch (corrupt or truncated frame)")
    kind = buf[3]
    if kind not in _KINDS:
        raise CorruptFrameError(f"unknown frame kind {kind}")
    flags = buf[4]
    pos = 5
    host_raw, pos = _get_bytes(body, pos)
    seq, pos = _get_uvarint(body, pos)
    fp_raw, pos = _get_raw(body, pos, _FP_BYTES, "fingerprint")
    step_lo, pos = _get_svarint(body, pos)
    step_hi, pos = _get_svarint(body, pos)
    frame = Frame(
        kind=kind, host_id=host_raw.decode(), seq=seq,
        fingerprint=fp_raw.hex(), step_lo=step_lo, step_hi=step_hi,
        shutdown=bool(flags & FLAG_SHUTDOWN),
    )

    if kind == KIND_HINT:
        scope_raw, pos = _get_bytes(body, pos)
        reason_raw, pos = _get_bytes(body, pos)
        trip_raw, pos = _get_raw(body, pos, 1, "tripwire flag")
        frame.scope = scope_raw.decode()
        frame.reason = reason_raw.decode()
        frame.tripwire = bool(trip_raw[0])
        _expect_end(body, pos)
        return frame

    if kind == KIND_AGG:
        frame.n_hosts, pos = _get_uvarint(body, pos)
        frame.frames_in, pos = _get_uvarint(body, pos)
        frame.dropped, pos = _get_uvarint(body, pos)
    n_scopes, pos = _get_uvarint(body, pos)
    total, pos = _get_uvarint(body, pos)
    if n_scopes > len(body) or total > len(body):
        # a corrupted count would otherwise drive a huge decode loop
        raise CorruptFrameError(
            f"implausible lane counts n_scopes={n_scopes} total={total}")
    calls, pos = _get_int_block(body, pos, n_scopes, "calls")
    samples, pos = _get_int_block(body, pos, total, "samples")
    fdt = np.float64 if kind == KIND_AGG else np.float32
    nbytes = total * np.dtype(fdt).itemsize
    raw, pos = _get_raw(body, pos, nbytes, "values")
    frame.calls = calls
    frame.values = np.frombuffer(raw, fdt).copy()
    frame.samples = samples
    if kind == KIND_AGG:
        res = []
        for _ in range(total):
            seen, pos = _get_uvarint(body, pos)
            k, pos = _get_uvarint(body, pos)
            if k > len(body):
                raise CorruptFrameError(f"implausible reservoir size {k}")
            raw, pos = _get_raw(body, pos, 4 * k, "reservoir samples")
            res.append((seen, np.frombuffer(raw, np.float32).copy()))
        frame.reservoirs = res
    _expect_end(body, pos)
    return frame


def _expect_end(body: bytes, pos: int) -> None:
    if pos != len(body):
        raise CorruptFrameError(
            f"{len(body) - pos} trailing bytes after payload")


# ---------------------------------------------------------------------------
# Stream framing
# ---------------------------------------------------------------------------

_LEN = struct.Struct("<I")
MAX_FRAME_BYTES = 1 << 26       # 64 MiB — a corrupt length must not OOM us


def pack_frame(frame_bytes: bytes) -> bytes:
    """Length-prefix one encoded frame for a byte stream."""
    if len(frame_bytes) > MAX_FRAME_BYTES:
        raise ValueError(f"frame too large ({len(frame_bytes)} bytes)")
    return _LEN.pack(len(frame_bytes)) + frame_bytes


class FrameReader:
    """Incremental splitter/decoder for a length-prefixed frame stream.

    Feed whatever bytes the socket produced; ``frames()`` yields every
    complete decoded frame and leaves partial ones buffered.  Decode
    errors propagate to the caller — on a byte stream there is no reliable
    resync past a corrupt frame, so the connection should be dropped (and
    accounted) by whoever owns it.
    """

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def frames(self):
        while True:
            if len(self._buf) < _LEN.size:
                return
            (n,) = _LEN.unpack(bytes(self._buf[:_LEN.size]))
            if n > MAX_FRAME_BYTES:
                raise CorruptFrameError(f"frame length {n} exceeds cap")
            if len(self._buf) < _LEN.size + n:
                return
            body = bytes(self._buf[_LEN.size:_LEN.size + n])
            del self._buf[:_LEN.size + n]
            yield decode_frame(body)
