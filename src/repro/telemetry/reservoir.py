"""Reservoir sampling for fleet percentile estimation.

The head reports per-scope distributions (p50/p95/p99) over per-host,
per-interval event means — values that arrive as an unbounded stream at
every aggregator.  A fixed-capacity uniform reservoir (Vitter's Algorithm
R, the same scheme Scalene's sampler uses) keeps the estimate O(k) per
lane no matter how many hosts or how long the run.

Two operations matter for the tree:

* ``add(x)`` — leaf path: every drained frame contributes its lanes'
  interval means.
* ``merge(items, seen)`` — fan-in path: a child aggregator ships its own
  reservoir (plus how many values it represents) upward; the parent folds
  it in weighted by ``seen`` so each original observation keeps a
  near-uniform inclusion probability across the whole subtree.

Deterministic under a seeded ``numpy.random.Generator`` — tests pin seeds
and compare percentiles against a merged-stream oracle.
"""
from __future__ import annotations

import numpy as np


class Reservoir:
    """Fixed-capacity uniform sample of a value stream (Algorithm R)."""

    __slots__ = ("k", "seen", "_items", "_rng")

    def __init__(self, k: int, rng: np.random.Generator | None = None):
        if k < 1:
            raise ValueError(f"reservoir capacity must be >= 1, got {k}")
        self.k = int(k)
        self.seen = 0
        self._items: list[float] = []
        self._rng = rng if rng is not None else np.random.default_rng(0)

    def add(self, x: float) -> None:
        self.seen += 1
        if len(self._items) < self.k:
            self._items.append(float(x))
            return
        j = int(self._rng.integers(0, self.seen))
        if j < self.k:
            self._items[j] = float(x)

    def merge(self, items, seen: int) -> None:
        """Fold a child reservoir (``items`` drawn uniformly from ``seen``
        observations) into this one.

        When everything still fits in ``k`` the merge is exact
        (concatenation).  Otherwise the combined pool is subsampled with
        per-item weights ``seen/len(items)`` — each item stands in for
        that many original observations — which keeps inclusion
        probabilities uniform across subtrees of very different sizes.
        """
        items = [float(x) for x in np.asarray(items).reshape(-1)]
        seen = int(seen)
        if seen < len(items):
            raise ValueError(
                f"reservoir merge: seen={seen} < {len(items)} items")
        if not items:
            self.seen += seen
            return
        if self.seen == len(self._items) and \
                len(self._items) + len(items) <= self.k:
            # both sides exhaustive and the union fits: exact
            self._items.extend(items)
            self.seen += seen
            return
        pool = self._items + items
        w = np.concatenate([
            np.full(len(self._items),
                    (self.seen / len(self._items)) if self._items else 0.0),
            np.full(len(items), seen / len(items)),
        ])
        n_keep = min(self.k, len(pool))
        idx = self._rng.choice(
            len(pool), size=n_keep, replace=False, p=w / w.sum())
        self._items = [pool[i] for i in idx]
        self.seen += seen

    @property
    def items(self) -> np.ndarray:
        return np.asarray(self._items, np.float32)

    def percentile(self, q) -> float | np.ndarray:
        """Percentile estimate over the sample (NaN when empty)."""
        if not self._items:
            q_arr = np.asarray(q, np.float64)
            return (float("nan") if q_arr.ndim == 0
                    else np.full(q_arr.shape, np.nan))
        return np.percentile(np.asarray(self._items, np.float64), q)

    def __len__(self) -> int:
        return len(self._items)

    def __repr__(self) -> str:
        return f"Reservoir(k={self.k}, n={len(self._items)}, seen={self.seen})"
