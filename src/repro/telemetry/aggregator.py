"""Aggregator node — socket fan-in, per-lane merge, tree composition.

One ``Aggregator`` accepts length-prefixed wire frames from any number of
downstream senders (leaf ``FleetAgent``s and/or child aggregators) on a
TCP listener and maintains, per flat ``SlotLayout`` lane:

* exact int64/f64 running sums of every accepted ``KIND_DELTA`` (calls,
  values, samples) — the fleet-counter exactness path;
* a fixed-capacity ``Reservoir`` of per-frame interval means
  (``values[lane] / samples[lane]``) — the fleet-percentile path.

Tree composition follows PerSyst's shape: a child aggregator periodically
pushes its own merged state upward as a ``KIND_AGG`` frame.  Those frames
carry CUMULATIVE state, so the parent keeps only the LATEST frame per
child and folds it in at ``merged()`` query time — re-sending never double
counts, and a child that dies simply stops refreshing (its last state
remains visible, its host count stops growing).

Loss accounting is two-sided: senders count what their bounded buffers
dropped; this node counts seq gaps per sender (``lost_frames``) plus
frames it rejected (fingerprint mismatch / corruption / version skew).
A plan-fingerprint mismatch is a hard reject — merging counters whose
lanes mean different things is worse than dropping them.

Downlink: ``broadcast_hint`` writes a ``KIND_HINT`` frame back down every
live downstream connection (agents apply it via
``AdaptiveController.apply_fleet_hint``); hints arriving from a parent are
re-broadcast downward, so a head-level decision reaches every leaf.

The per-host step-rate baselines reuse ``core.adaptive._Baseline`` — the
same EWMA+MAD machinery the per-process controller uses for step-time
outliers — which is what the head's straggler flags read.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time

import numpy as np

from repro.core.adaptive import _Baseline

from . import wire
from .agent import _FrameLink
from .reservoir import Reservoir


@dataclasses.dataclass
class HostRecord:
    """Per-sender bookkeeping (leaf host or child aggregator)."""

    host_id: str
    kind: int = wire.KIND_DELTA
    frames: int = 0
    last_seq: int = -1
    lost_frames: int = 0            # seq gaps: sender encoded, we never saw
    last_step: int = -1
    first_seen: float = 0.0
    last_seen: float = 0.0
    shutdown: bool = False
    rate: float = float("nan")      # steps/sec over the last closed window
    baseline: _Baseline = dataclasses.field(default_factory=_Baseline)
    rate_window: float = 0.02       # min seconds of wall clock per sample
    _pending_steps: int = 0
    _anchor: float = 0.0

    def observe(self, frame: wire.Frame, now: float,
                rate_alpha: float) -> None:
        if self.frames == 0:
            self.first_seen = now
            self._anchor = now
        gap = frame.seq - self.last_seq - 1
        if gap > 0:
            self.lost_frames += gap
        self.last_seq = max(self.last_seq, frame.seq)
        self.frames += 1
        if frame.step_hi > self.last_step and self.last_seen > 0.0:
            # windowed rate: accumulate step spans until at least
            # ``rate_window`` of wall clock separates us from the anchor,
            # then emit ONE sample.  Arrival times are scheduler/TCP noise
            # frame-to-frame (a close-time flush delivers many frames
            # microseconds apart); per-frame instantaneous rates explode
            # unboundedly upward and poison the EWMA, while a windowed
            # sample collapses any burst into its honest average.
            self._pending_steps += frame.step_hi - self.last_step
            dt = now - self._anchor
            if dt >= self.rate_window:
                self.rate = self._pending_steps / dt
                self.baseline.update(self.rate, rate_alpha)
                self._pending_steps = 0
                self._anchor = now
        self.last_step = max(self.last_step, frame.step_hi)
        self.last_seen = now
        self.shutdown = self.shutdown or frame.shutdown

    def smoothed_rate(self) -> float:
        return self.baseline.mean if self.baseline.n else self.rate


@dataclasses.dataclass
class MergedView:
    """A point-in-time combined view over this node and its children."""

    calls: np.ndarray               # [n_scopes] i64 fleet sums
    values: np.ndarray              # [total] f64 fleet sums
    samples: np.ndarray             # [total] i64 fleet sums
    reservoirs: list                # [total] Reservoir (fresh merged copies)
    n_hosts: int
    frames_in: int
    dropped: int                    # lost (seq gaps) + rejected, whole subtree
    hosts: dict                     # host_id -> HostRecord (direct senders)
    fingerprint: str
    step_hi: int


class Aggregator:
    """Fan-in node of the fleet telemetry tree.

    address       (host, port) to listen on; port 0 picks a free one —
                  read the bound port back from ``self.address``
    node_id       this node's host_id in frames it pushes upward
    parent        optional (host, port) of a parent aggregator; call
                  ``push()`` (or set ``push_interval``) to send cumulative
                  KIND_AGG frames upward
    fingerprint   optional pinned plan fingerprint; otherwise learned from
                  the first counter frame and enforced afterwards
    reservoir_k   per-lane reservoir capacity
    seed          reservoir RNG seed (deterministic percentiles in tests)
    """

    def __init__(self, address=("127.0.0.1", 0), *, node_id: str = "agg",
                 parent=None, push_interval: float | None = None,
                 fingerprint: str = "", reservoir_k: int = 256,
                 seed: int = 0, rate_alpha: float = 0.2):
        self.node_id = str(node_id)
        self._requested_address = (str(address[0]), int(address[1]))
        self.reservoir_k = int(reservoir_k)
        self.rate_alpha = float(rate_alpha)
        self._rng = np.random.default_rng(seed)
        self._seed = int(seed)

        self._lock = threading.RLock()
        self._fingerprint = fingerprint or ""
        self._calls: np.ndarray | None = None       # i64 [n_scopes]
        self._values: np.ndarray | None = None      # f64 [total]
        self._samples: np.ndarray | None = None     # i64 [total]
        self._reservoirs: list[Reservoir] = []
        self._hosts: dict[str, HostRecord] = {}
        self._children: dict[str, wire.Frame] = {}  # latest AGG per child
        self._step_hi = -1
        self.frames_in = 0
        self.rejected_fingerprint = 0
        self.rejected_corrupt = 0
        self.rejected_version = 0
        self.hints_sent = 0

        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._conns: list[socket.socket] = []
        self._stop = threading.Event()
        self._closed = False

        self._parent_link: _FrameLink | None = None
        if parent is not None:
            self._parent_link = _FrameLink(
                parent, on_frame=self._on_parent_frame,
                name=f"agg-up-{node_id}")
        self._push_interval = push_interval
        self._push_seq = 0
        self._push_thread: threading.Thread | None = None

    # -- lifecycle ---------------------------------------------------------
    def serve(self) -> "Aggregator":
        """Bind the listener and start accepting downstream connections."""
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind(self._bind_address)
        sock.listen(64)
        self._listener = sock
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"agg-accept-{self.node_id}",
            daemon=True)
        self._accept_thread.start()
        if self._push_interval is not None and self._parent_link is not None:
            self._push_thread = threading.Thread(
                target=self._push_loop, name=f"agg-push-{self.node_id}",
                daemon=True)
            self._push_thread.start()
        return self

    @property
    def _bind_address(self):
        return self._requested_address

    @property
    def address(self) -> tuple[str, int]:
        """The bound (host, port) — valid after ``serve()``."""
        if self._listener is None:
            raise RuntimeError("Aggregator.serve() has not been called")
        return self._listener.getsockname()[:2]

    def close(self, flush_timeout: float = 5.0) -> None:
        """Stop accepting, push a final (shutdown) frame upward, close."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._stop.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        if self._parent_link is not None:
            try:
                self._send_up(shutdown=True)
            except Exception:
                pass
            self._parent_link.close(flush_timeout)
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        t = self._accept_thread
        if t is not None and t.is_alive():
            t.join(timeout=1.0)

    def __enter__(self) -> "Aggregator":
        return self.serve() if self._listener is None else self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- socket plumbing ---------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return
            with self._lock:
                self._conns.append(conn)
            threading.Thread(
                target=self._conn_loop, args=(conn,),
                name=f"agg-conn-{self.node_id}", daemon=True).start()

    def _conn_loop(self, conn: socket.socket) -> None:
        reader = wire.FrameReader()
        try:
            while not self._stop.is_set():
                data = conn.recv(65536)
                if not data:
                    return
                reader.feed(data)
                try:
                    for frame in reader.frames():
                        self.ingest(frame)
                except wire.VersionSkewError:
                    with self._lock:
                        self.rejected_version += 1
                    return          # no resync on a corrupt byte stream
                except wire.WireError:
                    with self._lock:
                        self.rejected_corrupt += 1
                    return
        except OSError:
            return
        finally:
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- merge core --------------------------------------------------------
    def ingest(self, frame: wire.Frame) -> bool:
        """Fold one decoded frame in; False if it was rejected.

        Public so in-process tests can drive the merge without sockets.
        """
        now = time.monotonic()
        with self._lock:
            if frame.kind == wire.KIND_HINT:
                # hints travel downward only; one arriving here is a peer
                # misconfiguration, not data — drop it.
                return False
            if not self._accept_fingerprint(frame):
                self.rejected_fingerprint += 1
                return False
            rec = self._hosts.get(frame.host_id)
            if rec is None:
                rec = self._hosts[frame.host_id] = HostRecord(
                    host_id=frame.host_id, kind=frame.kind)
            rec.observe(frame, now, self.rate_alpha)
            self._step_hi = max(self._step_hi, frame.step_hi)
            if frame.kind == wire.KIND_AGG:
                # cumulative child state: keep latest only (tree fan-in).
                # NOT counted into frames_in — that tallies leaf DELTA
                # frames only, so merged() can add the child's own
                # frames_in without double counting the carrier frames.
                self._children[frame.host_id] = frame
                return True
            self.frames_in += 1
            self._merge_delta(frame)
            return True

    def _accept_fingerprint(self, frame: wire.Frame) -> bool:
        fp = frame.fingerprint
        if fp == wire._ZERO_FP:
            return True             # control frame from a host that never
                                    # drained (pure-shutdown agent)
        if not self._fingerprint:
            self._fingerprint = fp
            return True
        return fp == self._fingerprint

    def _merge_delta(self, frame: wire.Frame) -> None:
        calls = frame.calls.astype(np.int64)
        values = frame.values.astype(np.float64)
        samples = frame.samples.astype(np.int64)
        if self._calls is None:
            self._calls = np.zeros(calls.shape, np.int64)
            self._values = np.zeros(values.shape, np.float64)
            self._samples = np.zeros(samples.shape, np.int64)
            self._reservoirs = [
                Reservoir(self.reservoir_k,
                          np.random.default_rng(self._seed + i))
                for i in range(values.shape[0])
            ]
        if calls.shape != self._calls.shape or \
                values.shape != self._values.shape:
            # same fingerprint implies same layout; treat as corruption
            self.rejected_corrupt += 1
            return
        self._calls += calls
        self._values += values
        self._samples += samples
        for lane in np.nonzero(samples > 0)[0].tolist():
            self._reservoirs[lane].add(values[lane] / samples[lane])

    # -- views -------------------------------------------------------------
    def merged(self) -> MergedView:
        """Combine direct state with the latest cumulative child frames."""
        with self._lock:
            if self._calls is not None:
                calls = self._calls.copy()
                values = self._values.copy()
                samples = self._samples.copy()
                res = [self._clone_reservoir(r, i)
                       for i, r in enumerate(self._reservoirs)]
            else:
                calls = values = samples = None
                res = []
            children = list(self._children.values())
            n_hosts = sum(1 for r in self._hosts.values()
                          if r.kind == wire.KIND_DELTA)
            frames_in = self.frames_in
            dropped = self._dropped_locked()
            hosts = dict(self._hosts)
            fp = self._fingerprint
            step_hi = self._step_hi

        for child in children:
            if calls is None:
                calls = np.zeros(child.calls.shape, np.int64)
                values = np.zeros(child.values.shape, np.float64)
                samples = np.zeros(child.samples.shape, np.int64)
                res = [Reservoir(self.reservoir_k,
                                 np.random.default_rng(self._seed + i))
                       for i in range(child.values.shape[0])]
            if child.calls.shape != calls.shape:
                continue            # rejected at ingest already
            calls = calls + child.calls.astype(np.int64)
            values = values + child.values.astype(np.float64)
            samples = samples + child.samples.astype(np.int64)
            n_hosts += child.n_hosts
            frames_in += child.frames_in
            dropped += child.dropped
            for lane, (seen, items) in enumerate(child.reservoirs or []):
                if lane < len(res):
                    res[lane].merge(items, seen)

        if calls is None:
            calls = np.zeros((0,), np.int64)
            values = np.zeros((0,), np.float64)
            samples = np.zeros((0,), np.int64)
        return MergedView(
            calls=calls, values=values, samples=samples, reservoirs=res,
            n_hosts=n_hosts, frames_in=frames_in, dropped=dropped,
            hosts=hosts, fingerprint=fp, step_hi=step_hi,
        )

    def _clone_reservoir(self, r: Reservoir, lane: int) -> Reservoir:
        out = Reservoir(self.reservoir_k,
                        np.random.default_rng(self._seed + 7919 + lane))
        out.merge(r.items, r.seen)
        return out

    def _dropped_locked(self) -> int:
        lost = sum(r.lost_frames for r in self._hosts.values())
        return (lost + self.rejected_fingerprint + self.rejected_corrupt
                + self.rejected_version)

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped_locked()

    def stats(self) -> dict:
        with self._lock:
            return {
                "node_id": self.node_id,
                "frames_in": self.frames_in,
                "n_hosts": sum(1 for r in self._hosts.values()
                               if r.kind == wire.KIND_DELTA),
                "n_children": len(self._children),
                "lost_frames": sum(r.lost_frames
                                   for r in self._hosts.values()),
                "rejected_fingerprint": self.rejected_fingerprint,
                "rejected_corrupt": self.rejected_corrupt,
                "rejected_version": self.rejected_version,
                "hints_sent": self.hints_sent,
                "step_hi": self._step_hi,
                "fingerprint": self._fingerprint,
            }

    # -- upward push (tree fan-in) -----------------------------------------
    def push(self) -> bool:
        """Send one cumulative KIND_AGG frame to the parent now."""
        if self._parent_link is None:
            raise RuntimeError("Aggregator has no parent configured")
        return self._send_up(shutdown=False)

    def _send_up(self, shutdown: bool) -> bool:
        view = self.merged()
        with self._lock:
            seq = self._push_seq
            self._push_seq += 1
        frame = wire.encode_agg(
            view.calls, view.values, view.samples,
            [(r.seen, r.items) for r in view.reservoirs],
            host_id=self.node_id, seq=seq,
            fingerprint=view.fingerprint or "",
            step_lo=-1, step_hi=view.step_hi, n_hosts=view.n_hosts,
            frames_in=view.frames_in, dropped=view.dropped,
            shutdown=shutdown,
        )
        return self._parent_link.send(frame, force=shutdown)

    def _push_loop(self) -> None:
        while not self._stop.wait(self._push_interval):
            try:
                self._send_up(shutdown=False)
            except Exception:
                pass

    def _on_parent_frame(self, frame: wire.Frame) -> None:
        # a hint from above fans out below — the head reaches every leaf
        if frame.kind == wire.KIND_HINT:
            self._broadcast_raw(wire.encode_hint(
                frame.scope, frame.reason, host_id=self.node_id,
                seq=frame.seq, tripwire=frame.tripwire))

    # -- downlink hints ----------------------------------------------------
    def broadcast_hint(self, scope: str, reason: str, *,
                       tripwire: bool = False) -> int:
        """Write one KIND_HINT down every live downstream connection.

        Returns how many connections it reached.
        """
        frame = wire.encode_hint(
            scope or "", reason, host_id=self.node_id, seq=self.hints_sent,
            tripwire=tripwire)
        return self._broadcast_raw(frame)

    def _broadcast_raw(self, frame: bytes) -> int:
        data = wire.pack_frame(frame)
        with self._lock:
            conns = list(self._conns)
        sent = 0
        for conn in conns:
            try:
                conn.sendall(data)
                sent += 1
            except OSError:
                pass
        with self._lock:
            self.hints_sent += 1
        return sent

    def __repr__(self) -> str:
        st = self.stats()
        return (f"Aggregator({self.node_id!r}, hosts={st['n_hosts']}, "
                f"children={st['n_children']}, frames={st['frames_in']})")
