"""Per-host fleet agent — a telemetry Sink that ships drained deltas as
wire frames to an aggregator.

``FleetAgent`` attaches to the existing ``TelemetryPlane`` fan-out exactly
like the adaptive controller does (CallbackSink-style): its ``emit`` runs
on the drain thread, so it must NEVER dispatch device computation — the
ROADMAP drain invariant.  Everything it touches is already host numpy
(``snap.delta``), and this module deliberately never imports jax; tests
attest it with a raising ``sys.modules`` guard.

Emit does exactly two things on the drain thread: normalize the delta to
dense SlotLayout lanes and enqueue a LAZY frame into a BOUNDED buffer —
the wire encode itself runs on the sender thread right before the send
(codec cost measured in ``run_fleet_agg_sweep``), so shipping costs the
monitored app's drain path almost nothing.  The sender thread owns the
socket: connect with exponential backoff, length-prefixed sends, reconnect
on failure.  An unreachable aggregator therefore costs the monitored
application only the enqueue: frames pile up in the bounded buffer and the
OLDEST are dropped with accounting (never even encoded)
(``dropped_frames``) — the per-frame ``seq`` means the aggregator sees the
gap and accounts the loss on its side too.

The socket is bidirectional: when a ``controller`` (core/adaptive.py) is
attached, a reader thread applies head-level KIND_HINT frames via
``AdaptiveController.apply_fleet_hint`` — fleet-shared escalation
decisions closing the per-process gap noted in ROADMAP item 3.

``close()`` encodes one final frame with ``shutdown=True``, flushes the
buffer, and stops the threads; it is idempotent (a double close never
double-sends — ``ScalpelRuntime``'s graceful-shutdown path and an explicit
``close()`` can both run).
"""
from __future__ import annotations

import socket
import threading
import time
from collections import deque

import numpy as np

from . import wire


class _FrameLink:
    """A resilient length-prefixed frame pipe to one peer.

    Owns the socket and the sender thread; ``send(frame_bytes)`` enqueues
    into a bounded buffer (drop-oldest with accounting).  Shared by
    ``FleetAgent`` (leaf → aggregator) and ``Aggregator`` (child → parent
    tree fan-in).
    """

    def __init__(self, address, *, max_buffer: int = 256,
                 connect_timeout: float = 2.0, backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0, on_frame=None,
                 name: str = "fleet-link"):
        self.address = (str(address[0]), int(address[1]))
        self.max_buffer = max(1, int(max_buffer))
        self.connect_timeout = float(connect_timeout)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self.on_frame = on_frame      # downlink callback (decoded Frame)
        self.name = name

        self.frames_sent = 0
        self.bytes_sent = 0
        self.dropped_frames = 0
        self.connects = 0
        self.reconnects = 0
        self.send_errors = 0

        self._q: deque[bytes] = deque()
        self._cond = threading.Condition()
        self._inflight = False
        self._stop = threading.Event()
        self._sock: socket.socket | None = None
        self._sock_lock = threading.Lock()
        self._sender: threading.Thread | None = None
        self._reader: threading.Thread | None = None
        self._closed = False

    # -- producer side -----------------------------------------------------
    def send(self, frame_bytes, force: bool = False) -> bool:
        """Enqueue one frame; False if it displaced/was dropped.

        Accepts encoded bytes OR a zero-arg callable returning them — a
        lazy frame is materialized on the sender thread right before the
        send, keeping the encode off the producer's (drain) thread.  A
        frame dropped from the buffer is never encoded at all.

        ``force`` grows past the bound by one — the shutdown frame must
        never be the one dropped.
        """
        with self._cond:
            if self._closed and not force:
                self.dropped_frames += 1
                return False
            ok = True
            if len(self._q) >= self.max_buffer and not force:
                self._q.popleft()      # drop-oldest: fresher data wins
                self.dropped_frames += 1
                ok = False
            self._q.append(frame_bytes)
            self._cond.notify_all()
        self._ensure_sender()
        return ok

    def flush(self, timeout: float = 5.0) -> bool:
        """Block until the buffer drains (or timeout); True when empty."""
        self._ensure_sender()
        end = time.monotonic() + timeout
        with self._cond:
            while self._q or self._inflight:
                left = end - time.monotonic()
                if left <= 0:
                    return False
                self._cond.wait(min(left, 0.05))
        return True

    def close(self, flush_timeout: float = 5.0) -> None:
        with self._cond:
            if self._closed:
                return
            self._closed = True
        self.flush(flush_timeout)
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        t = self._sender
        if t is not None and t.is_alive():
            t.join(timeout=flush_timeout + 1.0)
        self._drop_conn()
        with self._cond:
            # anything still queued never made it out
            self.dropped_frames += len(self._q)
            self._q.clear()

    @property
    def connected(self) -> bool:
        return self._sock is not None

    # -- sender machinery --------------------------------------------------
    def _ensure_sender(self) -> None:
        # started once and runs until close — its loop swallows every
        # error, so no per-send is_alive() probe on the producer path
        if self._stop.is_set() or self._sender is not None:
            return
        self._sender = threading.Thread(
            target=self._sender_loop, name=self.name, daemon=True)
        self._sender.start()

    def _sender_loop(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._stop.is_set():
                    self._cond.wait(0.1)
                if not self._q:
                    return               # stopped and drained
                frame = self._q.popleft()
                self._inflight = True
            if callable(frame):
                try:
                    frame = frame()
                except Exception:   # pragma: no cover - encoder bug
                    frame = None
            ok = frame is not None and self._send_one(frame)
            with self._cond:
                self._inflight = False
                if not ok:
                    self.dropped_frames += 1
                self._cond.notify_all()

    def _send_one(self, frame: bytes) -> bool:
        backoff = self.backoff_s
        while True:
            sock = self._connect()
            if sock is None:
                if self._stop.is_set():
                    return False
                self._stop.wait(backoff)
                backoff = min(backoff * 2, self.backoff_max_s)
                continue
            try:
                sock.sendall(wire.pack_frame(frame))
                self.frames_sent += 1
                self.bytes_sent += len(frame) + 4
                return True
            except OSError:
                self.send_errors += 1
                self._drop_conn()
                if self._stop.is_set():
                    return False

    def _connect(self) -> socket.socket | None:
        with self._sock_lock:
            if self._sock is not None:
                return self._sock
        try:
            sock = socket.create_connection(
                self.address, timeout=self.connect_timeout)
        except OSError:
            return None
        sock.settimeout(None)
        with self._sock_lock:
            self._sock = sock
            self.connects += 1
            if self.connects > 1:
                self.reconnects += 1
        if self.on_frame is not None:
            self._reader = threading.Thread(
                target=self._reader_loop, args=(sock,),
                name=f"{self.name}-rx", daemon=True)
            self._reader.start()
        return sock

    def _drop_conn(self) -> None:
        with self._sock_lock:
            sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def _reader_loop(self, sock: socket.socket) -> None:
        """Downlink: decode frames the peer pushes back (hints)."""
        reader = wire.FrameReader()
        try:
            while not self._stop.is_set():
                data = sock.recv(65536)
                if not data:
                    return
                reader.feed(data)
                for frame in reader.frames():
                    try:
                        self.on_frame(frame)
                    except Exception:  # pragma: no cover - callback bug
                        pass
        except (OSError, wire.WireError):
            return


def _dense_gather(padded: np.ndarray, widths, dtype) -> np.ndarray:
    """[n_scopes, max_slots] → flat [total] in SlotLayout lane order."""
    if not widths or not sum(widths):
        return np.zeros((0,), dtype)
    return np.concatenate(
        [np.asarray(padded[i, :w], dtype) for i, w in enumerate(widths)])


class FleetAgent:
    """Telemetry sink shipping each drained delta as one wire frame.

    Deliberately NOT a ``core.telemetry.Sink`` subclass: the plane
    duck-types its sinks (emit/flush/close/stats), and importing
    ``repro.core`` would pull jax into this module — which must stay
    jax-free end to end (drain-thread rule, attested by test).

    host_id      this process's stable fleet identity
    address      (host, port) of the aggregator it reports to
    fingerprint  the producing spec's plan fingerprint; when omitted it is
                 taken from the first drained snapshot (the shutdown frame
                 of an agent that never emitted uses the zero fingerprint)
    controller   optional AdaptiveController — head-level escalation hints
                 arriving on the downlink are applied to it

    Accounting (surfaced uniformly via ``stats()`` →
    ``TelemetryPlane.stats()['sinks']``): frames/bytes sent, encode
    seconds, dropped frames, reconnects.  ``shipped_*`` accumulate exactly
    what was ENCODED (int64/f64) — the per-host oracle the fleet tests sum
    against.
    """

    def __init__(self, host_id: str, address, *, fingerprint: str = "",
                 controller=None, max_buffer: int = 256,
                 connect_timeout: float = 2.0, backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0):
        self.host_id = str(host_id)
        self.controller = controller
        self._fingerprint = fingerprint or ""
        self._link = _FrameLink(
            address, max_buffer=max_buffer, connect_timeout=connect_timeout,
            backoff_s=backoff_s, backoff_max_s=backoff_max_s,
            on_frame=self._on_downlink, name=f"fleet-agent-{host_id}",
        )
        self._seq = 0
        self._last_step = -1
        self._lanes = (0, 0)
        self._encoder: wire.DeltaStreamEncoder | None = None
        self._lock = threading.Lock()
        self._enc_lock = threading.Lock()
        self._closed = False
        self.frames_encoded = 0
        self.encode_seconds = 0.0
        self.emit_seconds = 0.0
        self.hints_applied = 0
        self.shipped_calls: np.ndarray | None = None    # int64 sums
        self.shipped_values: np.ndarray | None = None   # f64 sums
        self.shipped_samples: np.ndarray | None = None  # int64 sums

    # -- drain-thread side (never dispatches device work) ------------------
    def emit(self, snap) -> None:
        with self._lock:
            if self._closed:
                return
            t0 = time.perf_counter()
            delta = snap.delta
            calls = np.asarray(delta.calls).reshape(-1)
            values = np.asarray(delta.values)
            samples = np.asarray(delta.samples)
            if values.ndim == 2:
                # legacy padded CounterState delta: gather each scope's live
                # footprint into SlotLayout order (host numpy — the wire
                # contract is the dense lane order either way)
                widths = [len(c.slots) for c in snap.spec.contexts]
                values = _dense_gather(values, widths, np.float32)
                samples = _dense_gather(samples, widths, np.int64)
            else:
                values = values.reshape(-1)
                samples = samples.reshape(-1)
            if not self._fingerprint:
                self._fingerprint = snap.spec.fingerprint
            if self._encoder is None:
                self._encoder = wire.DeltaStreamEncoder(
                    self.host_id, self._fingerprint)
            enc = self._encoder
            step = int(snap.step)

            # the drain thread only normalizes and ENQUEUES — the wire
            # encode AND the shipped_* oracle sums run lazily on the
            # link's sender thread, off the monitored app's drain path.
            # Safe to defer: the plane hands sinks a fresh host copy per
            # drain, nothing mutates these arrays afterwards.  A frame
            # dropped from the bounded buffer is never encoded, so the
            # shipped_* oracle stays exactly "sums over frames encoded".
            def _encode(calls=calls, values=values, samples=samples,
                        seq=self._seq, lo=self._last_step, hi=step,
                        enc=enc) -> bytes:
                t = time.thread_time()
                buf = enc.encode(calls, values, samples, seq=seq,
                                 step_lo=lo, step_hi=hi)
                with self._enc_lock:
                    if self.shipped_calls is None:
                        self.shipped_calls = np.zeros(calls.shape, np.int64)
                        self.shipped_values = np.zeros(values.shape,
                                                       np.float64)
                        self.shipped_samples = np.zeros(samples.shape,
                                                        np.int64)
                    # += upcasts in place (i64 += i32, f64 += f32)
                    self.shipped_calls += calls
                    self.shipped_values += values
                    self.shipped_samples += samples
                    # codec CPU on the sender thread (thread_time: GIL
                    # and scheduler waits excluded)
                    self.encode_seconds += time.thread_time() - t
                return buf

            self._seq += 1
            self._last_step = step
            self.frames_encoded += 1
            self._lanes = (calls.shape[0], values.shape[0])
            # emit_seconds = everything this sink costs the drain thread
            # (normalize + enqueue)
            self.emit_seconds += time.perf_counter() - t0
        self._link.send(_encode)

    def _on_downlink(self, frame: wire.Frame) -> None:
        if frame.kind != wire.KIND_HINT or self.controller is None:
            return
        self.controller.apply_fleet_hint(
            frame.scope or None, reason=frame.reason,
            tripwire=frame.tripwire)
        self.hints_applied += 1

    # -- lifecycle ---------------------------------------------------------
    def flush(self, timeout: float = 0.25) -> None:
        """Best-effort bounded wait for the send buffer to drain.

        The plane calls this on every synchronous ``flush()``; with an
        unreachable aggregator it must not stall the caller — the bounded
        buffer + ``close()``'s longer flush own delivery, this just keeps a
        healthy link caught up.
        """
        self._link.flush(timeout)

    def close(self, flush_timeout: float = 5.0) -> None:
        """Send the final ``shutdown=True`` frame, flush, stop.  Idempotent:
        the second close (runtime shutdown + atexit, say) sends nothing."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            n, t = self._lanes
            if self._encoder is None:
                self._encoder = wire.DeltaStreamEncoder(
                    self.host_id, self._fingerprint)
            frame = self._encoder.encode(
                np.zeros((n,), np.int64), np.zeros((t,), np.float32),
                np.zeros((t,), np.int64), seq=self._seq,
                step_lo=self._last_step, step_hi=self._last_step,
                shutdown=True,
            )
            self._seq += 1
        self._link.send(frame, force=True)
        self._link.close(flush_timeout)

    # -- accounting --------------------------------------------------------
    @property
    def dropped_frames(self) -> int:
        return self._link.dropped_frames

    @property
    def reconnects(self) -> int:
        return self._link.reconnects

    @property
    def connected(self) -> bool:
        return self._link.connected

    def stats(self) -> dict:
        """Uniform sink-health dict (TelemetryPlane.stats() collects it)."""
        return {
            "host_id": self.host_id,
            "frames_encoded": self.frames_encoded,
            "frames_sent": self._link.frames_sent,
            "bytes_sent": self._link.bytes_sent,
            "dropped_frames": self._link.dropped_frames,
            "reconnects": self._link.reconnects,
            "send_errors": self._link.send_errors,
            "encode_seconds": round(self.encode_seconds, 6),
            "emit_seconds": round(self.emit_seconds, 6),
            "hints_applied": self.hints_applied,
            "connected": self._link.connected,
        }

    def __repr__(self) -> str:
        return (f"FleetAgent({self.host_id!r} -> "
                f"{self._link.address[0]}:{self._link.address[1]}, "
                f"sent={self._link.frames_sent}, "
                f"dropped={self._link.dropped_frames})")
