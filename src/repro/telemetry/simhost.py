"""Simulated fleet host — the shared per-process workload behind the
multi-process fleet tests and ``examples/fleet_monitor.py``.

One ``run_host`` call is one "host" of the fleet: it builds the shared
``MonitorSpec`` (every host MUST compile the same plans — the wire
fingerprint check enforces it), runs a small monitored workload with a
``FleetAgent`` attached to the runtime's telemetry plane, and returns (or,
via the CLI, prints as a ``FLEET-ORACLE:`` JSON line) everything the
aggregation tier is later checked against:

* ``shipped_calls`` / ``shipped_values`` / ``shipped_samples`` — the
  agent's own int64/f64 sums over every frame it ENCODED.  The fleet-sum
  acceptance test asserts the aggregator's totals equal the sum of these
  per-host oracles (int lanes exactly, float lanes to f64 tolerance).
* ``lane_means`` — per flat lane, the per-drain interval means recorded by
  a shadow ``CallbackSink`` on the same plane.  The percentile acceptance
  test merges all hosts' streams and compares ``np.percentile`` of the
  merged stream against the head's reservoir estimate.

Fault hooks (``repro.testing.faults``): ``straggle_s`` adds a host-side
``StragglerDelay`` sleep every step (the straggler the head must flag);
``nan_step`` splices a NaN into one scope's probed tensor (the tripwire
the head turns into a fleet-wide hint).

    python -m repro.telemetry.simhost --host-id h0 --port 9999 --steps 30
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

EVENTS = ("ACT_RMS", "ACT_ZERO_FRAC", "NAN_COUNT", "INF_COUNT")
SCOPES = ("layer/attn", "layer/mlp", "head")
FAULT_SCOPE = "layer/attn"


def build_spec():
    """The fleet-shared MonitorSpec (same plans ⇒ same wire fingerprint)."""
    from repro.core.context import EventSpec, MonitorSpec, ScopeContext

    return MonitorSpec.of([
        ScopeContext.exhaustive(s, [EventSpec(e, "x") for e in EVENTS])
        for s in SCOPES
    ])


def run_host(host_id: str, port: int, *, steps: int = 30, cadence: int = 2,
             seed: int = 0, pace_s: float = 0.005, straggle_s: float = 0.0,
             nan_step: int | None = None, adaptive: bool = False,
             linger_s: float = 0.0, max_buffer: int = 256,
             aggregator_host: str = "127.0.0.1") -> dict:
    """Run one simulated host against the aggregator at ``port``.

    ``pace_s`` sleeps every step on EVERY host so healthy step rates are
    stable (socket-arrival-time rates on an unpaced microbenchmark are
    pure scheduler noise); ``straggle_s`` adds the straggler's extra
    per-step sleep on top.  ``linger_s`` keeps the process alive after its
    steps polling for a fleet hint (the downlink demo) — it exits early
    the moment one is applied.
    """
    import jax
    import jax.numpy as jnp

    from repro import core as scalpel
    from repro.core.adaptive import AdaptiveConfig
    from repro.testing.faults import FaultInjector, StragglerDelay, TensorFault

    spec = build_spec()
    runtime = scalpel.ScalpelRuntime(spec, hook_every=cadence)
    ctl = None
    if adaptive:
        ctl = runtime.attach_controller(AdaptiveConfig(
            overhead_budget=1.0, quiet_steps=10_000))
    agent = runtime.attach_fleet_agent(
        host_id, (aggregator_host, int(port)), max_buffer=max_buffer)

    # shadow oracle: per-lane interval means of every drained delta, off
    # the same plane fan-out the agent rides
    lane_means: list[list[float]] = []

    def record(snap):
        d = snap.delta
        vals = np.asarray(d.values, np.float64).reshape(-1)
        smps = np.asarray(d.samples, np.int64).reshape(-1)
        if not lane_means:
            lane_means.extend([] for _ in range(vals.shape[0]))
        for i in range(vals.shape[0]):
            if smps[i] > 0:
                lane_means[i].append(float(vals[i] / smps[i]))

    runtime.telemetry.add_sink(scalpel.CallbackSink(record))

    faults = []
    if straggle_s > 0:
        faults.append(StragglerDelay(step=0, seconds=straggle_s, every=1))
    if nan_step is not None:
        faults.append(TensorFault(FAULT_SCOPE, "x", step=int(nan_step),
                                  kind="nan"))
    injector = FaultInjector(faults)

    mon = scalpel.Monitor(spec, telemetry=runtime.telemetry, counter_axes=())
    key = jax.random.PRNGKey(seed)
    w1, w2, w3 = (jax.random.normal(k, (32, 32)) * 0.2
                  for k in jax.random.split(key, 3))

    def workload(x, step):
        h = jnp.tanh(x @ w1)
        with scalpel.function("layer/attn"):
            scalpel.probe(x=injector.corrupt(FAULT_SCOPE, "x", step, h))
        m = jnp.tanh(h @ w2)
        with scalpel.function("layer/mlp"):
            scalpel.probe(x=m)
        y = m @ w3
        with scalpel.function("head"):
            scalpel.probe(x=y)
        return x, step + 1

    step_fn = mon.jit(workload)
    mstate = mon.init()
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 32))
    step = jnp.zeros((), jnp.int32)
    for i in range(int(steps)):
        mstate = mon.sync(mstate, runtime=runtime)
        (x, step), mstate = step_fn(mstate, x, step)
        runtime.on_step(mstate.counters, ring=mstate.ring)
        runtime.flush()
        injector.host_step(i)
        if pace_s > 0:
            time.sleep(pace_s)

    if linger_s > 0 and ctl is not None:
        deadline = time.monotonic() + linger_s
        while time.monotonic() < deadline:
            if ctl.stats["fleet_hints"] >= 1:
                break
            time.sleep(0.02)

    # close FIRST: the plane's sink-close path flushes the agent and sends
    # its final shutdown frame — stats snapped after include it, so the
    # oracle's frames_sent matches the aggregator's per-host frame count
    runtime.close()
    agent_stats = agent.stats()
    oracle = {
        "host_id": host_id,
        "steps": int(steps),
        "fingerprint": spec.fingerprint,
        "shipped_calls": [int(v) for v in
                          (agent.shipped_calls if agent.shipped_calls
                           is not None else [])],
        "shipped_values": [float(v) for v in
                           (agent.shipped_values if agent.shipped_values
                            is not None else [])],
        "shipped_samples": [int(v) for v in
                            (agent.shipped_samples if agent.shipped_samples
                             is not None else [])],
        "lane_means": lane_means,
        "agent": agent_stats,
        "straggler_fired": list(injector.fired),
        "fleet_hints": (ctl.stats["fleet_hints"] if ctl is not None
                        else None),
        "levels": (ctl.levels if ctl is not None else None),
    }
    return oracle


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--host-id", required=True)
    p.add_argument("--port", type=int, required=True)
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--cadence", type=int, default=2)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--pace-s", type=float, default=0.005)
    p.add_argument("--straggle-s", type=float, default=0.0)
    p.add_argument("--nan-step", type=int, default=None)
    p.add_argument("--adaptive", action="store_true")
    p.add_argument("--linger-s", type=float, default=0.0)
    p.add_argument("--max-buffer", type=int, default=256)
    args = p.parse_args(argv)
    oracle = run_host(
        args.host_id, args.port, steps=args.steps, cadence=args.cadence,
        seed=args.seed, pace_s=args.pace_s, straggle_s=args.straggle_s,
        nan_step=args.nan_step, adaptive=args.adaptive,
        linger_s=args.linger_s, max_buffer=args.max_buffer,
    )
    print("FLEET-ORACLE: " + json.dumps(oracle, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
