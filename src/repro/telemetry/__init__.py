"""Fleet telemetry tier — cross-host aggregation for ScALPEL (ROADMAP 2).

Three layers, PerSyst/LIKWID-shaped (PAPERS.md):

    FleetAgent (wire.py, agent.py)     per-host sink on the TelemetryPlane
        │  KIND_DELTA frames            drain: encodes each drained
        ▼                               CompactDelta, bounded buffer,
    Aggregator (aggregator.py)          reconnect backoff, drop accounting
        │  KIND_AGG frames (tree)      merges per (scope, event) lane:
        ▼                               exact i64/f64 sums + reservoirs
    FleetHead (head.py)                fleet p50/p95/p99, exact sums,
        │  KIND_HINT frames             straggler flags, JSONL report
        ▼  (downlink, rebroadcast)
    AdaptiveController.apply_fleet_hint

``wire``/``agent``/``reservoir`` import eagerly and are deliberately
jax-free (the agent runs on the telemetry drain thread, which must never
dispatch device work — attested by test).  ``Aggregator``/``FleetHead``
resolve lazily because they pull ``core.adaptive`` (which imports jax)
for the shared EWMA+MAD baseline machinery.
"""
from . import wire  # noqa: F401
from .agent import FleetAgent  # noqa: F401
from .reservoir import Reservoir  # noqa: F401

_LAZY = {
    "Aggregator": ("repro.telemetry.aggregator", "Aggregator"),
    "HostRecord": ("repro.telemetry.aggregator", "HostRecord"),
    "MergedView": ("repro.telemetry.aggregator", "MergedView"),
    "FleetHead": ("repro.telemetry.head", "FleetHead"),
}

__all__ = ["wire", "FleetAgent", "Reservoir",
           "Aggregator", "HostRecord", "MergedView", "FleetHead"]


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(mod_name), attr)
