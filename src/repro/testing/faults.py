"""Deterministic, spec-addressable fault injection (adaptive-loop harness).

The adaptive controller's contract — "an injected mid-run NaN is localized
to the correct scope within K drained snapshots" — is only testable with
faults that are (a) deterministic, (b) addressed the same way the monitor
addresses things (scope + probe-tensor name + step), and (c) in-graph
where the fault must flow through the probe path.  Three injector kinds:

* ``TensorFault`` — splice NaN/Inf into a named scope's probed tensor at
  step S (optionally repeating).  ``FaultInjector.corrupt`` is called
  inside the traced step with a *traced* step scalar, so arming/firing is
  a ``jnp.where`` on data — the graph never re-traces across the fault
  boundary, exactly like the monitoring plane it exercises.  The corrupted
  value is whatever the caller probes; inject on a probe-only copy to keep
  the fault from propagating into the model state.
* ``StragglerDelay`` — a host-side sleep at step S
  (``FaultInjector.host_step`` from the step loop), tripping step-time
  outlier detectors without touching the graph.
* ``FailingSink`` / ``SlowSink`` — telemetry-plane IO faults: emits that
  raise (drain-hardening tests) or stall (overhead-budget tests).
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core import telemetry as telemetry_lib

_BAD = {"nan": float("nan"), "inf": float("inf")}


@dataclasses.dataclass(frozen=True)
class TensorFault:
    """NaN/Inf splice into scope ``scope``'s probed tensor ``tensor``.

    Fires when the (traced) step equals ``step`` — or, with ``every > 0``,
    on every ``every``-th step from ``step`` onward (a never-quiet scope).
    ``count`` leading elements of the flattened tensor are corrupted.
    """

    scope: str
    tensor: str
    step: int
    kind: str = "nan"       # "nan" | "inf"
    count: int = 1
    every: int = 0

    def __post_init__(self):
        if self.kind not in _BAD:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class StragglerDelay:
    """Host-side sleep of ``seconds`` before step ``step`` retires —
    a simulated straggler for step-time outlier detectors."""

    step: int
    seconds: float
    every: int = 0


class FaultInjector:
    """The armed fault set. One instance serves a whole run; every fault
    is addressed by (scope, tensor, step), so the same injector can be
    handed to the traced step (``corrupt``) and the host loop
    (``host_step``)."""

    def __init__(self, faults=()):
        self.tensor_faults: list[TensorFault] = [
            f for f in faults if isinstance(f, TensorFault)
        ]
        self.host_faults: list[StragglerDelay] = [
            f for f in faults if isinstance(f, StragglerDelay)
        ]
        self.fired: list[str] = []      # host-side audit (host faults only)

    # -- in-graph ---------------------------------------------------------
    def corrupt(self, scope: str, tensor: str, step, x):
        """Apply every armed TensorFault matching (scope, tensor) to ``x``.

        ``step`` is a traced i32 scalar (e.g. the carried step stamp): the
        returned graph is fault-free data-flow except a ``jnp.where`` per
        armed fault — adding or moving a fault never re-traces anything,
        it is a different *constant*, same program shape.
        """
        step = jnp.asarray(step, jnp.int32)
        for f in self.tensor_faults:
            if f.scope != scope or f.tensor != tensor:
                continue
            if f.every > 0:
                hit = (step >= f.step) & ((step - f.step) % f.every == 0)
            else:
                hit = step == f.step
            flat = x.reshape(-1)
            n = max(1, min(int(f.count), flat.shape[0]))
            bad = jnp.asarray(_BAD[f.kind], x.dtype)
            flat = flat.at[:n].set(jnp.where(hit, bad, flat[:n]))
            x = flat.reshape(x.shape)
        return x

    # -- host-side --------------------------------------------------------
    def host_step(self, step: int) -> None:
        """Run host faults due at ``step`` (call once per step, host loop)."""
        for f in self.host_faults:
            if f.every > 0:
                due = step >= f.step and (step - f.step) % f.every == 0
            else:
                due = step == f.step
            if due:
                time.sleep(f.seconds)
                self.fired.append(f"straggler {f.seconds}s @ step {step}")


class FailingSink(telemetry_lib.Sink):
    """A sink whose ``emit`` raises deterministically.

    ``fail_first=N``: the first N emit attempts raise, then it heals.
    ``fail_always=True``: every emit raises (exercises the drop path).
    Successful emits record ``snap.step`` in ``emitted``.
    """

    def __init__(self, fail_first: int = 0, fail_always: bool = False,
                 exc: type = OSError):
        self.fail_first = int(fail_first)
        self.fail_always = bool(fail_always)
        self.exc = exc
        self.attempts = 0
        self.emitted: list[int] = []

    def emit(self, snap) -> None:
        self.attempts += 1
        if self.fail_always or self.attempts <= self.fail_first:
            raise self.exc("injected sink failure")
        self.emitted.append(snap.step)


class SlowSink(telemetry_lib.Sink):
    """A sink that sleeps in ``emit`` — inflates measured drain overhead
    so budget-loop tests can force the proportional controller to act."""

    def __init__(self, seconds: float = 0.02):
        self.seconds = float(seconds)
        self.emitted: list[int] = []

    def emit(self, snap) -> None:
        time.sleep(self.seconds)
        self.emitted.append(snap.step)
