"""Test-support harnesses (fault injection, failing sinks).

Importable from production examples/benchmarks too — everything here is
deterministic and dependency-free; nothing imports pytest.
"""
from .faults import (  # noqa: F401
    FailingSink,
    FaultInjector,
    SlowSink,
    StragglerDelay,
    TensorFault,
)
