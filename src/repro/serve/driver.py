"""Device-resident decode driver for the continuous-batching engine.

One jitted **megastep** advances every lane by K tokens without touching
the host: a ``lax.scan`` (the ``Monitor.scan`` megastep shape — K inner
steps per commit/dispatch boundary) whose body

1. appends the lanes' CURRENT tokens to the token egress ring (tokens are
   emitted the step they are consumed, matching the serial engine's
   emit-then-decode order),
2. vmaps the single-request ``decode_step`` + on-device sampling over the
   lane axis, with a per-lane collector opened INSIDE the vmap so counters
   attribute to lanes (each lane's per-token RNG key splits exactly like
   the serial engine's, so seeded streams are bitwise identical to a
   serial run — vmap semantics guarantee stacked-equals-individual),
3. folds the lane-stacked delta through ``Monitor.commit_lanes`` (inactive
   lanes masked out; aggregate counters ring-append at the telemetry
   cadence), and
4. advances the per-lane active/remaining masks — finished lanes retire
   in-graph, no re-trace.

K (``steps_per_commit``) bounds both the per-token dispatch amortization
and the reaction latency: admission and adaptive/knob swaps land at
megastep boundaries, up to K tokens late (the ROADMAP megastep-latency
note) — so serving defaults to a modest K rather than the throughput
optimum.

The jit boundary is leaf-wise (``Monitor.jit_wrapped`` style): the
read-only ``params``/``tparams``/model params are inputs only, and the
slab + per-lane decode state are donated — the steady-state loop allocates
nothing for the cache.  The rings are NEVER donated: the host drains their
buffers while the next megastep runs.

Lane-mesh sharding (``mesh`` + ``lane_axis``): all three programs compile
through ``shard_map`` over a 1-D ``lanes`` mesh (``partition.lane_mesh``)
so the slab spans devices.  The invariants, per program:

* megastep — lane-dim state (slab / tok / keys / masks / per-lane counter
  rows / ``lane_sched`` / token-ring slots) stays PER-SHARD; only the
  lane-SUMMED aggregate psum-reduces over the lane axis
  (``Monitor.commit_lanes`` via ``counter_reduce_axes``), feeding the
  unchanged replicated ring/adaptive stack.  ``lane_sched`` must never see
  the psum (the ROADMAP mux invariant).
* admission — every shard runs the same program on its local block; the
  traced GLOBAL lane index maps to a local one, and only the owning shard
  takes the write (clamped-index + ``owned`` mask; see ``write_lane`` /
  ``Monitor.admit_lane``).
* prefill — replicated (every shard computes the batch-1 prompt; no
  transfers).  Its counter delta is replicated too and is deliberately
  NOT psum-reduced — ``admit_lane`` folds it into the replicated
  aggregate exactly once per shard's copy.

Prompt-length bucketing: ``_prefill_bucketed`` takes right-padded tokens
plus a traced ``length`` (mask-correct per family — see
``models/*.SUPPORTS_PREFILL_LENGTH``), so admission + prefill compile once
per BUCKET instead of once per distinct prompt length.
"""
from __future__ import annotations

import copy

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import telemetry as telemetry_lib
from repro.core.monitor import LaneMonitorState, Monitor
from repro.models.registry import Arch, write_lane


class DecodeDriver:
    """Compiles and owns the three jitted serve programs: the K-step
    megastep, the admission slab update, and the monitored prefill
    (exact-length + bucketed variants) — optionally shard_mapped over a
    ``lanes`` mesh axis."""

    def __init__(self, arch: Arch, mon: Monitor, *, cache_len: int,
                 temperature: float, steps_per_commit: int,
                 mesh=None, lane_axis: str = "lanes"):
        if steps_per_commit < 1:
            raise ValueError(
                f"steps_per_commit must be >= 1, got {steps_per_commit}")
        self.arch = arch
        self.cache_len = int(cache_len)
        self.temperature = float(temperature)
        self.steps_per_commit = int(steps_per_commit)
        self.mesh = mesh
        self.lane_axis = lane_axis
        if mesh is not None:
            # the driver's monitor copy psums counter aggregates over the
            # lane axis INSIDE shard_map (explicit axes, like shard_wrap —
            # no ambient sharding_ctx: the model's own logical-axis
            # constraints must not name manual axes)
            mon = copy.copy(mon)
            mon.counter_axes = tuple(mesh.axis_names)
        self.mon = mon

        sample = self.sample
        fingerprint = mon.spec.fingerprint
        k_steps = self.steps_per_commit
        sharded = mesh is not None
        LANE, REP = P(lane_axis), P()
        ring_spec = telemetry_lib.TokenRing(
            steps=REP, toks=P(None, lane_axis), live=P(None, lane_axis),
            head=REP,
        )

        def compile_program(core, in_specs, out_specs, donate=()):
            if not sharded:
                return jax.jit(core, donate_argnums=donate)
            return jax.jit(
                shard_map(core, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False),
                donate_argnums=donate,
            )

        def megastep_core(lane_calls, lane_values, lane_samples, lane_sched,
                          calls, values, samples, step, ring,
                          mparams, tparams, params,
                          slab, tok, keys, active, remaining, tok_ring):
            def lane_step(sched, cache, t, key):
                # collector opened INSIDE the vmap: trace-time call counts
                # are identical across lanes (same program), and the delta
                # comes back as an explicit lane-stacked output
                with mon.open(mparams, calls_base=sched) as col:
                    logits, cache2 = arch.decode_step(params, cache, t)
                delta = col.compact_delta()
                # serial contract, per lane: split, then sample with the sub
                key2, sub = jax.random.split(key)
                nxt = sample(logits, sub)
                return cache2, nxt, key2, delta

            def sbody(c, _):
                (slab, tok, keys, active, remaining,
                 lane_calls, lane_values, lane_samples, lane_sched,
                 calls, values, samples, step, ring, tok_ring) = c
                step2 = step + 1
                # egress first: the token each lane consumes THIS step (the
                # serial engine emits tok_i, then decodes it)
                tok_ring2 = telemetry_lib.token_ring_append(
                    tok_ring, tok[:, 0, 0], active, step2)
                slab2, nxt, keys2, delta = jax.vmap(
                    lane_step, in_axes=(0, 0, 0, 0)
                )(lane_sched, slab, tok, keys)
                ls = LaneMonitorState(
                    lane_calls=lane_calls, lane_values=lane_values,
                    lane_samples=lane_samples, lane_sched=lane_sched,
                    calls=calls, values=values, samples=samples,
                    step=step, ring=ring, params=mparams, tparams=tparams,
                    fingerprint=fingerprint,
                )
                ls2 = mon.commit_lanes(ls, delta, active)
                remaining2 = remaining - active
                active2 = ((active > 0) & (remaining2 > 0)).astype(jnp.int32)
                return (slab2, nxt, keys2, active2, remaining2,
                        ls2.lane_calls, ls2.lane_values, ls2.lane_samples,
                        ls2.lane_sched, ls2.calls, ls2.values, ls2.samples,
                        ls2.step, ls2.ring, tok_ring2), None

            init = (slab, tok, keys, active, remaining,
                    lane_calls, lane_values, lane_samples, lane_sched,
                    calls, values, samples, step, ring, tok_ring)
            out, _ = jax.lax.scan(sbody, init, None, length=k_steps)
            return out

        # arg positions: 0-8 monitor leaves, 9-11 read-only knobs/params,
        # 12-16 slab + per-lane decode state (donated — the engine holds
        # only the outputs), 17 token ring (never donated; host-drained)
        self._megastep = compile_program(
            megastep_core,
            in_specs=(LANE, LANE, LANE, LANE, REP, REP, REP, REP, REP,
                      REP, REP, REP, LANE, LANE, LANE, LANE, LANE,
                      ring_spec),
            out_specs=(LANE, LANE, LANE, LANE, LANE,
                       LANE, LANE, LANE, LANE, REP, REP, REP, REP, REP,
                       ring_spec),
            donate=(12, 13, 14, 15, 16),
        )

        def admit_core(slab, tok, keys, active, remaining,
                       lane_calls, lane_values, lane_samples, lane_sched,
                       calls, values, samples, step, ring, tparams,
                       lane, cache, tok0, key0, max_new, pdelta):
            if sharded:
                # global traced lane -> this shard's local block index;
                # non-owners run the same program as a masked no-op
                n_local = active.shape[0]
                li = lane - jax.lax.axis_index(lane_axis) * n_local
                own = (li >= 0) & (li < n_local)
                li = jnp.clip(li, 0, n_local - 1)
            else:
                li, own = lane, None

            def setm(arr, val):
                val = jnp.asarray(val).astype(arr.dtype)
                if own is None:
                    return arr.at[li].set(val)
                return arr.at[li].set(jnp.where(own, val, arr[li]))

            slab2 = write_lane(slab, li, cache, owned=own)
            ls = LaneMonitorState(
                lane_calls=lane_calls, lane_values=lane_values,
                lane_samples=lane_samples, lane_sched=lane_sched,
                calls=calls, values=values, samples=samples,
                step=step, ring=ring, params=None, tparams=tparams,
                fingerprint=fingerprint,
            )
            ls2 = mon.admit_lane(ls, li, pdelta, owned=own)
            return ((slab2,
                     setm(tok, tok0),
                     setm(keys, key0),
                     setm(active, 1),
                     setm(remaining, jnp.asarray(max_new, jnp.int32))),
                    (ls2.lane_calls, ls2.lane_values, ls2.lane_samples,
                     ls2.lane_sched, ls2.calls, ls2.values, ls2.samples,
                     ls2.step, ls2.ring))

        # lane/max_new are traced scalars: ONE compiled admission program
        # serves every lane and request length — no re-trace on admission
        self._admit = compile_program(
            admit_core,
            in_specs=(LANE, LANE, LANE, LANE, LANE,
                      LANE, LANE, LANE, LANE, REP, REP, REP, REP, REP, REP,
                      REP, REP, REP, REP, REP, REP),
            out_specs=((LANE, LANE, LANE, LANE, LANE),
                       (LANE, LANE, LANE, LANE, REP, REP, REP, REP, REP)),
            donate=(0, 1, 2, 3, 4),
        )

        def prefill_core(params, mparams, tokens, key):
            base = jnp.zeros((mon.spec.n_scopes,), jnp.int32)
            with mon.open(mparams, calls_base=base) as col:
                cache, logits = arch.prefill(
                    params, {"tokens": tokens}, cache_len=cache_len)
            # serial first-token contract: sample with the UNSPLIT request
            # key on the prefill logits (the lane splits per token after)
            tok0 = sample(logits, key)
            return cache, tok0, col.compact_delta()

        def prefill_bucketed_core(params, mparams, tokens, length, key):
            base = jnp.zeros((mon.spec.n_scopes,), jnp.int32)
            with mon.open(mparams, calls_base=base) as col:
                cache, logits = arch.prefill(
                    params, {"tokens": tokens}, cache_len=cache_len,
                    length=length)
            tok0 = sample(logits, key)
            return cache, tok0, col.compact_delta()

        # exact-length fallback: retraces per distinct prompt length (the
        # engine prefers the bucketed program whenever the family supports
        # a traced ``length``)
        self._prefill = compile_program(
            prefill_core,
            in_specs=(REP, REP, REP, REP), out_specs=(REP, REP, REP))
        # bucketed: one trace per PAD BUCKET — ``length`` is a traced
        # operand, so every prompt length in a bucket shares the program
        self._prefill_bucketed = compile_program(
            prefill_bucketed_core,
            in_specs=(REP, REP, REP, REP, REP), out_specs=(REP, REP, REP))

    # -- host-visible entry points ----------------------------------------
    def sample(self, logits, rng):
        """Identical semantics to the serial ``Engine._sample``."""
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        logits = logits / self.temperature
        return jax.random.categorical(rng, logits)[:, None].astype(jnp.int32)

    def prefill(self, params, mparams, tokens, key):
        """Monitored batch-1 prefill + first-token sample:
        ``(cache, tok0, compact delta)`` — one dispatch, all async."""
        return self._prefill(params, mparams,
                             jnp.asarray(tokens, jnp.int32), key)

    def prefill_bucketed(self, params, mparams, tokens, length, key):
        """Bucketed prefill: ``tokens`` right-padded to its bucket width,
        ``length`` the real prompt length (traced — no re-trace per
        length).  Same returns as ``prefill``."""
        return self._prefill_bucketed(
            params, mparams, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(int(length), jnp.int32), key)

    def trace_counts(self) -> dict[str, int]:
        """Compile-cache sizes of the three programs (jit cache stats) —
        the bucketing win's attestation: ``prefill_traces`` is bounded by
        the bucket count, not by distinct prompt lengths."""

        def n(f):
            try:
                return int(f._cache_size())
            except Exception:  # cache-stat API unavailable
                return -1

        return {
            "prefill_traces": n(self._prefill) + n(self._prefill_bucketed),
            "admission_traces": n(self._admit),
            "megastep_traces": n(self._megastep),
        }

    def admit(self, lstate: LaneMonitorState, slab, tok, keys, active,
              remaining, lane, cache, tok0, key0, max_new, pdelta):
        """Write an admitted request into lane ``lane`` and seed its
        counter rows with the prefill delta — one async dispatch (donates
        the previous slab/lane-state buffers; rings are never donated)."""
        (state, leaves) = self._admit(
            slab, tok, keys, active, remaining,
            lstate.lane_calls, lstate.lane_values, lstate.lane_samples,
            lstate.lane_sched, lstate.calls, lstate.values, lstate.samples,
            lstate.step, lstate.ring, lstate.tparams,
            jnp.asarray(int(lane), jnp.int32), cache, tok0, key0,
            jnp.asarray(int(max_new), jnp.int32), pdelta,
        )
        (lane_calls, lane_values, lane_samples, lane_sched,
         calls, values, samples, step, ring) = leaves
        ls2 = LaneMonitorState(
            lane_calls=lane_calls, lane_values=lane_values,
            lane_samples=lane_samples, lane_sched=lane_sched,
            calls=calls, values=values, samples=samples, step=step,
            ring=ring, params=lstate.params, tparams=lstate.tparams,
            fingerprint=lstate.fingerprint,
        )
        return state, ls2

    def megastep(self, lstate: LaneMonitorState, params,
                 slab, tok, keys, active, remaining, tok_ring):
        """Dispatch one K-token megastep; returns the new lane decode state
        tuple, the new LaneMonitorState, and the new token ring."""
        (slab2, tok2, keys2, active2, remaining2,
         lane_calls, lane_values, lane_samples, lane_sched,
         calls, values, samples, step, ring, tok_ring2) = self._megastep(
            lstate.lane_calls, lstate.lane_values, lstate.lane_samples,
            lstate.lane_sched, lstate.calls, lstate.values, lstate.samples,
            lstate.step, lstate.ring, lstate.params, lstate.tparams, params,
            slab, tok, keys, active, remaining, tok_ring,
        )
        ls2 = LaneMonitorState(
            lane_calls=lane_calls, lane_values=lane_values,
            lane_samples=lane_samples, lane_sched=lane_sched,
            calls=calls, values=values, samples=samples, step=step,
            ring=ring, params=lstate.params, tparams=lstate.tparams,
            fingerprint=lstate.fingerprint,
        )
        return (slab2, tok2, keys2, active2, remaining2), ls2, tok_ring2
