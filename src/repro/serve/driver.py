"""Device-resident decode driver for the continuous-batching engine.

One jitted **megastep** advances every lane by K tokens without touching
the host: a ``lax.scan`` (the ``Monitor.scan`` megastep shape — K inner
steps per commit/dispatch boundary) whose body

1. appends the lanes' CURRENT tokens to the token egress ring (tokens are
   emitted the step they are consumed, matching the serial engine's
   emit-then-decode order),
2. vmaps the single-request ``decode_step`` + on-device sampling over the
   lane axis, with a per-lane collector opened INSIDE the vmap so counters
   attribute to lanes (each lane's per-token RNG key splits exactly like
   the serial engine's, so seeded streams are bitwise identical to a
   serial run — vmap semantics guarantee stacked-equals-individual),
3. folds the lane-stacked delta through ``Monitor.commit_lanes`` (inactive
   lanes masked out; aggregate counters ring-append at the telemetry
   cadence), and
4. advances the per-lane active/remaining masks — finished lanes retire
   in-graph, no re-trace.

K (``steps_per_commit``) bounds both the per-token dispatch amortization
and the reaction latency: admission and adaptive/knob swaps land at
megastep boundaries, up to K tokens late (the ROADMAP megastep-latency
note) — so serving defaults to a modest K rather than the throughput
optimum.

The jit boundary is leaf-wise (``Monitor.jit_wrapped`` style): the
read-only ``params``/``tparams``/model params are inputs only, and the
slab + per-lane decode state are donated — the steady-state loop allocates
nothing for the cache.  The rings are NEVER donated: the host drains their
buffers while the next megastep runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import telemetry as telemetry_lib
from repro.core.monitor import LaneMonitorState, Monitor
from repro.models.registry import Arch, write_lane


class DecodeDriver:
    """Compiles and owns the three jitted serve programs: the K-step
    megastep, the admission slab update, and the monitored prefill."""

    def __init__(self, arch: Arch, mon: Monitor, *, cache_len: int,
                 temperature: float, steps_per_commit: int):
        if steps_per_commit < 1:
            raise ValueError(
                f"steps_per_commit must be >= 1, got {steps_per_commit}")
        self.arch = arch
        self.mon = mon
        self.cache_len = int(cache_len)
        self.temperature = float(temperature)
        self.steps_per_commit = int(steps_per_commit)

        sample = self.sample
        fingerprint = mon.spec.fingerprint
        k_steps = self.steps_per_commit

        def megastep_core(lane_calls, lane_values, lane_samples, lane_sched,
                          calls, values, samples, step, ring,
                          mparams, tparams, params,
                          slab, tok, keys, active, remaining, tok_ring):
            def lane_step(sched, cache, t, key):
                # collector opened INSIDE the vmap: trace-time call counts
                # are identical across lanes (same program), and the delta
                # comes back as an explicit lane-stacked output
                with mon.open(mparams, calls_base=sched) as col:
                    logits, cache2 = arch.decode_step(params, cache, t)
                delta = col.compact_delta()
                # serial contract, per lane: split, then sample with the sub
                key2, sub = jax.random.split(key)
                nxt = sample(logits, sub)
                return cache2, nxt, key2, delta

            def sbody(c, _):
                (slab, tok, keys, active, remaining,
                 lane_calls, lane_values, lane_samples, lane_sched,
                 calls, values, samples, step, ring, tok_ring) = c
                step2 = step + 1
                # egress first: the token each lane consumes THIS step (the
                # serial engine emits tok_i, then decodes it)
                tok_ring2 = telemetry_lib.token_ring_append(
                    tok_ring, tok[:, 0, 0], active, step2)
                slab2, nxt, keys2, delta = jax.vmap(
                    lane_step, in_axes=(0, 0, 0, 0)
                )(lane_sched, slab, tok, keys)
                ls = LaneMonitorState(
                    lane_calls=lane_calls, lane_values=lane_values,
                    lane_samples=lane_samples, lane_sched=lane_sched,
                    calls=calls, values=values, samples=samples,
                    step=step, ring=ring, params=mparams, tparams=tparams,
                    fingerprint=fingerprint,
                )
                ls2 = mon.commit_lanes(ls, delta, active)
                remaining2 = remaining - active
                active2 = ((active > 0) & (remaining2 > 0)).astype(jnp.int32)
                return (slab2, nxt, keys2, active2, remaining2,
                        ls2.lane_calls, ls2.lane_values, ls2.lane_samples,
                        ls2.lane_sched, ls2.calls, ls2.values, ls2.samples,
                        ls2.step, ls2.ring, tok_ring2), None

            init = (slab, tok, keys, active, remaining,
                    lane_calls, lane_values, lane_samples, lane_sched,
                    calls, values, samples, step, ring, tok_ring)
            out, _ = jax.lax.scan(sbody, init, None, length=k_steps)
            return out

        # arg positions: 0-8 monitor leaves, 9-11 read-only knobs/params,
        # 12-16 slab + per-lane decode state (donated — the engine holds
        # only the outputs), 17 token ring (never donated; host-drained)
        self._megastep = jax.jit(megastep_core,
                                 donate_argnums=(12, 13, 14, 15, 16))

        def admit_core(slab, tok, keys, active, remaining,
                       lane_calls, lane_values, lane_samples, lane_sched,
                       calls, values, samples, step, ring, tparams,
                       lane, cache, tok0, key0, max_new, pdelta):
            slab2 = write_lane(slab, lane, cache)
            ls = LaneMonitorState(
                lane_calls=lane_calls, lane_values=lane_values,
                lane_samples=lane_samples, lane_sched=lane_sched,
                calls=calls, values=values, samples=samples,
                step=step, ring=ring, params=None, tparams=tparams,
                fingerprint=fingerprint,
            )
            ls2 = mon.admit_lane(ls, lane, pdelta)
            return ((slab2,
                     tok.at[lane].set(tok0),
                     keys.at[lane].set(key0),
                     active.at[lane].set(1),
                     remaining.at[lane].set(
                         jnp.asarray(max_new, jnp.int32))),
                    (ls2.lane_calls, ls2.lane_values, ls2.lane_samples,
                     ls2.lane_sched, ls2.calls, ls2.values, ls2.samples,
                     ls2.step, ls2.ring))

        # lane/max_new are traced scalars: ONE compiled admission program
        # serves every lane and request length — no re-trace on admission
        self._admit = jax.jit(admit_core, donate_argnums=(0, 1, 2, 3, 4))

        def prefill_core(params, mparams, tokens, key):
            base = jnp.zeros((mon.spec.n_scopes,), jnp.int32)
            with mon.open(mparams, calls_base=base) as col:
                cache, logits = arch.prefill(
                    params, {"tokens": tokens}, cache_len=cache_len)
            # serial first-token contract: sample with the UNSPLIT request
            # key on the prefill logits (the lane splits per token after)
            tok0 = sample(logits, key)
            return cache, tok0, col.compact_delta()

        # retraces per distinct prompt length (the usual bucketing caveat)
        self._prefill = jax.jit(prefill_core)

    # -- host-visible entry points ----------------------------------------
    def sample(self, logits, rng):
        """Identical semantics to the serial ``Engine._sample``."""
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        logits = logits / self.temperature
        return jax.random.categorical(rng, logits)[:, None].astype(jnp.int32)

    def prefill(self, params, mparams, tokens, key):
        """Monitored batch-1 prefill + first-token sample:
        ``(cache, tok0, compact delta)`` — one dispatch, all async."""
        return self._prefill(params, mparams,
                             jnp.asarray(tokens, jnp.int32), key)

    def admit(self, lstate: LaneMonitorState, slab, tok, keys, active,
              remaining, lane, cache, tok0, key0, max_new, pdelta):
        """Write an admitted request into lane ``lane`` and seed its
        counter rows with the prefill delta — one async dispatch (donates
        the previous slab/lane-state buffers; rings are never donated)."""
        (state, leaves) = self._admit(
            slab, tok, keys, active, remaining,
            lstate.lane_calls, lstate.lane_values, lstate.lane_samples,
            lstate.lane_sched, lstate.calls, lstate.values, lstate.samples,
            lstate.step, lstate.ring, lstate.tparams,
            jnp.asarray(int(lane), jnp.int32), cache, tok0, key0,
            jnp.asarray(int(max_new), jnp.int32), pdelta,
        )
        (lane_calls, lane_values, lane_samples, lane_sched,
         calls, values, samples, step, ring) = leaves
        ls2 = LaneMonitorState(
            lane_calls=lane_calls, lane_values=lane_values,
            lane_samples=lane_samples, lane_sched=lane_sched,
            calls=calls, values=values, samples=samples, step=step,
            ring=ring, params=lstate.params, tparams=lstate.tparams,
            fingerprint=lstate.fingerprint,
        )
        return state, ls2

    def megastep(self, lstate: LaneMonitorState, params,
                 slab, tok, keys, active, remaining, tok_ring):
        """Dispatch one K-token megastep; returns the new lane decode state
        tuple, the new LaneMonitorState, and the new token ring."""
        (slab2, tok2, keys2, active2, remaining2,
         lane_calls, lane_values, lane_samples, lane_sched,
         calls, values, samples, step, ring, tok_ring2) = self._megastep(
            lstate.lane_calls, lstate.lane_values, lstate.lane_samples,
            lstate.lane_sched, lstate.calls, lstate.values, lstate.samples,
            lstate.step, lstate.ring, lstate.params, lstate.tparams, params,
            slab, tok, keys, active, remaining, tok_ring,
        )
        ls2 = LaneMonitorState(
            lane_calls=lane_calls, lane_values=lane_values,
            lane_samples=lane_samples, lane_sched=lane_sched,
            calls=calls, values=values, samples=samples, step=step,
            ring=ring, params=lstate.params, tparams=lstate.tparams,
            fingerprint=lstate.fingerprint,
        )
        return (slab2, tok2, keys2, active2, remaining2), ls2, tok_ring2
