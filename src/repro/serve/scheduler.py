"""Host-side continuous-batching scheduler: lane table, admission queue,
token attribution.

The device never sees requests — it sees LANES.  The scheduler owns the
mapping: which request occupies which lane, how many tokens it still owes,
and which drained token-ring slot belongs to whom.

Two deliberate design points keep the host out of the hot path:

* Completion is tracked ARITHMETICALLY.  Every lane decodes exactly once
  per megastep inner step and retires via the device-side active mask, so
  a dispatched K-step megastep advances an occupied lane by exactly
  ``min(K, remaining)`` tokens — admission/eviction decisions never read
  device state.

* Token attribution is DEFERRED.  Sampled tokens arrive a megastep late
  through the telemetry token ring; each lane keeps a FIFO of
  ``(request, expected)`` segments that drained slots consume in step
  order, so a lane's tokens attribute correctly even when retirement and
  re-admission happen before its last tokens are drained.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # [1, s] prompt
    max_new: int
    seed: int | None = None


@dataclasses.dataclass
class ServeResult:
    """One finished request: its sampled tokens plus the per-lane counter
    attribution harvested at retirement (prefill + decode, compact
    layout)."""

    tokens: np.ndarray                  # [n_new] i32, decode order
    counters: Any = None                # plan.CompactDelta (host numpy)
    lane: int = -1


class Scheduler:
    def __init__(self, n_lanes: int, buckets=None):
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        self.n_lanes = int(n_lanes)
        # prompt-length buckets (sorted pad widths, or None = exact-length):
        # admission pads each prompt to its routed width so prefill compiles
        # once per BUCKET, not once per distinct length
        self.buckets = (tuple(sorted({int(b) for b in buckets}))
                        if buckets else None)
        self.prompt_tokens = 0
        self.pad_tokens = 0
        self.buckets_used: set[int] = set()
        self.queue: deque[Request] = deque()
        self.lane_rid: list[int | None] = [None] * n_lanes
        self.lane_left: list[int] = [0] * n_lanes
        # per-lane FIFO of [rid, tokens_still_expected] segments, admission
        # order — drained token slots consume them in step order
        self._segments: list[deque[list[int]]] = \
            [deque() for _ in range(n_lanes)]
        self._out: dict[int, list[int]] = {}
        self._expected: dict[int, int] = {}
        self._counters: dict[int, Any] = {}
        self._lane_of: dict[int, int] = {}
        self._next_rid = 0
        self.admitted = 0
        self.completed = 0

    # -- submission --------------------------------------------------------
    def submit(self, tokens, max_new: int, seed: int | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._expected[rid] = int(max_new)
        self._out[rid] = []
        if max_new > 0:
            self.queue.append(Request(rid, np.asarray(tokens),
                                      int(max_new), seed))
        return rid

    # -- prompt-length bucketing -------------------------------------------
    def route(self, length: int) -> int:
        """Route a prompt length to its pad width: the smallest configured
        bucket that fits (prompts past the largest bucket — and every
        prompt when bucketing is off — go exact-length).  Records pad
        waste: the fraction of prefill FLOPs spent on pad is the price of
        the bounded trace count."""
        length = int(length)
        width = length
        if self.buckets:
            for b in self.buckets:
                if b >= length:
                    width = b
                    break
        self.prompt_tokens += length
        self.pad_tokens += width - length
        self.buckets_used.add(width)
        return width

    @property
    def pad_waste_frac(self) -> float:
        """Pad tokens as a fraction of all prefill tokens routed so far."""
        tot = self.prompt_tokens + self.pad_tokens
        return self.pad_tokens / tot if tot else 0.0

    # -- lane table --------------------------------------------------------
    def free_lanes(self) -> list[int]:
        return [i for i, r in enumerate(self.lane_rid) if r is None]

    @property
    def occupied(self) -> bool:
        return any(r is not None for r in self.lane_rid)

    def admit(self, lane: int, req: Request) -> None:
        assert self.lane_rid[lane] is None, f"lane {lane} occupied"
        self.lane_rid[lane] = req.rid
        self.lane_left[lane] = req.max_new
        self._segments[lane].append([req.rid, req.max_new])
        self._lane_of[req.rid] = lane
        self.admitted += 1

    def advance(self, k: int) -> list[tuple[int, int]]:
        """Account one dispatched K-step megastep.  Returns the
        ``(lane, rid)`` pairs whose requests finish WITHIN it: their lanes
        are free for the next admission phase (the device's active mask
        retired them in-graph; no re-trace, no readback)."""
        done = []
        for lane, rid in enumerate(self.lane_rid):
            if rid is None:
                continue
            self.lane_left[lane] -= min(int(k), self.lane_left[lane])
            if self.lane_left[lane] == 0:
                done.append((lane, rid))
                self.lane_rid[lane] = None
                self.completed += 1
        return done

    # -- token attribution (drained slots, a megastep behind) --------------
    def attribute(self, drained) -> int:
        """Feed drained token-ring slots ``(seq, step, toks, live)`` in
        append order; returns the number of tokens attributed."""
        n = 0
        for _seq, _step, toks, live in drained:
            for lane in np.nonzero(np.asarray(live) != 0)[0]:
                seg = self._segments[int(lane)]
                assert seg, f"live token on lane {lane} with no segment"
                rid, left = seg[0]
                self._out[rid].append(int(toks[int(lane)]))
                n += 1
                if left <= 1:
                    seg.popleft()
                else:
                    seg[0][1] = left - 1
        return n

    def set_counters(self, rid: int, counters) -> None:
        self._counters[rid] = counters

    # -- completion --------------------------------------------------------
    @property
    def all_attributed(self) -> bool:
        return all(len(self._out[r]) == e
                   for r, e in self._expected.items())

    def results(self) -> dict[int, ServeResult]:
        """Assemble final per-request results; every submitted request must
        be fully attributed (the engine drains the last ring first)."""
        out = {}
        for rid, expected in self._expected.items():
            toks = self._out[rid]
            assert len(toks) == expected, (
                f"request {rid}: {len(toks)}/{expected} tokens attributed"
            )
            out[rid] = ServeResult(
                tokens=np.asarray(toks, np.int32),
                counters=self._counters.get(rid),
                lane=self._lane_of.get(rid, -1),
            )
        return out
