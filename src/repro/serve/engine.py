"""Serving engines: jitted prefill + decode with ScALPEL counters.

Two engines share the monitoring machinery:

* ``Engine`` — the static-batch reference: a fixed batch of slots, one
  prefill per batch, token-synchronous decode steps driven by a host loop
  (one dispatch + host sample per token).  Kept as the semantics oracle:
  the continuous engine's greedy tokens and seeded RNG streams are
  bitwise-checked against it.

* ``ContinuousEngine`` — the production path (ROADMAP item 1): a packed
  request SLAB of ``n_lanes`` decode lanes, each an independent request at
  its own position over its own KV/recurrent cache, advanced K tokens per
  dispatch by a device-resident megastep (``serve/driver.py``) with
  on-device sampling.  New requests enter free lanes between megasteps
  (one compiled admission program — no re-trace); finished lanes retire
  in-graph via the active mask.  Sampled tokens leave through the
  telemetry plane's token ring, drained one megastep behind the dispatch,
  so the decode hot loop performs ZERO host syncs per token — the only
  blocking readback is the final drain at request completion.

Monitoring rides the functional ``Monitor`` API in both: the serial engine
threads one ``MonitorState``; the continuous engine threads a
``LaneMonitorState`` whose per-lane counter rows attribute NaN/entropy
anomalies to individual requests while the lane-summed aggregate feeds the
same ring → drain → adaptive-controller stack unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.models.registry import Arch

from .driver import DecodeDriver
from .scheduler import Scheduler, ServeResult  # noqa: F401  (re-export)


@dataclasses.dataclass
class ServeConfig:
    cache_len: int = 1024
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0
    # continuous-batching knobs (ignored by the serial Engine):
    # n_lanes — decode lanes in the packed slab (concurrent requests).
    # steps_per_commit — K tokens per megastep dispatch.  Bounds BOTH the
    #   dispatch amortization and the reaction latency: admission, adaptive
    #   decisions, and knob swaps land at megastep boundaries, up to K
    #   tokens late (the ROADMAP megastep note) — so serving defaults to a
    #   modest K instead of the pure-throughput optimum.
    # token_ring_depth — token egress ring slots; 0 => max(2*K, 8) (the
    #   pipelined drain consumes K slots per megastep).
    # lane_shards — shard the decode slab over this many devices along a
    #   1-D "lanes" mesh axis (dist.partition.lane_mesh).  Every lane-dim
    #   tensor (slab, tok/keys/masks, per-lane counter rows, token-ring
    #   slots) stays per-shard; only the lane-summed counter aggregate
    #   psum-reduces.  1 = single device (byte-identical programs to the
    #   unsharded engine).  Must divide n_lanes.
    # prefill_buckets — prompt-length pad policy: "pow2" pads each prompt
    #   to the next power-of-two bucket (>= prefill_bucket_min, <=
    #   cache_len) so admission+prefill compile once per BUCKET instead of
    #   once per distinct prompt length; None = exact-length (retrace per
    #   length).  Auto-disabled for families without a length-masked
    #   prefill (models.registry.Arch.supports_prefill_length).
    n_lanes: int = 4
    steps_per_commit: int = 8
    token_ring_depth: int = 0
    lane_shards: int = 1
    prefill_buckets: str | None = "pow2"
    prefill_bucket_min: int = 8

    def bucket_widths(self, supports_length: bool) -> tuple[int, ...] | None:
        """Resolve the configured pad-bucket widths (None = bucketing off)."""
        if self.prefill_buckets is None or not supports_length:
            return None
        if self.prefill_buckets != "pow2":
            raise ValueError(
                f"unknown prefill_buckets policy {self.prefill_buckets!r} "
                f"(expected 'pow2' or None)")
        widths, b = [], max(1, int(self.prefill_bucket_min))
        while b <= self.cache_len:
            widths.append(b)
            b *= 2
        return tuple(widths) or None


def _discover_spec(arch: Arch, cfg: ServeConfig):
    """Scope discovery from an abstract prefill + decode (shared by both
    engines so they compile identical probe plans)."""

    def probe_fn(p, toks):
        cache, logits = arch.prefill(p, {"tokens": toks},
                                     cache_len=cfg.cache_len)
        return arch.decode_step(p, cache, toks[:, :1])

    seen = scalpel.discover(
        probe_fn, arch.abstract_params(),
        jax.ShapeDtypeStruct((1, min(32, cfg.cache_len)), jnp.int32),
    )
    return scalpel.spec_from_discovery(seen)


class Engine:
    def __init__(self, arch: Arch, params, cfg: ServeConfig,
                 spec=None, runtime=None):
        self.arch = arch
        self.params = params
        self.cfg = cfg
        if spec is None:
            spec = _discover_spec(arch, cfg)
        self.spec = spec
        self.runtime = runtime or scalpel.ScalpelRuntime(spec)
        # ONE pytree replaces the old (counters, ring, decode_step) triple:
        # the monitor borrows the runtime's telemetry plane for its ring.
        self.mon = scalpel.Monitor(spec, telemetry=self.runtime.telemetry)
        self.mstate = self.mon.init()
        # per-token decode times, keyed by (batch, max_new): medians of one
        # regime never mix with another's (a [1,1]-shape decode is not
        # comparable to a [16,1] one)
        self.step_times: dict[tuple[int, int], list[float]] = {}
        # the RNG carries across generate() calls — reseeding per call would
        # make every generation sample identically (see generate()).
        self._rng = jax.random.PRNGKey(cfg.seed)

        def _prefill(params, batch):
            return self.arch.prefill(params, batch,
                                     cache_len=self.cfg.cache_len)

        def _decode(params, cache, tokens):
            return self.arch.decode_step(params, cache, tokens)

        # wrapped signatures: (mstate, *args) -> (out, mstate).  Monitor.jit
        # draws the jit boundary leaf-wise (runtime knobs never round-trip
        # the graph); the cache is donated, the MonitorState is NOT (its
        # ring buffers are read by the telemetry drain thread while later
        # decode steps run).
        self._jit_prefill = self.mon.jit(_prefill)
        self._jit_decode = self.mon.jit(_decode, donate_argnums=(1,))

    @property
    def counters(self):
        """The engine's cumulative counters (compact dense layout)."""
        return self.mstate.counters

    def reset_stats(self) -> None:
        """Drop accumulated decode timings (all shape buckets)."""
        self.step_times.clear()

    def _sample(self, logits, rng):
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        logits = logits / self.cfg.temperature
        return jax.random.categorical(rng, logits)[:, None].astype(jnp.int32)

    def generate(self, batch: dict[str, Any], max_new: int | None = None,
                 seed: int | None = None):
        """batch: {'tokens': [b, s], ...extras}. Returns [b, n_new] tokens.

        ``max_new=None`` falls back to the config default; an explicit
        ``max_new=0`` is honored and returns an empty ``[b, 0]`` result.

        ``seed``: per-request seed; by default the engine's RNG is split and
        carried across calls so repeated sampled generations differ.

        RNG strategy: a seeded request derives its whole sampling stream
        from ``PRNGKey(seed)`` alone — it never reads or advances the
        engine-level carried RNG, and the per-token keys are split from
        the request key, not from any monitoring state.  Consequences the
        adaptive loop depends on: (a) two requests with the same seed and
        prompt sample identical tokens regardless of how many unseeded
        requests ran in between (engine split order is irrelevant), and
        (b) a monitoring plan swap mid-decode (``runtime.set_params`` /
        cadence change picked up by the per-token ``mon.sync``) cannot
        perturb sampling — MonitorParams are masks over counter lanes,
        data-flow-disjoint from logits and keys.  Tested in
        test_train_serve.py::test_serve_seeded_rng_independent, and
        inherited by the continuous engine's per-lane keys
        (test_serve_batching.py).
        """
        max_new = self.cfg.max_new_tokens if max_new is None else int(max_new)
        if max_new <= 0:
            b = int(np.shape(batch["tokens"])[0])
            return (
                jnp.zeros((b, 0), jnp.int32),
                {"prefill_s": 0.0, "decode_total_s": 0.0,
                 "decode_per_tok_s": 0.0, "decode_p50_s": 0.0},
            )
        if seed is not None:
            rng = jax.random.PRNGKey(seed)
        else:
            self._rng, rng = jax.random.split(self._rng)
        t0 = time.perf_counter()
        # pick up live runtime knobs (mask/period/cadence) — reference
        # swaps into the state pytree, never a re-trace
        self.mstate = self.mon.sync(self.mstate, runtime=self.runtime)
        (cache, logits), self.mstate = self._jit_prefill(
            self.mstate, self.params, batch
        )
        self.runtime.observe(self.mstate.counters)
        jax.block_until_ready(logits)  # output sync: sampling needs logits
        prefill_s = time.perf_counter() - t0
        outs = []
        tok = self._sample(logits, rng)
        t0 = time.perf_counter()
        for i in range(max_new):
            outs.append(tok)
            self.mstate = self.mon.sync(self.mstate, runtime=self.runtime)
            (logits, cache), self.mstate = self._jit_decode(
                self.mstate, self.params, cache, tok
            )
            # async monitoring: swap the ring ref to the drain thread and
            # keep decoding — no block_until_ready inside the token loop.
            self.runtime.on_step(self.mstate.counters,
                                 ring=self.mstate.ring)
            rng, sub = jax.random.split(rng)
            tok = self._sample(logits, sub)
        out = jnp.concatenate(outs, axis=1)
        jax.block_until_ready(out)  # output sync: the sampled tokens
        decode_s = time.perf_counter() - t0
        per_tok = decode_s / max_new
        shape_key = (int(np.shape(batch["tokens"])[0]), max_new)
        bucket = self.step_times.setdefault(shape_key, [])
        bucket.append(per_tok)
        return (
            out,
            {
                "prefill_s": prefill_s,
                "decode_total_s": decode_s,
                "decode_per_tok_s": per_tok,
                # p50 over THIS call's (batch, max_new) bucket only
                "decode_p50_s": float(np.median(bucket)),
            },
        )

    def report(self) -> str:
        self.runtime.observe(self.mstate.counters)
        return self.runtime.report("ScALPEL serving report")


class ContinuousEngine:
    """Continuous-batching engine: submit requests, run megasteps, join.

    Usage::

        eng = ContinuousEngine(arch, params, ServeConfig(n_lanes=8))
        rid = eng.submit(tokens, max_new=64, seed=123)
        results = eng.run()      # {rid: ServeResult(tokens, counters, lane)}

    RNG contract (inherited from ``Engine.generate``): a seeded request's
    stream derives from ``PRNGKey(seed)`` alone — the first token samples
    with the unsplit key on the prefill logits, then each decode step
    splits per token inside its lane.  vmap guarantees per-lane streams
    are bitwise identical to a serial run, so identical seeds produce
    identical tokens regardless of lane placement or concurrent unseeded
    traffic.

    Host-sync discipline: megastep dispatch, admission (prefill + slab
    write), counter-ring publish and token-ring publish are all async; the
    token ring is drained one megastep BEHIND the dispatch (its producer
    already retired, so the copy doesn't wait on in-flight work).  The one
    blocking readback is the final drain when all lanes empty — request
    completion.  ``stats`` counts every dispatch and drain so tests can
    attest the zero-syncs-per-token claim.
    """

    def __init__(self, arch: Arch, params, cfg: ServeConfig,
                 spec=None, runtime=None):
        self.arch = arch
        self.params = params
        self.cfg = cfg
        if spec is None:
            spec = _discover_spec(arch, cfg)
        self.spec = spec
        self.runtime = runtime or scalpel.ScalpelRuntime(spec)
        self.mon = scalpel.Monitor(spec, telemetry=self.runtime.telemetry)
        n = int(cfg.n_lanes)
        shards = int(cfg.lane_shards)
        self.mesh = None
        if shards > 1:
            from repro.dist.partition import lane_mesh

            if n % shards:
                raise ValueError(
                    f"n_lanes={n} must divide evenly over "
                    f"lane_shards={shards}")
            self.mesh = lane_mesh(shards)
        self.driver = DecodeDriver(
            arch, self.mon, cache_len=cfg.cache_len,
            temperature=cfg.temperature,
            steps_per_commit=cfg.steps_per_commit,
            mesh=self.mesh,
        )
        self._buckets = cfg.bucket_widths(arch.supports_prefill_length)
        self.sched = Scheduler(n, buckets=self._buckets)
        self.lstate = self.mon.lane_init(n)
        # per-lane decode state: slab of batch-1 caches + current token +
        # RNG key + active/remaining masks (all donated through megasteps)
        self.slab = arch.init_lane_cache(n, cfg.cache_len, mesh=self.mesh)
        self.tok = jnp.zeros((n, 1, 1), jnp.int32)
        self.keys = jnp.stack([jax.random.PRNGKey(0)] * n)
        self.active = jnp.zeros((n,), jnp.int32)
        self.remaining = jnp.zeros((n,), jnp.int32)
        depth = int(cfg.token_ring_depth) or max(2 * cfg.steps_per_commit, 8)
        self.tok_ring = self.runtime.telemetry.make_token_ring(n, depth)
        if self.mesh is not None:
            self._place_sharded()
        self._rng = jax.random.PRNGKey(cfg.seed)
        self._warned_traces = False
        self.stats = {
            "megasteps": 0, "prefills": 0, "admissions": 0,
            "tokens_out": 0, "token_drains": 0, "wall_s": 0.0,
        }

    def _place_sharded(self) -> None:
        """Lay the initial lane state out on the lane mesh: lane-dim leaves
        split over the ``lanes`` axis, aggregate leaves replicated — the
        shard_map programs then consume everything without a resharding
        copy (and donation recycles the same sharded buffers)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = self.mesh
        lane = NamedSharding(mesh, P("lanes"))
        rep = NamedSharding(mesh, P())
        row1 = NamedSharding(mesh, P(None, "lanes"))
        put = jax.device_put
        self.slab = jax.tree.map(lambda x: put(x, lane), self.slab)
        self.tok = put(self.tok, lane)
        self.keys = put(self.keys, lane)
        self.active = put(self.active, lane)
        self.remaining = put(self.remaining, lane)
        ls = self.lstate
        self.lstate = dataclasses.replace(
            ls,
            lane_calls=put(ls.lane_calls, lane),
            lane_values=put(ls.lane_values, lane),
            lane_samples=put(ls.lane_samples, lane),
            lane_sched=put(ls.lane_sched, lane),
            calls=put(ls.calls, rep),
            values=put(ls.values, rep),
            samples=put(ls.samples, rep),
            step=put(ls.step, rep),
            ring=jax.tree.map(lambda x: put(x, rep), ls.ring),
        )
        tr = self.tok_ring
        self.tok_ring = dataclasses.replace(
            tr, steps=put(tr.steps, rep), toks=put(tr.toks, row1),
            live=put(tr.live, row1), head=put(tr.head, rep),
        )

    @property
    def counters(self):
        """Aggregate (lane-summed) cumulative counters — serial-comparable."""
        return self.lstate.counters

    def submit(self, tokens, max_new: int | None = None,
               seed: int | None = None) -> int:
        """Queue a single request (tokens: [1, s]); returns its rid.
        ``max_new=None`` falls back to the config; 0 completes immediately
        with an empty result."""
        max_new = self.cfg.max_new_tokens if max_new is None \
            else int(max_new)
        return self.sched.submit(tokens, max_new, seed)

    def _admit_ready(self) -> None:
        for lane in self.sched.free_lanes():
            if not self.sched.queue:
                break
            req = self.sched.queue.popleft()
            if req.seed is not None:
                key = jax.random.PRNGKey(req.seed)
            else:
                self._rng, key = jax.random.split(self._rng)
            # two async dispatches per admission: monitored prefill (+
            # first-token sample with the UNSPLIT request key — the serial
            # contract) and the slab/counter-row write
            s = int(np.shape(req.tokens)[1])
            width = self.sched.route(s)
            if self._buckets is not None:
                toks = np.asarray(req.tokens)
                if width > s:
                    toks = np.pad(toks, ((0, 0), (0, width - s)))
                cache, tok0, pdelta = self.driver.prefill_bucketed(
                    self.params, self.lstate.params, toks, s, key)
            else:
                cache, tok0, pdelta = self.driver.prefill(
                    self.params, self.lstate.params, req.tokens, key)
            self._check_traces()
            (self.slab, self.tok, self.keys, self.active,
             self.remaining), self.lstate = self.driver.admit(
                self.lstate, self.slab, self.tok, self.keys, self.active,
                self.remaining, lane, cache, tok0, key, req.max_new, pdelta)
            self.sched.admit(lane, req)
            self.stats["prefills"] += 1
            self.stats["admissions"] += 1

    def _check_traces(self) -> None:
        """One-shot compile-churn warning: when prefill has traced more
        than twice per bucket actually in use, admission is re-compiling
        per prompt length — point at the bucket config."""
        if self._warned_traces:
            return
        traces = self.driver.trace_counts()["prefill_traces"]
        n_buckets = (len(self.sched.buckets_used)
                     if self._buckets is not None else 1)
        if traces > 2 * max(1, n_buckets):
            self._warned_traces = True
            import warnings

            hint = ("prefill_buckets is disabled or unsupported for this "
                    "family" if self._buckets is None else
                    f"buckets in use: {sorted(self.sched.buckets_used)}")
            warnings.warn(
                f"serve prefill has compiled {traces} traces for "
                f"{max(1, n_buckets)} prompt bucket(s) — every distinct "
                f"prompt length is re-tracing. Configure "
                f"ServeConfig.prefill_buckets/prefill_bucket_min to bound "
                f"compiles ({hint}).", RuntimeWarning, stacklevel=3)

    def run(self) -> dict[int, ServeResult]:
        """Drive megasteps until every submitted request completes."""
        plane = self.runtime.telemetry
        k = self.cfg.steps_per_commit
        t0 = time.perf_counter()
        while True:
            # knob swaps (adaptive/runtime) land here — megastep boundary
            self.lstate = self.mon.sync(self.lstate, runtime=self.runtime)
            self._admit_ready()
            if not self.sched.occupied:
                break
            (self.slab, self.tok, self.keys, self.active, self.remaining), \
                self.lstate, self.tok_ring = self.driver.megastep(
                    self.lstate, self.params, self.slab, self.tok,
                    self.keys, self.active, self.remaining, self.tok_ring)
            self.stats["megasteps"] += 1
            # arithmetic completion: each occupied lane advanced by
            # min(K, remaining) tokens — no device readback to retire
            for lane, rid in self.sched.advance(k):
                # harvest per-request counters as eager device slices
                # (async); materialized at join
                self.sched.set_counters(rid,
                                        self.lstate.lane_counters(lane))
            # async monitoring egress: aggregate ring to the drain thread
            self.runtime.on_step(self.lstate.counters,
                                 ring=self.lstate.ring)
            # pipelined token drain: consume the PREVIOUS megastep's ring
            # (its producer already retired) before publishing this one
            self.stats["tokens_out"] += self.sched.attribute(
                plane.drain_tokens())
            self.stats["token_drains"] += 1
            plane.publish_tokens(self.tok_ring)
        # the one blocking readback: the final ring drain at completion
        self.stats["tokens_out"] += self.sched.attribute(
            plane.drain_tokens())
        self.stats["token_drains"] += 1
        self.stats["wall_s"] += time.perf_counter() - t0
        if plane.dropped_tokens:
            raise RuntimeError(
                f"token ring overrun: {plane.dropped_tokens} slots lost — "
                f"token_ring_depth must exceed appends per drain")
        results = self.sched.results()
        for r in results.values():
            if r.counters is not None:
                r.counters = scalpel.Monitor.lane_counters_host(r.counters)
        return results

    def compile_stats(self) -> dict[str, Any]:
        """Jit cache sizes of the three serve programs plus the pad-waste
        fraction — the bucketing win's observable surface."""
        out = self.driver.trace_counts()
        out["pad_waste_frac"] = self.sched.pad_waste_frac
        out["buckets_used"] = sorted(self.sched.buckets_used)
        return out

    def report(self) -> str:
        self.runtime.observe(self.lstate.counters)
        rep = self.runtime.report("ScALPEL serving report (continuous)")
        cs = self.compile_stats()
        rep += (
            f"\ncompile: prefill_traces={cs['prefill_traces']} "
            f"admission_traces={cs['admission_traces']} "
            f"megastep_traces={cs['megastep_traces']} "
            f"pad_waste_frac={cs['pad_waste_frac']:.3f} "
            f"lane_shards={int(self.cfg.lane_shards)}"
        )
        return rep
