"""Batched serving engine: jitted prefill + decode with ScALPEL counters.

Static-batch engine (the production norm for TPU serving): a fixed batch of
slots, one prefill per batch, token-synchronous decode steps.  Decode
counters use the same MonitorSpec machinery as training, so a serving
deployment gets per-scope KV/attention monitoring and the same runtime
reconfiguration (mask/period swaps between decode steps).

Monitoring rides the functional ``Monitor`` API: prefill and decode are
``mon.wrap``-ped pure functions of ONE MonitorState pytree — the compact
counters, the device-side telemetry ring, and the decode-step stamp that
the old engine carried as three separate attributes.  Each wrapped call
ring-appends in-graph (lax.cond-guarded on the runtime cadence) and the
ring is drained by the telemetry plane's background thread.  The engine
only synchronizes with the device for its outputs — prefill logits and the
final sampled tokens — never for monitoring.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.models.registry import Arch


@dataclasses.dataclass
class ServeConfig:
    cache_len: int = 1024
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


class Engine:
    def __init__(self, arch: Arch, params, cfg: ServeConfig,
                 spec=None, runtime=None):
        self.arch = arch
        self.params = params
        self.cfg = cfg
        if spec is None:
            # discover scopes from an abstract prefill+decode
            def probe_fn(p, toks):
                cache, logits = arch.prefill(p, {"tokens": toks},
                                             cache_len=cfg.cache_len)
                return arch.decode_step(p, cache, toks[:, :1])

            seen = scalpel.discover(
                probe_fn, arch.abstract_params(),
                jax.ShapeDtypeStruct((1, min(32, cfg.cache_len)), jnp.int32),
            )
            spec = scalpel.spec_from_discovery(seen)
        self.spec = spec
        self.runtime = runtime or scalpel.ScalpelRuntime(spec)
        # ONE pytree replaces the old (counters, ring, decode_step) triple:
        # the monitor borrows the runtime's telemetry plane for its ring.
        self.mon = scalpel.Monitor(spec, telemetry=self.runtime.telemetry)
        self.mstate = self.mon.init()
        self.step_times: list[float] = []
        # the RNG carries across generate() calls — reseeding per call would
        # make every generation sample identically (see generate()).
        self._rng = jax.random.PRNGKey(cfg.seed)

        def _prefill(params, batch):
            return self.arch.prefill(params, batch,
                                     cache_len=self.cfg.cache_len)

        def _decode(params, cache, tokens):
            return self.arch.decode_step(params, cache, tokens)

        # wrapped signatures: (mstate, *args) -> (out, mstate).  Monitor.jit
        # draws the jit boundary leaf-wise (runtime knobs never round-trip
        # the graph); the cache is donated, the MonitorState is NOT (its
        # ring buffers are read by the telemetry drain thread while later
        # decode steps run).
        self._jit_prefill = self.mon.jit(_prefill)
        self._jit_decode = self.mon.jit(_decode, donate_argnums=(1,))

    @property
    def counters(self):
        """The engine's cumulative counters (compact dense layout)."""
        return self.mstate.counters

    def _sample(self, logits, rng):
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        logits = logits / self.cfg.temperature
        return jax.random.categorical(rng, logits)[:, None].astype(jnp.int32)

    def generate(self, batch: dict[str, Any], max_new: int | None = None,
                 seed: int | None = None):
        """batch: {'tokens': [b, s], ...extras}. Returns [b, n_new] tokens.

        ``seed``: per-request seed; by default the engine's RNG is split and
        carried across calls so repeated sampled generations differ.

        RNG strategy: a seeded request derives its whole sampling stream
        from ``PRNGKey(seed)`` alone — it never reads or advances the
        engine-level carried RNG, and the per-token keys are split from
        the request key, not from any monitoring state.  Consequences the
        adaptive loop depends on: (a) two requests with the same seed and
        prompt sample identical tokens regardless of how many unseeded
        requests ran in between (engine split order is irrelevant), and
        (b) a monitoring plan swap mid-decode (``runtime.set_params`` /
        cadence change picked up by the per-token ``mon.sync``) cannot
        perturb sampling — MonitorParams are masks over counter lanes,
        data-flow-disjoint from logits and keys.  Tested in
        test_train_serve.py::test_serve_seeded_rng_independent.
        """
        max_new = max_new or self.cfg.max_new_tokens
        if seed is not None:
            rng = jax.random.PRNGKey(seed)
        else:
            self._rng, rng = jax.random.split(self._rng)
        t0 = time.perf_counter()
        # pick up live runtime knobs (mask/period/cadence) — reference
        # swaps into the state pytree, never a re-trace
        self.mstate = self.mon.sync(self.mstate, runtime=self.runtime)
        (cache, logits), self.mstate = self._jit_prefill(
            self.mstate, self.params, batch
        )
        self.runtime.observe(self.mstate.counters)
        jax.block_until_ready(logits)  # output sync: sampling needs logits
        prefill_s = time.perf_counter() - t0
        outs = []
        tok = self._sample(logits, rng)
        t0 = time.perf_counter()
        for i in range(max_new):
            outs.append(tok)
            self.mstate = self.mon.sync(self.mstate, runtime=self.runtime)
            (logits, cache), self.mstate = self._jit_decode(
                self.mstate, self.params, cache, tok
            )
            # async monitoring: swap the ring ref to the drain thread and
            # keep decoding — no block_until_ready inside the token loop.
            self.runtime.on_step(self.mstate.counters,
                                 ring=self.mstate.ring)
            rng, sub = jax.random.split(rng)
            tok = self._sample(logits, sub)
        out = jnp.concatenate(outs, axis=1)
        jax.block_until_ready(out)  # output sync: the sampled tokens
        decode_s = time.perf_counter() - t0
        per_tok = decode_s / max_new if max_new else 0.0
        self.step_times.append(per_tok)
        return (
            out,
            {
                "prefill_s": prefill_s,
                "decode_total_s": decode_s,
                "decode_per_tok_s": per_tok,
                "decode_p50_s": float(np.median(self.step_times))
                if self.step_times else 0.0,
            },
        )

    def report(self) -> str:
        self.runtime.observe(self.mstate.counters)
        return self.runtime.report("ScALPEL serving report")
