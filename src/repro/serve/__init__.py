from .driver import DecodeDriver  # noqa: F401
from .engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    ServeConfig,
)
from .scheduler import Request, Scheduler, ServeResult  # noqa: F401
