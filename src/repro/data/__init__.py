from .pipeline import DataConfig, SyntheticLM, prefetch, shard_batch  # noqa: F401
