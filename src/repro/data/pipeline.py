"""Deterministic synthetic LM data pipeline.

Production shape without production data: document-structured synthetic token
streams (Zipfian unigrams + per-document Markov drift + EOS packing), fully
deterministic in (seed, step) — a restart resumes the stream exactly, which
the checkpoint/restart test relies on.  Batches are staged to device with the
mesh sharding, with a background prefetch queue of configurable depth.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512
    zipf_a: float = 1.2


class SyntheticLM:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step])
        )
        n = c.global_batch * (c.seq_len + 1)
        # zipfian unigram pool, bounded to vocab
        toks = rng.zipf(c.zipf_a, size=n).astype(np.int64)
        toks = (toks % (c.vocab - 1)) + 1  # reserve 0 for EOS
        # per-document drift: add a doc-local offset, then EOS boundaries
        doc_len = np.maximum(
            8, rng.poisson(c.mean_doc_len, size=n // 8 + 2)
        )
        bounds = np.cumsum(doc_len)
        bounds = bounds[bounds < n]
        offsets = np.zeros(n, np.int64)
        if len(bounds):
            drift = rng.integers(0, c.vocab // 4, size=len(bounds) + 1)
            offsets = drift[np.searchsorted(bounds, np.arange(n),
                                            side="right")]
        toks = ((toks + offsets) % (c.vocab - 1)) + 1
        toks[bounds] = c.eos_id
        toks = toks.reshape(c.global_batch, c.seq_len + 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def shard_batch(batch, mesh=None, axes=None):
    """Stage a host batch onto the mesh with 'batch'-axis sharding."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    from repro.dist.partition import logical_to_pspec
    from jax.sharding import NamedSharding

    def put(name, x):
        ax = (axes or {}).get(name, ("batch",) + (None,) * (x.ndim - 1))
        return jax.device_put(
            x, NamedSharding(mesh, logical_to_pspec(ax, mesh=mesh))
        )

    return {k: put(k, v) for k, v in batch.items()}


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch queue."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    _SENTINEL = object()

    def worker():
        try:
            for x in it:
                q.put(x)
        finally:
            q.put(_SENTINEL)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        x = q.get()
        if x is _SENTINEL:
            return
        yield x
