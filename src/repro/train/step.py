"""Training step: microbatched gradient accumulation with ScALPEL counters
threaded through the whole step (forward probes via grad aux, gradient-level
probes after accumulation, optimizer update inside the same jitted program).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.core import telemetry as telemetry_lib
from repro.core.counters import CounterState, MonitorParams
from repro.models.registry import Arch
from repro.optim import OptConfig, apply_updates, global_norm, init_opt_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    counters: CounterState
    step: Any

    @staticmethod
    def create(arch: Arch, opt_cfg: OptConfig, spec, rng):
        params = arch.init(rng)
        return TrainState(
            params=params,
            opt=init_opt_state(opt_cfg, params),
            counters=CounterState.zeros(spec),
            step=jnp.zeros((), jnp.int32),
        )


GRAD_SCOPE_EVENTS = ["MEAN:gnorm", "MEAN:loss_value"]


def build_monitor_spec(arch: Arch, batch,
                       tensor_events=("ACT_RMS",),
                       extra: dict | None = None):
    """Discover the compile-time scope set from one abstract forward+loss.

    The analogue of compiling with -finstrument-functions: every scope the
    traced program touches becomes interceptable; generic tensor events are
    attached to every probed tensor; callers can override per-scope contexts
    afterwards (MonitorSpec.with_context) or via a ScALPEL config file.
    """
    abstract_batch = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype
                                       if not hasattr(x, "dtype") else x.dtype),
        batch,
    )
    params = arch.abstract_params()
    seen = scalpel.discover(
        lambda p, b: arch.loss_fn(p, b), params, abstract_batch
    )
    spec = scalpel.spec_from_discovery(seen, tensor_events=tensor_events)
    from repro.core.context import EventSpec, ScopeContext

    spec = spec.with_context(
        ScopeContext.exhaustive(
            "grads", [EventSpec.parse(e) for e in GRAD_SCOPE_EVENTS]
        )
    )
    if extra:
        from repro.core.context import spec_from_mapping

        for ctx in spec_from_mapping(extra).contexts:
            spec = spec.with_context(ctx)
    return spec


def make_train_step(arch: Arch, opt_cfg: OptConfig, spec,
                    microbatches: int = 1, counter_axes=None):
    """Build the jittable train_step(tstate, batch, mparams) -> (tstate, out).

    ``counter_axes``: mesh axis names to psum counters over (multi-host
    aggregation — the paper's MPI support); None on a single device.

    The step optionally carries a telemetry ``SnapshotRing``: call it as
    ``train_step(tstate, batch, mparams, tparams, ring)`` and the step's
    final counters are ring-appended in-graph (lax.cond-guarded on the
    dynamic cadence in ``tparams`` — changing it never re-traces) and the
    updated ring is returned third.  The ring argument must NOT be donated:
    the telemetry drain thread reads the previous ring's buffers while the
    next step runs.
    """

    def mb_loss(params, mb, calls_base, mparams):
        cs = CounterState(
            calls=calls_base,
            values=jnp.zeros((spec.n_scopes, spec.max_slots), jnp.float32),
            samples=jnp.zeros((spec.n_scopes, spec.max_slots), jnp.int32),
        )
        with scalpel.collecting(spec, mparams, cs) as col:
            loss = arch.loss_fn(params, mb)
        return loss, col.delta

    vag = jax.value_and_grad(mb_loss, has_aux=True)

    def train_step(tstate: TrainState, batch, mparams: MonitorParams,
                   tparams: telemetry_lib.TelemetryParams | None = None,
                   ring: telemetry_lib.SnapshotRing | None = None):
        base = tstate.counters
        params = tstate.params

        if microbatches == 1:
            # grads stay in param dtype; the optimizer casts per-leaf
            (loss, delta), grads = vag(params, batch, base.calls, mparams)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mb):
                gacc, dacc, lacc = carry
                (l, d), g = vag(params, mb, base.calls + dacc.calls, mparams)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g
                )
                return (gacc, dacc.add(d), lacc + l), None

            (grads, delta, loss), _ = jax.lax.scan(
                body, (g0, CounterState.zeros(spec), jnp.zeros((), jnp.float32)),
                split,
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        # -- step-level scope: gradient statistics ------------------------
        mid = base.add(delta)
        with scalpel.collecting(spec, mparams, mid) as col:
            with scalpel.function("grads"):
                scalpel.probe(
                    gnorm=global_norm(grads)[None],
                    loss_value=loss[None],
                )
        new_params, new_opt, stats = apply_updates(
            opt_cfg, tstate.opt, params, grads
        )
        counters = mid.add(col.delta)
        if counter_axes:
            counters = counters.psum(counter_axes)
        new_state = TrainState(
            params=new_params, opt=new_opt, counters=counters,
            step=tstate.step + 1,
        )
        out = {"loss": loss, **stats}
        if ring is None:
            return new_state, out
        ring = telemetry_lib.ring_append(ring, counters, tparams,
                                         step=new_state.step)
        return new_state, out, ring

    return train_step
