"""Training step: microbatched gradient accumulation with ScALPEL counters
threaded through ONE MonitorState pytree (forward probes via grad aux,
gradient-level probes after accumulation, optimizer update inside the same
jitted program, mesh-aware counter reduction through the Monitor).

The step never touches ``col.delta`` or a padded CounterState: microbatch
deltas accumulate in the spec's compact dense layout (``plan.CompactDelta``
rides the gradient-accumulation scan), and ``Monitor.commit`` folds the
step's total into the carried MonitorState — psum over whatever mesh axes
are bound, step stamp, in-graph telemetry ring append at the dynamic
cadence, all in one place.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.core import plan as plan_lib
from repro.models.registry import Arch
from repro.optim import OptConfig, apply_updates, global_norm, init_opt_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    """Model-side state only — counters live in the MonitorState pytree,
    which is threaded separately so the train state can be donated while
    the telemetry ring's buffers stay readable by the drain thread."""

    params: Any
    opt: Any
    step: Any

    @staticmethod
    def create(arch: Arch, opt_cfg: OptConfig, rng):
        params = arch.init(rng)
        return TrainState(
            params=params,
            opt=init_opt_state(opt_cfg, params),
            step=jnp.zeros((), jnp.int32),
        )


GRAD_SCOPE_EVENTS = ["MEAN:gnorm", "MEAN:loss_value"]


def build_monitor_spec(arch: Arch, batch,
                       tensor_events=("ACT_RMS",),
                       extra: dict | None = None):
    """Discover the compile-time scope set from one abstract forward+loss.

    The analogue of compiling with -finstrument-functions: every scope the
    traced program touches becomes interceptable; generic tensor events are
    attached to every probed tensor; callers can override per-scope contexts
    afterwards (MonitorSpec.with_context) or via a ScALPEL config file.
    """
    abstract_batch = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype
                                       if not hasattr(x, "dtype") else x.dtype),
        batch,
    )
    params = arch.abstract_params()
    seen = scalpel.discover(
        lambda p, b: arch.loss_fn(p, b), params, abstract_batch
    )
    spec = scalpel.spec_from_discovery(seen, tensor_events=tensor_events)
    from repro.core.context import EventSpec, ScopeContext

    spec = spec.with_context(
        ScopeContext.exhaustive(
            "grads", [EventSpec.parse(e) for e in GRAD_SCOPE_EVENTS]
        )
    )
    if extra:
        from repro.core.context import spec_from_mapping

        for ctx in spec_from_mapping(extra).contexts:
            spec = spec.with_context(ctx)
    return spec


def _make_step_core(arch: Arch, opt_cfg: OptConfig, spec, microbatches: int,
                    mon: scalpel.Monitor):
    """The single-step body in the WRAPPED signature:
    ``step_core(mstate, tstate, batch) -> ((tstate', out), mstate')``.

    Opens its own collection regions (the forward probes ride a
    ``value_and_grad`` aux, so the ambient-collector path cannot carry
    them) and folds the step's compact delta through ``mon.commit``
    exactly once — which makes it directly drivable by
    ``Monitor.scan(..., wrapped=True)`` for megasteps.
    """

    def mb_loss(params, mb, calls_base, mparams):
        with mon.open(mparams, calls_base=calls_base) as col:
            loss = arch.loss_fn(params, mb)
        return loss, col.compact_delta()

    vag = jax.value_and_grad(mb_loss, has_aux=True)

    def step_core(mstate: scalpel.MonitorState, tstate: TrainState, batch):
        params = tstate.params
        # the multiplex schedule follows THIS shard's own call counts —
        # never the mesh-reduced totals in mstate.calls (which double as
        # the base only for monitors that never reduce)
        base_calls = mstate.sched_calls if mstate.sched_calls is not None \
            else mstate.calls

        if microbatches == 1:
            # grads stay in param dtype; the optimizer casts per-leaf
            (loss, delta), grads = vag(params, batch, base_calls,
                                       mstate.params)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mb):
                gacc, dacc, lacc = carry
                (l, d), g = vag(params, mb, base_calls + dacc.calls,
                                mstate.params)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g
                )
                return (gacc, dacc.add(d), lacc + l), None

            # the accumulation carry rides the COMPACT footprint — the
            # padded [n_scopes, max_slots] block appears nowhere in the step
            (grads, delta, loss), _ = jax.lax.scan(
                body,
                (g0, plan_lib.CompactDelta.zeros(spec),
                 jnp.zeros((), jnp.float32)),
                split,
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        # -- step-level scope: gradient statistics ------------------------
        with mon.open(mstate.params,
                      calls_base=base_calls + delta.calls) as col:
            with scalpel.function("grads"):
                scalpel.probe(
                    gnorm=global_norm(grads)[None],
                    loss_value=loss[None],
                )
        delta = delta.add(col.compact_delta())
        new_params, new_opt, stats = apply_updates(
            opt_cfg, tstate.opt, params, grads
        )
        # mesh reduction + accumulate + step stamp + ring append, in one
        # place — the call site never sees a counter again
        mstate = mon.commit(mstate, delta)
        new_state = TrainState(
            params=new_params, opt=new_opt, step=tstate.step + 1,
        )
        return (new_state, {"loss": loss, **stats}), mstate

    return step_core


def make_train_step(arch: Arch, opt_cfg: OptConfig, spec,
                    microbatches: int = 1, counter_axes="auto",
                    monitor: scalpel.Monitor | None = None):
    """Build the jittable ``train_step(tstate, batch, mstate) ->
    (tstate', out, mstate')``.

    ``mstate`` is the functional MonitorState pytree (``monitor.init()``):
    compact counters, telemetry ring, step stamp, and the runtime
    MonitorParams/TelemetryParams — all dynamic inputs, so mask/period/
    cadence swaps between steps never re-trace.  It must NOT be donated:
    the telemetry drain thread reads the carried ring's buffers while the
    next step runs.

    ``counter_axes``: mesh axes to psum counters over (the paper's MPI
    support).  The default "auto" reduces over whichever ambient-mesh axes
    the trace binds — cluster-wide sums under ``shard_map``/pmap, a no-op
    under plain jit or on a single device.  Pass ``monitor`` to share a
    configured Monitor (e.g. one owning a telemetry plane) instead.

    For the megastep form (one commit/dispatch per K steps) see
    ``make_train_megastep`` — this single-step signature is kept for
    callers that drive and jit one step at a time.
    """
    mon = monitor if monitor is not None else scalpel.Monitor(
        spec, counter_axes=counter_axes
    )
    step_core = _make_step_core(arch, opt_cfg, spec, microbatches, mon)

    def train_step(tstate: TrainState, batch, mstate: scalpel.MonitorState):
        (new_state, out), mstate = step_core(mstate, tstate, batch)
        return new_state, out, mstate

    train_step.monitor = mon
    return train_step


def make_train_megastep(arch: Arch, opt_cfg: OptConfig, spec,
                        microbatches: int = 1, counter_axes="auto",
                        monitor: scalpel.Monitor | None = None):
    """Build the K-step megastep train driver on ``Monitor.scan``:
    ``train_megastep(mstate, batches, tstate) -> ((tstate', outs),
    mstate')``.

    ``batches`` is a per-step batch pytree stacked on a leading axis — its
    length IS the steps-per-commit for the call (a ragged final chunk just
    passes a shorter stack; each distinct K traces once).  All K steps run
    inside one ``lax.scan``: counters accumulate compactly in-carry, the
    per-shard ``sched_calls`` schedule base advances K×, and the telemetry
    ring appends on every inner step's true stamp at the dynamic cadence —
    while the host dispatch/commit boundary is crossed once.

    The wrapped signature plugs straight into ``Monitor.jit_wrapped`` for
    the leaf-wise boundary (read-only ``params``/``tparams`` enter the
    compiled step but never leave it; donate ``tstate`` via
    ``donate_argnums=(1,)`` — ``batches`` sits at 0).

    ``outs`` leaves are stacked ``[K, ...]`` (per-step loss/gnorm/lr).
    """
    mon = monitor if monitor is not None else scalpel.Monitor(
        spec, counter_axes=counter_axes
    )
    step_core = _make_step_core(arch, opt_cfg, spec, microbatches, mon)

    def body(mstate, tstate, batch):
        (tstate, out), mstate = step_core(mstate, tstate, batch)
        return ((tstate, out), mstate)

    mega = mon.scan(body, wrapped=True)

    def train_megastep(mstate: scalpel.MonitorState, batches,
                       tstate: TrainState):
        # the scan carry holds the final TrainState; ys stack each step's
        # out dict on the leading axis
        (tstate, outs), mstate = mega(mstate, tstate, batches)
        return (tstate, outs), mstate

    train_megastep.monitor = mon
    return train_megastep
