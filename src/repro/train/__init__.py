from .step import TrainState, build_monitor_spec, make_train_step  # noqa: F401
from .loop import TrainLoopConfig, fit  # noqa: F401
