from .step import (TrainState, build_monitor_spec,  # noqa: F401
                   make_train_megastep, make_train_step)
from .loop import TrainLoopConfig, fit  # noqa: F401
