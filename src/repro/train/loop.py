"""Training loop: data pipeline + jitted step + ScALPEL runtime + fault
tolerance (checkpoint/restart, straggler detection via the host_time
backend, NaN tripwire via in-graph counters).

The monitored hot path is fully asynchronous: the jitted step appends its
counters to a device-side SnapshotRing in-graph (telemetry plane), the loop
keeps a bounded window of in-flight steps instead of blocking every step,
and the adaptive hooks (NaN tripwire, straggler detection) run on drained
snapshots on the telemetry drain thread — never a synchronous
full-CounterState device→host transfer inside the step loop.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro import core as scalpel
from repro.checkpoint import CheckpointManager
from repro.core.backends.host_time import HostTimer
from repro.data import DataConfig, SyntheticLM, prefetch, shard_batch
from repro.models.registry import Arch
from repro.optim import OptConfig
from .step import TrainState, build_monitor_spec, make_train_megastep


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    microbatches: int = 1
    seed: int = 0
    straggler_sigma: float = 3.0
    monitor_config_path: str | None = None  # ScALPEL config file (reloadable)
    jsonl_path: str | None = None
    hook_every: int = 10       # telemetry ring-append cadence (steps)
    ring_depth: int = 8        # device-side snapshot ring depth
    max_in_flight: int = 2     # bounded dispatch window (megasteps)
    # steps per commit/dispatch: K>1 fuses K train steps into one compiled
    # megastep (lax.scan) — one host dispatch, one counter commit boundary,
    # ring snapshots still on true per-step stamps.  mon.sync (and so the
    # adaptive controller's decisions) applies at megastep boundaries.
    steps_per_commit: int = 1
    strict_plan_resume: bool = True  # raise (vs warn) on plan mismatch
    # closed adaptive loop: True (default AdaptiveConfig) or an
    # AdaptiveConfig — installs an AdaptiveController on the runtime; the
    # loop's existing mon.sync picks up its escalation/cadence decisions
    adaptive: Any = None
    graceful_shutdown: bool = False  # SIGTERM/atexit flush + final report


def fit(arch: Arch, opt_cfg: OptConfig, data_cfg: DataConfig,
        loop_cfg: TrainLoopConfig, mesh=None,
        on_report: Callable | None = None) -> dict[str, Any]:
    """Train; returns summary dict (final loss, step times, reports)."""
    data = SyntheticLM(data_cfg)
    sample = data.batch_at(0)
    spec = build_monitor_spec(arch, sample)

    runtime = scalpel.ScalpelRuntime(
        spec,
        config_path=loop_cfg.monitor_config_path,
        jsonl_path=loop_cfg.jsonl_path,
        hook_every=loop_cfg.hook_every,
        ring_depth=loop_cfg.ring_depth,
        graceful_shutdown=loop_cfg.graceful_shutdown,
    )
    controller = None
    if loop_cfg.adaptive:
        controller = runtime.attach_controller(
            None if loop_cfg.adaptive is True else loop_cfg.adaptive
        )
    timer = HostTimer()
    events: list[str] = []

    # fault-tolerance hooks driven by drained telemetry snapshots (the hook
    # runs on the drain thread — it must not touch in-flight device buffers)
    nan_seen: set[str] = set()
    stragglers_seen: set[int] = set()

    def tripwire(rt, reports):
        for r in reports:
            for s in r.slots:
                if (s.slot_id.startswith("NAN_COUNT") and s.raw > 0
                        and r.scope not in nan_seen):
                    nan_seen.add(r.scope)
                    events.append(f"NaN detected in scope {r.scope}")
        # HostTimer.outliers re-reports the same indices every invocation;
        # dedupe so `events` records each straggler step once.
        bad = [i for i in timer.outliers("train_step",
                                         loop_cfg.straggler_sigma)
               if i not in stragglers_seen]
        if bad:
            stragglers_seen.update(bad)
            events.append(f"straggler steps (>{loop_cfg.straggler_sigma}σ): "
                          f"{bad[-3:]}")
        if on_report is not None:
            on_report(rt, reports)

    runtime.add_hook(tripwire)

    # the functional monitor: ONE pytree threads compact counters, the
    # telemetry ring, the step stamp and the runtime params through the step
    mon = scalpel.Monitor(spec, telemetry=runtime.telemetry)
    step_fn = make_train_megastep(arch, opt_cfg, spec,
                                  microbatches=loop_cfg.microbatches,
                                  monitor=mon)
    # leaf-wise jit boundary (the serve engine's): the read-only
    # MonitorParams/TelemetryParams enter the compiled megastep but are
    # never outputs — they stop round-tripping the step.  Donate the train
    # state only (argnum 1 past mstate: batches sit at 0) — the
    # MonitorState's ring buffers are read by the drain thread while later
    # steps run and must stay valid.
    jit_step = mon.jit_wrapped(step_fn, donate_argnums=(1,))

    mgr = (CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.ckpt_keep)
           if loop_cfg.ckpt_dir else None)

    # -- init or restore (crash recovery / elastic resume) -----------------
    tstate = TrainState.create(arch, opt_cfg,
                               jax.random.PRNGKey(loop_cfg.seed))
    mstate = mon.init()
    start_step = 0
    if mgr is not None and mgr.latest() is not None:
        latest = mgr.latest()
        # plan attestation FIRST, from the manifest alone: counters from
        # different compiled probe plans must not silently resume — and a
        # changed spec would otherwise surface as an opaque shape error
        # mid-restore rather than this diagnostic.
        attested = runtime.check_resume_metadata(
            mgr.metadata(latest), strict=loop_cfg.strict_plan_resume
        )
        if attested is None:
            # no fingerprint ⇒ the checkpoint predates the Monitor layout
            # ({'model', 'monitor'} tree) and CANNOT restore into it; fail
            # with a migration diagnostic, not a mid-restore KeyError.
            raise RuntimeError(
                f"checkpoint step_{latest} in {loop_cfg.ckpt_dir} predates "
                "the Monitor checkpoint layout (no plan fingerprint in "
                "meta.json); restart training or migrate the checkpoint"
            )
        saved_tree = {"model": tstate,
                      "monitor": mon.checkpoint_payload(mstate)}
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), saved_tree
        )
        saved, meta = mgr.restore(latest, abstract)
        tstate = saved["model"]
        mstate = mon.restore(mstate, saved["monitor"])
        start_step = int(meta["step"])
        events.append(f"restored from step {start_step}")

    losses: list[float] = []
    last_logged: dict[str, float] = {}
    max_in_flight = max(1, loop_cfg.max_in_flight)
    inflight: collections.deque = collections.deque()

    def retire(window: int) -> None:
        """Block on megasteps beyond the in-flight window, oldest first.
        ``out`` leaves are stacked per-step ``[K]`` arrays."""
        while len(inflight) > window:
            rstep, out = inflight.popleft()
            jax.block_until_ready(out["loss"])
            losses.extend(
                float(v) for v in np.asarray(out["loss"]).reshape(-1))
            last_logged.update(
                step=rstep, loss=losses[-1],
                gnorm=float(np.asarray(out["grad_norm"]).reshape(-1)[-1]),
                lr=float(np.asarray(out["lr"]).reshape(-1)[-1]),
            )

    K = max(1, loop_cfg.steps_per_commit)

    def megabatches():
        """Host batches grouped into K-step leading-axis stacks (the final
        chunk may be ragged — a shorter stack traces once per distinct K)."""
        buf: list = []
        first = start_step
        for s in range(start_step, loop_cfg.steps):
            buf.append(data.batch_at(s))
            if len(buf) == K or s == loop_cfg.steps - 1:
                yield first, s, jax.tree.map(
                    lambda *xs: np.stack(xs), *buf)
                buf, first = [], s + 1

    it = prefetch(megabatches(), 2)
    for first_step, last_step, host_batches in it:
        k_actual = last_step - first_step + 1
        # the per-step batch axis now sits under the stacked step axis
        batches = shard_batch(
            host_batches, mesh,
            axes={name: (None, "batch") + (None,) * (np.ndim(v) - 2)
                  for name, v in host_batches.items()},
        )
        t0 = time.perf_counter()
        # refresh the dynamic knobs riding in the state (mask/period/cadence
        # — reference swaps, never a re-trace); swaps take effect at the
        # NEXT megastep boundary, so the adaptive loop reacts with up to K
        # steps of latency
        mstate = mon.sync(mstate, runtime=runtime)
        (tstate, out), mstate = jit_step(mstate, batches, tstate)
        inflight.append((last_step, out))
        # bounded in-flight dispatch: only the megastep leaving the window
        # is synchronized, so device and host overlap up to max_in_flight
        # megasteps (amortized, the recorded time still equals the true
        # per-step time).
        retire(max_in_flight - 1)
        runtime.on_step(mstate.counters, ring=mstate.ring)
        # recorded PER STEP (megastep wall / K): straggler baselines and
        # step_stats survive a steps_per_commit swap
        timer.record("train_step",
                     (time.perf_counter() - t0) / k_actual)
        if loop_cfg.log_every and last_logged and any(
                s % loop_cfg.log_every == 0
                for s in range(first_step, last_step + 1)):
            # metrics belong to the most recently RETIRED megastep (the
            # window lags dispatch) — label them with its last step
            print(f"step {last_logged['step']:5d} "
                  f"loss {last_logged['loss']:.4f} "
                  f"gnorm {last_logged['gnorm']:.3f} "
                  f"lr {last_logged['lr']:.2e} "
                  f"dt {timer.stats('train_step').mean_s*1e3:.1f}ms "
                  f"(dispatched {last_step}, window {len(inflight)})")
        if mgr is not None and loop_cfg.ckpt_every and \
                (last_step + 1) // loop_cfg.ckpt_every \
                > first_step // loop_cfg.ckpt_every:
            # the cadence can only fire on megastep boundaries; save the
            # state that exists — after last_step+1 steps
            retire(0)
            mgr.save(last_step + 1,
                     {"model": tstate,
                      "monitor": mon.checkpoint_payload(mstate)},
                     extra=runtime.save_metadata())
    retire(0)
    if mgr is not None:
        mgr.save(loop_cfg.steps,
                 {"model": tstate,
                  "monitor": mon.checkpoint_payload(mstate)},
                 extra=runtime.save_metadata(), block=True)
        mgr.wait()

    report = runtime.report()  # flushes the ring through every sink
    if controller is not None:
        events.extend(controller.events)
    runtime.close()  # stop the drain thread; sinks are flushed + closed
    return {
        "controller": controller,
        "losses": losses,
        "final_loss": losses[-1] if losses else float("nan"),
        "step_stats": timer.stats("train_step"),
        "events": events,
        "report": report,
        "runtime": runtime,
        "state": tstate,
        "monitor": mstate,
        "spec": spec,
    }
