"""Training loop: data pipeline + jitted step + ScALPEL runtime + fault
tolerance (checkpoint/restart, straggler detection via the host_time
backend, NaN tripwire via in-graph counters).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as scalpel
from repro.checkpoint import CheckpointManager
from repro.core.backends.host_time import HostTimer
from repro.data import DataConfig, SyntheticLM, prefetch, shard_batch
from repro.models.registry import Arch
from repro.optim import OptConfig
from .step import TrainState, build_monitor_spec, make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    microbatches: int = 1
    seed: int = 0
    straggler_sigma: float = 3.0
    monitor_config_path: str | None = None  # ScALPEL config file (reloadable)
    jsonl_path: str | None = None
    hook_every: int = 10


def fit(arch: Arch, opt_cfg: OptConfig, data_cfg: DataConfig,
        loop_cfg: TrainLoopConfig, mesh=None,
        on_report: Callable | None = None) -> dict[str, Any]:
    """Train; returns summary dict (final loss, step times, reports)."""
    data = SyntheticLM(data_cfg)
    sample = data.batch_at(0)
    spec = build_monitor_spec(arch, sample)

    runtime = scalpel.ScalpelRuntime(
        spec,
        config_path=loop_cfg.monitor_config_path,
        jsonl_path=loop_cfg.jsonl_path,
        hook_every=loop_cfg.hook_every,
    )
    timer = HostTimer()
    events: list[str] = []

    # fault-tolerance hooks driven by live counters
    def tripwire(rt, reports):
        for r in reports:
            for s in r.slots:
                if s.slot_id.startswith("NAN_COUNT") and s.raw > 0:
                    events.append(f"NaN detected in scope {r.scope}")
        bad = timer.outliers("train_step", loop_cfg.straggler_sigma)
        if bad:
            events.append(f"straggler steps (>{loop_cfg.straggler_sigma}σ): "
                          f"{bad[-3:]}")
        if on_report is not None:
            on_report(rt, reports)

    runtime.add_hook(tripwire)

    step_fn = make_train_step(arch, opt_cfg, spec,
                              microbatches=loop_cfg.microbatches)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    mgr = (CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.ckpt_keep)
           if loop_cfg.ckpt_dir else None)

    # -- init or restore (crash recovery / elastic resume) -----------------
    tstate = TrainState.create(arch, opt_cfg, spec,
                               jax.random.PRNGKey(loop_cfg.seed))
    start_step = 0
    if mgr is not None and mgr.latest() is not None:
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tstate
        )
        tstate, meta = mgr.restore(mgr.latest(), abstract)
        start_step = int(meta["step"])
        events.append(f"restored from step {start_step}")

    losses = []
    it = prefetch(
        (data.batch_at(s) for s in range(start_step, loop_cfg.steps)), 2
    )
    for step, host_batch in enumerate(it, start=start_step):
        batch = shard_batch(host_batch, mesh)
        t0 = time.perf_counter()
        tstate, out = jit_step(tstate, batch, runtime.params)
        jax.block_until_ready(out["loss"])
        timer.record("train_step", time.perf_counter() - t0)
        runtime.on_step(tstate.counters)
        losses.append(float(out["loss"]))
        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(out['grad_norm']):.3f} "
                  f"lr {float(out['lr']):.2e} "
                  f"dt {timer.stats('train_step').mean_s*1e3:.1f}ms")
        if mgr is not None and loop_cfg.ckpt_every and \
                (step + 1) % loop_cfg.ckpt_every == 0:
            mgr.save(step + 1, tstate)
    if mgr is not None:
        mgr.save(loop_cfg.steps, tstate, block=True)
        mgr.wait()

    return {
        "losses": losses,
        "final_loss": losses[-1] if losses else float("nan"),
        "step_stats": timer.stats("train_step"),
        "events": events,
        "report": runtime.report(),
        "runtime": runtime,
        "state": tstate,
        "spec": spec,
    }
