from .adamw import (  # noqa: F401
    OptConfig,
    OptState,
    apply_updates,
    global_norm,
    init_opt_state,
    lr_at,
    opt_state_axes,
)
