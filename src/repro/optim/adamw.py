"""AdamW with memory-tiered optimizer state — the distributed-optimization
substrate for the large archs.

State tiers (per-run choice, see configs):
  f32      — classic AdamW (m, v in fp32)
  int8     — block-quantized m/v (8-bit Adam): int8 payload + per-row fp32
             scales; ~4x optimizer-state memory reduction, the trick that
             fits the 100B+ archs in 16 GB/chip HBM budgets
  factored — Adafactor-style factored second moment (row/col accumulators)
             for >=2D leaves, fp32 m optional (usually disabled) — the tier
             used by arctic-480b

Master weights: when model params are bf16, an fp32 master copy lives in the
optimizer state (standard mixed-precision contract).  All state tensors
inherit the parameter's logical axes, so FSDP shards them identically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state: str = "f32"        # f32 | int8 | factored
    momentum: bool = True     # factored tier may drop momentum entirely
    master: bool = True       # keep fp32 master when params are low-precision


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    count: Any
    master: Any      # fp32 params or () when disabled
    m: Any           # momentum tree (quantized leaves are dicts) or ()
    v: Any           # second-moment tree (quantized/factored leaves differ)


# -- lr schedule -------------------------------------------------------------

def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    if cfg.warmup_steps <= 0:
        warm = 1.0
    else:
        warm = jnp.minimum(1.0, step / cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


# -- int8 block quantization --------------------------------------------------

def _quant(x):
    """fp32 -> {q: int8, s: fp32 row scales}, signed *quadratic* code.

    dequant = s * sign(q) * (q/127)^2 — resolution concentrates near zero,
    which second-moment tensors need (linear int8 rounds small v to 0 and
    the Adam step m/sqrt(v_hat) explodes).
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-30)
    r = x / s  # in [-1, 1]
    q = jnp.clip(
        jnp.round(jnp.sign(r) * jnp.sqrt(jnp.abs(r)) * 127.0), -127, 127
    ).astype(jnp.int8)
    return {"q": q, "s": s}


def _dequant(d):
    qf = d["q"].astype(jnp.float32) / 127.0
    return jnp.sign(qf) * jnp.square(qf) * d["s"]


def _is_quant(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "s"}


# -- factored second moment ----------------------------------------------------

def _factored_init(p):
    if p.ndim < 2:
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "vr": jnp.zeros(p.shape[:-1], jnp.float32),
        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
    }


def _is_factored(x) -> bool:
    return isinstance(x, dict) and set(x) == {"vr", "vc"}


# -- init ----------------------------------------------------------------------

def init_opt_state(cfg: OptConfig, params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.state == "int8":
        mk_m = lambda p: _quant(f32(p))
        mk_v = lambda p: _quant(f32(p))
    elif cfg.state == "factored":
        mk_m = lambda p: _quant(f32(p))  # momentum (if any) stays 8-bit
        mk_v = _factored_init
    else:
        mk_m = f32
        mk_v = f32
    master = (
        # copy=True: params may already be fp32 and astype would alias the
        # buffer, breaking donation (same buffer donated twice).
        jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
        if cfg.master else ()
    )
    m = jax.tree.map(mk_m, params) if cfg.momentum else ()
    v = jax.tree.map(mk_v, params)
    return OptState(count=jnp.zeros((), jnp.int32), master=master, m=m, v=v)


def opt_state_axes(cfg: OptConfig, axes_tree) -> OptState:
    """Logical-axes tree matching init_opt_state's structure (for sharding)."""
    def qaxes(a):
        return {"q": a, "s": tuple(a[:-1]) + (None,)}

    def faxes(a):
        if len(a) < 2:
            return a
        return {"vr": tuple(a[:-1]), "vc": tuple(a[:-2]) + (a[-1],)}

    if cfg.state == "int8":
        m_ax = jax.tree.map(qaxes, axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        v_ax = m_ax
    elif cfg.state == "factored":
        m_ax = jax.tree.map(qaxes, axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        v_ax = jax.tree.map(faxes, axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    else:
        m_ax = axes_tree
        v_ax = axes_tree
    return OptState(
        count=(),
        master=axes_tree if cfg.master else (),
        m=m_ax if cfg.momentum else (),
        v=v_ax,
    )


# -- update ---------------------------------------------------------------------

def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)) + 1e-30
    )


def apply_updates(cfg: OptConfig, state: OptState, params, grads):
    """One AdamW step. Returns (new_params, new_state, stats)."""
    count = state.count + 1
    t = count.astype(jnp.float32)
    lr = lr_at(cfg, state.count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    masters = state.master if cfg.master else params
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, p_master, m, v):
        # per-leaf fp32 cast: never materialize a full fp32 gradient tree
        # (matters for the 100B+ archs where grads arrive in bf16)
        g = g.astype(jnp.float32) * scale
        if _is_quant(m):
            m_f = _dequant(m)
        else:
            m_f = m
        if cfg.momentum:
            m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
            m_hat = m_f / bc1
        else:
            m_hat = g
        if _is_factored(v):
            vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * jnp.mean(
                jnp.square(g), axis=-1
            )
            vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * jnp.mean(
                jnp.square(g), axis=-2
            )
            denom_sq = (
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1)[..., None, None], 1e-30)
            )
            v_hat = denom_sq / bc2
            new_v = {"vr": vr, "vc": vc}
        else:
            v_f = _dequant(v) if _is_quant(v) else v
            v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
            v_hat = v_f / bc2
            new_v = _quant(v_f) if _is_quant(v) else v_f
        step_ = lr * (m_hat / (jnp.sqrt(v_hat) + cfg.eps)
                      + cfg.weight_decay * p_master)
        new_master = p_master - step_
        new_m = (_quant(m_f) if _is_quant(m) else m_f) if cfg.momentum else m
        return new_master, new_m, new_v

    triples = jax.tree.map(
        upd, grads, masters,
        state.m if cfg.momentum else grads,  # placeholder, unused w/o momentum
        state.v,
        is_leaf=lambda x: _is_quant(x) or _is_factored(x),
    )
    # unzip the 3-tuples
    flat, treedef = jax.tree.flatten(
        triples, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and not isinstance(x[0], tuple)
    )
    new_master = jax.tree.unflatten(treedef, [x[0] for x in flat])
    new_m = jax.tree.unflatten(treedef, [x[1] for x in flat]) \
        if cfg.momentum else ()
    new_v = jax.tree.unflatten(treedef, [x[2] for x in flat])

    pd = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.map(lambda mm: mm.astype(pd), new_master)
    new_state = OptState(
        count=count,
        master=new_master if cfg.master else (),
        m=new_m,
        v=new_v,
    )
    stats = {"grad_norm": gnorm, "lr": lr, "clip_scale": scale}
    return new_params, new_state, stats
