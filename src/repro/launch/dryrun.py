"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any jax import (jax locks the device count on
first init) — hence the first two lines below.

For each cell this:
  1. builds the exact assigned ModelConfig + the cell's execution policy,
  2. constructs abstract inputs (ShapeDtypeStruct — no allocation) and
     NamedShardings from the logical-axes trees,
  3. jit(step).lower(...).compile() under the production mesh,
  4. records memory_analysis / cost_analysis / parsed collective traffic
     into a JSON record (the roofline source; EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out experiments/dryrun
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro import configs  # noqa: E402
from repro import core as scalpel  # noqa: E402
from repro.core.backends import hlo_graph, xla_cost  # noqa: E402
from repro.dist.partition import (  # noqa: E402
    sharding_ctx,
    tree_shardings,
)
from repro.launch.mesh import describe, make_production_mesh  # noqa: E402
from repro.models import SHAPES, Arch  # noqa: E402
from repro.optim import OptConfig, init_opt_state, opt_state_axes  # noqa: E402
from repro.train.step import TrainState, build_monitor_spec, make_train_step  # noqa: E402


def _replicated(tree, mesh):
    return jax.tree.map(
        lambda _: NamedSharding(mesh, PartitionSpec()), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _abstractify(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), tree
    )


def _opt_cfg(policy: dict) -> OptConfig:
    return OptConfig(
        state=policy.get("opt_state", "f32"),
        momentum=policy.get("opt_momentum", True),
        master=policy.get("opt_master", True),
    )


def build_cell(arch_id: str, shape_name: str, multi_pod: bool,
               monitor: str = "all", policy_overrides: dict | None = None):
    """Returns (fn, abstract_args, in_shardings, donate, meta)."""
    shape = SHAPES[shape_name]
    policy = configs.cell_policy(arch_id, shape_name)
    policy.update(policy_overrides or {})
    overrides = dict(policy.get("model_overrides", {}))
    cfg = configs.model_config(arch_id, **overrides)
    arch = Arch(cfg)

    ok, why = arch.supports(shape)
    if not ok:
        raise SkipCell(why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    batch = arch.input_specs(shape)
    tensor_events = () if monitor == "none" else ("ACT_RMS",)

    with mesh, sharding_ctx(mesh):
        params_abs = arch.abstract_params()
        params_sh = tree_shardings(params_abs, arch.param_axes(), mesh)
        batch_sh = {
            k: tree_shardings(
                {"x": v}, {"x": ("batch",) + (None,) * (v.ndim - 1)}, mesh
            )["x"]
            for k, v in batch.items()
        }

        if shape.kind == "train":
            spec = build_monitor_spec(arch, batch,
                                      tensor_events=tensor_events)
            opt_cfg = _opt_cfg(policy)
            opt_abs = jax.eval_shape(
                lambda p: init_opt_state(opt_cfg, p), params_abs
            )
            opt_sh = tree_shardings(
                opt_abs, opt_state_axes(opt_cfg, arch.param_axes()), mesh
            )
            tstate_abs = TrainState(
                params=params_abs, opt=opt_abs,
                step=jax.ShapeDtypeStruct((), jnp.int32),
            )
            tstate_sh = TrainState(
                params=params_sh, opt=opt_sh,
                step=NamedSharding(mesh, PartitionSpec()),
            )
            mon = scalpel.Monitor(spec)
            mstate_abs = _abstractify(mon.init())
            step_fn = make_train_step(
                arch, opt_cfg, spec,
                microbatches=policy.get("microbatches", 1),
                monitor=mon,
            )
            args = (tstate_abs, batch, mstate_abs)
            shardings = (tstate_sh, batch_sh, _replicated(mstate_abs, mesh))
            donate = (0,)
            fn = step_fn
        elif shape.kind == "prefill":
            def probe_fn(p, b):
                return arch.prefill(p, b, cache_len=shape.seq_len)

            seen = scalpel.discover(probe_fn, params_abs, batch)
            spec = scalpel.spec_from_discovery(seen,
                                               tensor_events=tensor_events)
            mon = scalpel.Monitor(spec)
            mstate_abs = _abstractify(mon.init())
            fn = mon.wrap(
                lambda params, b: arch.prefill(params, b,
                                               cache_len=shape.seq_len)
            )
            args = (mstate_abs, params_abs, batch)
            shardings = (_replicated(mstate_abs, mesh), params_sh, batch_sh)
            donate = ()
        else:  # decode
            cache_abs = arch.init_cache(shape.global_batch, shape.seq_len,
                                        abstract=True)
            cache_sh = tree_shardings(cache_abs, arch.cache_axes(), mesh)
            tokens = batch["tokens"]

            def probe_fn(p, c, t):
                return arch.decode_step(p, c, t)

            seen = scalpel.discover(probe_fn, params_abs, cache_abs, tokens)
            spec = scalpel.spec_from_discovery(seen,
                                               tensor_events=tensor_events)
            mon = scalpel.Monitor(spec)
            mstate_abs = _abstractify(mon.init())
            fn = mon.wrap(lambda params, cache, t:
                          arch.decode_step(params, cache, t))
            args = (mstate_abs, params_abs, cache_abs, tokens)
            shardings = (_replicated(mstate_abs, mesh), params_sh, cache_sh,
                         batch_sh["tokens"])
            donate = (2,)

    meta = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "kind": shape.kind,
        "n_params": arch.n_params(),
        "policy": {k: v for k, v in policy.items() if k != "model_overrides"},
        "model_overrides": overrides,
        "monitor": monitor,
        "scopes": list(spec.scopes),
    }
    return fn, args, shardings, donate, mesh, meta


class SkipCell(Exception):
    pass


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             monitor: str = "all", policy_overrides: dict | None = None,
             keep_hlo: bool = False) -> dict:
    t0 = time.time()
    fn, args, shardings, donate, mesh, meta = build_cell(
        arch_id, shape_name, multi_pod, monitor, policy_overrides
    )
    with mesh, sharding_ctx(mesh):
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    report = xla_cost.analyze(
        compiled, default_group=meta["n_devices"],
        scopes=tuple(meta["scopes"]), hlo_text=hlo_text,
    )
    # while-loop-aware graph costing (cost_analysis counts loop bodies once;
    # scan-over-layers would underreport by ~n_layers without this)
    graph = hlo_graph.analyze_text(hlo_text, default_group=meta["n_devices"])
    mem = report.memory_analysis or {}
    record = dict(
        meta,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops=report.flops,
        bytes_accessed=report.bytes_accessed,
        transcendentals=report.transcendentals,
        collective_link_bytes=report.collective_link_bytes,
        collective_payload_bytes=report.collective_payload_bytes,
        collectives_by_kind=report.collective_bytes_by_kind(),
        n_collectives=len(report.collectives),
        memory=mem,
        hlo_graph=graph,
    )
    if keep_hlo:
        record["hlo_collective_lines"] = [
            f"{c.kind} g{c.group_size} {c.link_bytes:.3e}B {c.scope}"
            for c in report.collectives[:2000]
        ]
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--monitor", default="all", choices=["all", "none"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if args.arch == "all" else [
        configs.canonical(a) for a in args.arch.split(",")
    ]
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch_id in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch_id}__{shape_name}__{'multi' if multi else 'single'}"
                if args.monitor != "all":
                    tag += f"__mon-{args.monitor}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {tag}")
                    continue
                print(f"[lower] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch_id, shape_name, multi,
                                   monitor=args.monitor,
                                   keep_hlo=args.keep_hlo)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    m = rec["memory"]
                    print(
                        f"[ok] {tag}: compile {rec['compile_s']}s "
                        f"flops {rec['flops']:.3e} "
                        f"coll {rec['collective_link_bytes']:.3e}B "
                        f"temp {m.get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
                        flush=True,
                    )
                    n_ok += 1
                except SkipCell as e:
                    with open(path, "w") as f:
                        json.dump({"arch": arch_id, "shape": shape_name,
                                   "mesh": "2x16x16" if multi else "16x16",
                                   "skipped": str(e)}, f, indent=1)
                    print(f"[skip] {tag}: {e}")
                    n_skip += 1
                except Exception:
                    n_fail += 1
                    print(f"[FAIL] {tag}:\n{traceback.format_exc()}",
                          flush=True)
    print(f"dry-run done: {n_ok} ok, {n_skip} skipped-by-design, "
          f"{n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
