"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.

Mesh axes:
  data  — batch / FSDP axis (16-way per pod)
  model — TP / vocab / expert axis (16-way, maps to the high-bandwidth ring)
  pod   — pod super-axis (pure DP across pods; gradient all-reduce crosses it)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    if data is None:
        data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def describe(mesh) -> str:
    return (
        f"mesh {dict(mesh.shape)} on {mesh.devices.size} devices "
        f"({mesh.devices.flat[0].platform})"
    )
