"""Distribution / SPMD helpers: logical-axis partitioning over named meshes."""
from .partition import (  # noqa: F401
    DEFAULT_RULES,
    axis_size,
    bound_axes,
    counter_reduce_axes,
    current_mesh,
    input_sharding,
    logical_to_pspec,
    relaxed_pspec,
    shard,
    sharding_ctx,
    tree_shardings,
)
