"""Logical-axis partitioning rules and relaxation.

Model code names tensor dimensions with *logical* axes ("batch", "heads",
"mlp", ...); this module maps them onto whatever *mesh* axes exist at run
time ("pod", "data", "model") and relaxes any mapping the current mesh or
tensor shape cannot honour:

* a logical axis whose mesh axes are absent from the mesh falls back to
  replicated (None) — the same model code runs on a laptop mesh and the
  16x16 production mesh;
* a mesh axis may appear at most once in a PartitionSpec, so duplicate
  claims (e.g. "batch" and "embed" both wanting "data") keep the first
  occurrence and replicate the rest;
* ``relaxed_pspec`` additionally drops mesh axes whose size does not divide
  the dimension (heads that don't divide the TP axis, ragged vocab, ...).

``sharding_ctx(mesh)`` installs the ambient mesh; with no ambient mesh every
helper is a no-op so uninstrumented / single-device code pays nothing.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

# Logical axis -> candidate mesh axes, in order.  Mirrors the production
# mesh of launch/mesh.py: "data" is the batch/FSDP axis, "model" the
# TP/vocab/expert axis, "pod" a pure-DP super-axis.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "groups": ("pod", "data"),
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_seq": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    # continuous-batching serve: the decode-lane slab axis (serve/driver.py
    # shard_maps its programs over a dedicated 1-D "lanes" mesh)
    "lanes": ("lanes",),
}

_TLS = threading.local()


def current_mesh():
    """The ambient mesh installed by ``sharding_ctx`` (None outside)."""
    return getattr(_TLS, "mesh", None)


@contextlib.contextmanager
def sharding_ctx(mesh, rules: Mapping[str, tuple[str, ...]] | None = None):
    """Install ``mesh`` (and optional rule overrides) as the ambient context."""
    prev = (getattr(_TLS, "mesh", None), getattr(_TLS, "rules", None))
    _TLS.mesh = mesh
    _TLS.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield mesh
    finally:
        _TLS.mesh, _TLS.rules = prev


def _rules(rules=None) -> Mapping[str, tuple[str, ...]]:
    if rules is not None:
        return {**DEFAULT_RULES, **rules}
    return getattr(_TLS, "rules", None) or DEFAULT_RULES


def _entry(axis, mesh, rules, used: set) -> Any:
    """Resolve one logical axis to a PartitionSpec entry on ``mesh``."""
    if axis is None:
        return None
    cands = rules.get(axis, (axis,) if axis in mesh.shape else ())
    picked = [a for a in cands if a in mesh.shape and a not in used]
    used.update(picked)
    if not picked:
        return None
    if len(picked) == 1:
        return picked[0]
    return tuple(picked)


def logical_to_pspec(axes: Sequence[str | None], mesh=None,
                     rules=None) -> PartitionSpec:
    """Map logical axis names to a PartitionSpec for ``mesh``.

    With no mesh (argument or ambient) the result is the empty spec —
    fully replicated, usable anywhere.
    """
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return PartitionSpec()
    rules = _rules(rules)
    used: set = set()
    return PartitionSpec(*(_entry(a, mesh, rules, used) for a in axes))


def relaxed_pspec(shape: Sequence[int], axes: Sequence[str | None], mesh,
                  rules=None) -> PartitionSpec:
    """Like ``logical_to_pspec`` but drops mesh axes that don't divide the dim.

    The relaxation the models rely on: a 5-head attention on a 4-way TP mesh
    falls back to replicated heads instead of erroring.
    """
    rules = _rules(rules)
    used: set = set()
    entries = []
    for dim, axis in zip(shape, axes):
        e = _entry(axis, mesh, rules, used)
        if e is not None:
            names = (e,) if isinstance(e, str) else e
            total = math.prod(mesh.shape[n] for n in names)
            if total == 0 or dim % total != 0:
                used.difference_update(names)
                e = None
        entries.append(e)
    return PartitionSpec(*entries)


def shard(x, *axes, rules=None):
    """Constrain ``x`` to its logical sharding under the ambient mesh.

    Outside any ``sharding_ctx`` this returns ``x`` unchanged (identity, not
    a copy) so single-device code pays nothing.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    ps = relaxed_pspec(x.shape, axes, mesh, rules=rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))


def input_sharding(shape: Sequence[int], axes: Sequence[str | None],
                   mesh=None, rules=None) -> NamedSharding:
    """NamedSharding for a host->device input of ``shape``."""
    mesh = mesh if mesh is not None else current_mesh()
    return NamedSharding(mesh, relaxed_pspec(shape, axes, mesh, rules=rules))


def tree_shardings(abs_tree, ax_tree, mesh=None, rules=None):
    """Per-leaf NamedShardings for a tree of ShapeDtypeStructs.

    ``ax_tree`` mirrors ``abs_tree`` with tuples of logical axis names at the
    leaves (tuples are leaves here, not pytree nodes).
    """
    mesh = mesh if mesh is not None else current_mesh()
    leaves, treedef = jax.tree_util.tree_flatten(abs_tree)
    ax_leaves = treedef.flatten_up_to(ax_tree)
    shs = [
        NamedSharding(mesh, relaxed_pspec(l.shape, ax, mesh, rules=rules))
        for l, ax in zip(leaves, ax_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, shs)


def lane_mesh(n_shards: int, axis: str = "lanes"):
    """A 1-D device mesh for lane-parallel serving (the decode slab's
    ``lanes`` axis).

    Unlike the training mesh (launch/mesh.py), a serve mesh may use a
    strict SUBSET of the local devices — a 2-way lane mesh on an 8-device
    host leaves the rest to other engines — so this builds ``jax.Mesh``
    directly from the first ``n_shards`` devices rather than going through
    ``make_mesh`` (which wants them all).
    """
    if n_shards < 1:
        raise ValueError(f"lane mesh needs >= 1 shard, got {n_shards}")
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"lane mesh needs {n_shards} devices, have {len(devs)} — "
            f"reduce ServeConfig.lane_shards (or force host devices via "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), (axis,))


def axis_size(name: str) -> int:
    """Size of mesh axis ``name`` in the ambient mesh (1 outside any ctx)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    return int(mesh.shape.get(name, 1))


def bound_axes(names: Sequence[str]) -> tuple[str, ...]:
    """The subset of ``names`` bound as *mapped* axes in the current trace.

    A mesh axis name is only psum-able from code that runs under a
    ``shard_map``/``pmap`` binding it; under plain jit-SPMD (sharded inputs,
    no per-shard body) reductions are already global and no axis is bound.
    Call this at trace time, where a psum would be issued.
    """
    out = []
    for n in names:
        try:
            jax.lax.axis_index(n)
        except NameError:
            continue
        out.append(n)
    return tuple(out)


def counter_reduce_axes(axes="auto") -> tuple[str, ...]:
    """Resolve the mesh axes a monitor should psum counters over.

    ``"auto"``: every axis of the ambient ``sharding_ctx`` mesh that is
    actually bound in the current trace — replicated-safe on a laptop
    (no mesh, or a 1-device mesh, or plain jit: nothing to reduce).
    An explicit tuple is filtered the same way, so the same wrapped step
    traces correctly inside and outside ``shard_map``.
    """
    if axes is None:
        return ()
    if axes == "auto":
        mesh = current_mesh()
        cands: tuple[str, ...] = tuple(mesh.axis_names) if mesh is not None \
            else ()
    else:
        cands = tuple(axes)
    return bound_axes(cands)
