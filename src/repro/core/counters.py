"""Counter state and runtime monitor parameters (the dynamic half).

``CounterState`` is the accumulated counter memory — an ordinary pytree of
device arrays that the application threads through its steps (and that
``lax.scan`` can carry).  ``MonitorParams`` is the runtime-reconfigurable
knob set: which scopes are monitored (mask), which slots within a scope are
live (slot_mask) and the call-count multiplex period — all *dynamic* inputs
to the jitted step, so flipping them never re-traces (paper C2/C3).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .context import MonitorSpec

Array = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CounterState:
    """Accumulated counters, shaped by the compile-time MonitorSpec.

    calls   [n_scopes]            i32 — times each scope was *intercepted*
    values  [n_scopes, max_slots] f32 — accumulated event values
    samples [n_scopes, max_slots] i32 — calls on which each slot was computed
    """

    calls: Array
    values: Array
    samples: Array

    @staticmethod
    def zeros(spec: MonitorSpec) -> "CounterState":
        n, m = spec.n_scopes, spec.max_slots
        return CounterState(
            calls=jnp.zeros((n,), jnp.int32),
            values=jnp.zeros((n, m), jnp.float32),
            samples=jnp.zeros((n, m), jnp.int32),
        )

    def add(self, other: "CounterState") -> "CounterState":
        return CounterState(
            calls=self.calls + other.calls,
            values=self.values + other.values,
            samples=self.samples + other.samples,
        )

    def sub(self, other: "CounterState") -> "CounterState":
        """Delta-decode: counters accumulated since ``other`` (elementwise).

        Works on device arrays and on host numpy trees alike — the telemetry
        plane uses it to turn consecutive cumulative ring snapshots into
        per-interval increments.
        """
        return CounterState(
            calls=self.calls - other.calls,
            values=self.values - other.values,
            samples=self.samples - other.samples,
        )

    def psum(self, axis_names) -> "CounterState":
        """Cross-shard reduction (the paper's 'MPI support')."""
        return CounterState(
            calls=jax.lax.psum(self.calls, axis_names),
            values=jax.lax.psum(self.values, axis_names),
            samples=jax.lax.psum(self.samples, axis_names),
        )

    # -- the padded block is a VIEW over the compact dense layout ---------
    # (the Monitor API threads counters compactly end-to-end; these
    # conversions are the interop seam for code that still wants the
    # [n_scopes, max_slots] block)
    def compact(self, spec: MonitorSpec):
        """Gather into the spec-wide dense layout (plan.CompactDelta)."""
        from . import plan as plan_lib

        return plan_lib.CompactDelta.compress(spec, self)

    @staticmethod
    def from_compact(spec: MonitorSpec, compact) -> "CounterState":
        """Expand a compact carrier (CompactDelta / MonitorState counters)
        back into the padded-block view."""
        from . import plan as plan_lib

        if not isinstance(compact, plan_lib.CompactDelta):
            compact = plan_lib.CompactDelta(
                calls=compact.calls, values=compact.values,
                samples=compact.samples,
            )
        return compact.expand(spec)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MonitorParams:
    """Runtime-mutable monitoring controls (no re-trace on change).

    scope_mask [n_scopes]            f32 — 1.0: monitor, 0.0: intercept only
    slot_mask  [n_scopes, max_slots] f32 — per-slot enable within a scope
    period     [n_scopes]            i32 — multiplex period (calls per set)
    """

    scope_mask: Array
    slot_mask: Array
    period: Array

    @staticmethod
    def all_on(spec: MonitorSpec) -> "MonitorParams":
        n, m = spec.n_scopes, spec.max_slots
        period = np.array(
            [max(1, c.default_period) for c in spec.contexts], np.int32
        )
        return MonitorParams(
            scope_mask=jnp.ones((n,), jnp.float32),
            slot_mask=jnp.ones((n, m), jnp.float32),
            period=jnp.asarray(period),
        )

    @staticmethod
    def all_off(spec: MonitorSpec) -> "MonitorParams":
        p = MonitorParams.all_on(spec)
        return MonitorParams(
            scope_mask=jnp.zeros_like(p.scope_mask),
            slot_mask=p.slot_mask,
            period=p.period,
        )

    @staticmethod
    def selective(spec: MonitorSpec, scopes: list[str]) -> "MonitorParams":
        """Monitor only the named scopes (the paper's 'selective' case)."""
        p = MonitorParams.all_off(spec)
        mask = np.zeros((spec.n_scopes,), np.float32)
        for s in scopes:
            mask[spec.scope_index(s)] = 1.0
        return MonitorParams(
            scope_mask=jnp.asarray(mask), slot_mask=p.slot_mask, period=p.period
        )

    # -- functional updates (host side, between steps) -------------------
    def enable(self, spec: MonitorSpec, scope: str, on: bool = True):
        mask = np.asarray(self.scope_mask).copy()
        mask[spec.scope_index(scope)] = 1.0 if on else 0.0
        return dataclasses.replace(self, scope_mask=jnp.asarray(mask))

    def set_slot(self, spec: MonitorSpec, scope: str, slot_id: str, on: bool):
        sm = np.asarray(self.slot_mask).copy()
        sm[spec.scope_index(scope), spec.slot_index(scope, slot_id)] = (
            1.0 if on else 0.0
        )
        return dataclasses.replace(self, slot_mask=jnp.asarray(sm))

    def set_period(self, spec: MonitorSpec, scope: str, period: int):
        p = np.asarray(self.period).copy()
        p[spec.scope_index(scope)] = max(1, int(period))
        return dataclasses.replace(self, period=jnp.asarray(p))
