"""Probe-plan compiler — per-(scope, event set) moment plans, spec → kernel.

ScALPEL's core claim is *selective* monitoring: the active event set of a
function changes at run time, yet the monitored path should only pay for
what that set needs.  Before this layer the probe path computed the UNION
of raw moments across every event set inside every ``lax.switch`` branch —
a sparse active set (say ACT_MAX_ABS alone) still swept six channels over
the tensor.  The same per-function-selectivity discipline LIKWID and Scaler
apply to keep always-on monitoring cheap applies here: compile, per (scope,
event set), exactly the work that set performs.

Compiled artifacts (all static / trace-time, cached on the hashable frozen
context objects):

* ``MomentPlan`` — one per (scope context, available probe tensors, event
  set): which slots are live, which finalize from the shared channel sweep
  (and from which probe tensor), which run their bespoke ``fn``, and the
  EXACT per-tensor channel tuples to sweep — including the optional
  ``ent_sum`` entropy channel that folds ATTN_ENTROPY into the same pass.
* ``ScopePlans`` — the per-scope bundle of MomentPlans plus the scope's slot
  width (the dense vector a probe branch scatters into).
* ``SlotLayout`` — the spec-wide dense slot→scatter layout: each scope's
  slots packed contiguously into one flat vector of ``total`` live slots.
  ``CompactDelta`` rides this layout through ``lax.scan`` carries so stacked
  layers sum ``total`` lanes per iteration instead of a padded
  ``[n_scopes, max_slots]`` block, and expands to a full ``CounterState``
  once at region exit.
* ``spec_fingerprint`` — a stable hash over the compiled plans; part of the
  spec's identity so reports/telemetry can attest which plan produced a
  counter stream, and config hot-swaps (mask/period changes — dynamic
  inputs) demonstrably leave it, and the traced graph, untouched.

``union=True`` compiles the pre-plan behaviour (every set sweeps the union
of channels across all sets) — kept as the benchmark baseline
(benchmarks/overhead.py ``run_plan_sweep``), not a supported hot path.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from . import events as events_lib
from .context import MonitorSpec, ScopeContext
from .counters import CounterState


@dataclasses.dataclass(frozen=True)
class PlanSlot:
    """One live slot of a plan: where it scatters and how it is evaluated.

    ``tensor``: the probe tensor a fused slot finalizes from ("" for bespoke
    slots, which receive the full probe-tensor dict).
    """

    index: int          # slot index within the scope context
    tensor: str
    fused: bool         # True: finalizer over the channel sweep


@dataclasses.dataclass(frozen=True)
class TensorSweep:
    """One probed tensor and the exact channels this event set sweeps."""

    tensor: str
    channels: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class MomentPlan:
    """The compiled probe work of ONE (scope, event set) pair."""

    scope: str
    set_index: int
    slots: tuple[PlanSlot, ...]     # live slots, ascending index
    sweeps: tuple[TensorSweep, ...]  # per-tensor exact channel requirements

    @property
    def members(self) -> tuple[int, ...]:
        return tuple(s.index for s in self.slots)

    @property
    def sweep_channel_count(self) -> int:
        """Data-pass channels this set pays for (static channels are free)."""
        return sum(
            1 for sw in self.sweeps for c in sw.channels
            if c in events_lib.SWEEP_CHANNELS
        )

    def describe(self) -> str:
        slots = ", ".join(
            ("~" if not s.fused else "") + str(s.index) for s in self.slots
        )
        sweeps = "; ".join(
            f"{sw.tensor or '<probe>'}:[{','.join(sw.channels)}]"
            for sw in self.sweeps
        )
        return f"set {self.set_index}: slots [{slots}] sweeps {sweeps or '-'}"


@dataclasses.dataclass(frozen=True)
class ScopePlans:
    """Per-scope bundle: one MomentPlan per event set + the branch width.

    ``bodies``/``branch_index`` carry the *deduplicated* branch table: sets
    whose plans perform identical work — same slot events in the same order,
    same exact channel sweeps — share ONE ``lax.switch`` branch body; only
    the scatter footprint (the member indices) differs between them and is
    threaded through the switch as data.  Compile time per scope grows with
    ``n_branches``, not ``n_sets``.
    """

    scope: str
    width: int                      # len(ctx.slots): the branch vector width
    plans: tuple[MomentPlan, ...]
    # dedup table: branch_index[k] names the body plan set k executes
    bodies: tuple[MomentPlan, ...] = ()
    branch_index: tuple[int, ...] = ()

    @property
    def n_sets(self) -> int:
        return len(self.plans)

    @property
    def n_branches(self) -> int:
        return len(self.bodies)

    @property
    def plans_deduped(self) -> int:
        """Event sets that reuse another set's branch body."""
        return self.n_sets - self.n_branches

    @property
    def any_live(self) -> bool:
        return any(p.slots for p in self.plans)

    @property
    def member_table(self) -> tuple[tuple[int, ...], ...]:
        """Per-set member indices, zero-padded to the widest set.

        The dynamic operand of the deduped switch: a shared branch body
        reads its set's scatter indices from this table instead of baking
        them in (``midx[:len(body.slots)]`` — the count is static per body).
        """
        w = max((len(p.members) for p in self.plans), default=0)
        return tuple(
            p.members + (0,) * (w - len(p.members)) for p in self.plans
        )


def _bind_tensor(spec, avail: frozenset | None) -> str:
    """The probe tensor a per-tensor slot binds to (static describe mode
    binds unqualified slots to the anonymous '<probe>' tensor '')."""
    if spec.tensor:
        return spec.tensor
    if avail is None:
        return ""
    (name,) = tuple(avail)
    return name


@functools.lru_cache(maxsize=None)
def compile_scope_plans(
    ctx: ScopeContext, avail: frozenset | None = None, union: bool = False
) -> ScopePlans:
    """Compile one MomentPlan per event set of ``ctx``.

    ``avail``: the probe tensor names this probe call provides (a scope may
    probe several times per invocation with different tensors; only the
    slots those tensors satisfy are live).  ``None`` = static mode: assume
    every slot computable — used for fingerprints and description, where no
    concrete probe call exists.

    ``union=True`` widens every set's sweeps to the union of channels over
    ALL sets (the pre-plan behaviour, kept as a benchmark baseline).
    """
    def live(i) -> bool:
        if avail is None:
            return True
        return events_lib.computable(ctx.slots[i], avail)

    # per-tensor channel union across ALL sets (the baseline's sweep)
    union_channels: dict[str, tuple[str, ...]] = {}
    if union:
        by_tensor: dict[str, list] = {}
        for i, s in enumerate(ctx.slots):
            if live(i) and events_lib.moment_based(s):
                by_tensor.setdefault(_bind_tensor(s, avail), []).append(s)
        union_channels = {
            t: events_lib.channels_for(ss) for t, ss in by_tensor.items()
        }

    plans = []
    for k, members in enumerate(ctx.event_sets):
        slots: list[PlanSlot] = []
        set_by_tensor: dict[str, list] = {}
        for i in sorted(members):
            if not live(i):
                continue
            s = ctx.slots[i]
            if events_lib.moment_based(s):
                t = _bind_tensor(s, avail)
                slots.append(PlanSlot(index=i, tensor=t, fused=True))
                set_by_tensor.setdefault(t, []).append(s)
            else:
                slots.append(PlanSlot(index=i, tensor="", fused=False))
        sweeps = tuple(
            TensorSweep(
                tensor=t,
                channels=(
                    union_channels[t] if union
                    else events_lib.channels_for(ss)
                ),
            )
            for t, ss in sorted(set_by_tensor.items())
        )
        plans.append(
            MomentPlan(scope=ctx.scope, set_index=k, slots=tuple(slots),
                       sweeps=sweeps)
        )
    # Dedup: two sets share a branch body iff they evaluate the same events
    # over the same tensors with the same exact sweeps — everything except
    # WHERE the results scatter, which the switch receives as data.
    bodies: list[MomentPlan] = []
    body_of: dict = {}
    branch_index: list[int] = []
    for p in plans:
        key = (
            tuple((ctx.slots[s.index], s.tensor, s.fused) for s in p.slots),
            p.sweeps,
        )
        j = body_of.get(key)
        if j is None:
            j = len(bodies)
            body_of[key] = j
            bodies.append(p)
        branch_index.append(j)
    return ScopePlans(
        scope=ctx.scope, width=max(1, len(ctx.slots)), plans=tuple(plans),
        bodies=tuple(bodies), branch_index=tuple(branch_index),
    )


# ---------------------------------------------------------------------------
# Spec-wide dense slot layout + compact scan-carry counters
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SlotLayout:
    """Dense slot→scatter layout of a MonitorSpec.

    Scope ``i``'s slots occupy ``[offsets[i], offsets[i] + widths[i])`` of a
    flat ``total``-lane vector — the live-slot footprint a scan carry sums
    per iteration, instead of the padded ``[n_scopes, max_slots]`` block.
    """

    offsets: tuple[int, ...]
    widths: tuple[int, ...]
    total: int

    @functools.cached_property
    def scatter_indices(self) -> tuple[np.ndarray, np.ndarray]:
        """(scope_ids, slot_ids) mapping flat lanes to [n_scopes, max_slots]."""
        scope_ids = np.concatenate(
            [np.full((w,), i, np.int32) for i, w in enumerate(self.widths)]
        ) if self.total else np.zeros((0,), np.int32)
        slot_ids = np.concatenate(
            [np.arange(w, dtype=np.int32) for w in self.widths]
        ) if self.total else np.zeros((0,), np.int32)
        return scope_ids, slot_ids


@functools.lru_cache(maxsize=None)
def spec_layout(spec: MonitorSpec) -> SlotLayout:
    """The spec's dense lane layout.

    **Lane ordering is a wire contract**: lanes run in ``spec.contexts``
    declaration order, each scope contributing its slots in ``ctx.slots``
    order.  The fleet wire format (repro.telemetry.wire) ships flat
    ``CompactDelta`` payloads in exactly this order and identifies the
    producing layout by ``spec_fingerprint`` — any change to this ordering
    is a wire-format break and must change the fingerprint (it does: the
    fingerprint hashes ``describe_plans``, which walks the same order).
    """
    widths = tuple(len(c.slots) for c in spec.contexts)
    offsets, off = [], 0
    for w in widths:
        offsets.append(off)
        off += w
    return SlotLayout(offsets=tuple(offsets), widths=widths, total=off)


@functools.lru_cache(maxsize=None)
def lane_slot_ids(spec: MonitorSpec) -> tuple[tuple[str, str], ...]:
    """Per flat lane, the (scope, slot_id) it carries — the human-readable
    side of the wire contract above.  ``lane_slot_ids(spec)[i]`` labels
    lane ``i`` of any ``CompactDelta``/wire frame produced under ``spec``.
    """
    out = []
    for ctx in spec.contexts:
        for slot in ctx.slots:
            out.append((ctx.scope, slot.slot_id))
    return tuple(out)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompactDelta:
    """Counter delta in the dense slot layout — the scan-carry form.

    calls    [n_scopes]  i32
    values   [total]     f32  (SlotLayout order)
    samples  [total]     i32
    """

    calls: jnp.ndarray
    values: jnp.ndarray
    samples: jnp.ndarray

    @staticmethod
    def zeros(spec: MonitorSpec) -> "CompactDelta":
        lay = spec_layout(spec)
        return CompactDelta(
            calls=jnp.zeros((spec.n_scopes,), jnp.int32),
            values=jnp.zeros((lay.total,), jnp.float32),
            samples=jnp.zeros((lay.total,), jnp.int32),
        )

    def add(self, other: "CompactDelta") -> "CompactDelta":
        return CompactDelta(
            calls=self.calls + other.calls,
            values=self.values + other.values,
            samples=self.samples + other.samples,
        )

    def sub(self, other: "CompactDelta") -> "CompactDelta":
        """Delta-decode (telemetry): counters accumulated since ``other``."""
        return CompactDelta(
            calls=self.calls - other.calls,
            values=self.values - other.values,
            samples=self.samples - other.samples,
        )

    def psum(self, axis_names) -> "CompactDelta":
        """Cross-shard reduction over mapped mesh axes (shard_map/pmap)."""
        return CompactDelta(
            calls=jax.lax.psum(self.calls, axis_names),
            values=jax.lax.psum(self.values, axis_names),
            samples=jax.lax.psum(self.samples, axis_names),
        )

    def expand(self, spec: MonitorSpec) -> CounterState:
        """Scatter the flat footprint back into a full CounterState."""
        lay = spec_layout(spec)
        n, m = spec.n_scopes, spec.max_slots
        values = jnp.zeros((n, m), jnp.float32)
        samples = jnp.zeros((n, m), jnp.int32)
        if lay.total:
            sids, slids = lay.scatter_indices
            values = values.at[sids, slids].set(self.values)
            samples = samples.at[sids, slids].set(self.samples)
        return CounterState(calls=self.calls, values=values, samples=samples)

    @staticmethod
    def compress(spec: MonitorSpec, state: CounterState) -> "CompactDelta":
        """Gather a full CounterState into the dense layout (one gather)."""
        lay = spec_layout(spec)
        if not lay.total:
            return CompactDelta(
                calls=state.calls,
                values=jnp.zeros((0,), jnp.float32),
                samples=jnp.zeros((0,), jnp.int32),
            )
        sids, slids = lay.scatter_indices
        return CompactDelta(
            calls=state.calls,
            values=state.values[sids, slids],
            samples=state.samples[sids, slids],
        )


# ---------------------------------------------------------------------------
# Sentinel sets — the compiled detector-lane table of the adaptive ladder
# ---------------------------------------------------------------------------

# detector kinds the adaptive controller runs over drained deltas
DETECT_TRIPWIRE = "tripwire"    # any positive delta trips (NaN/Inf counts)
DETECT_SPIKE = "spike"          # |x - EWMA| > sigma * MAD (zero fractions)
DETECT_COLLAPSE = "collapse"    # EWMA - x > sigma * MAD (entropy collapse)

_DETECTOR_BY_EVENT = {
    "NAN_COUNT": DETECT_TRIPWIRE,
    "INF_COUNT": DETECT_TRIPWIRE,
    "ACT_ZERO_FRAC": DETECT_SPIKE,
    "ATTN_ENTROPY": DETECT_COLLAPSE,
}


@dataclasses.dataclass(frozen=True)
class SentinelLane:
    """One anomaly-detector lane of a scope: which flat dense-layout lane
    to read off a drained ``CompactDelta`` and which detector to run."""

    scope: str
    scope_index: int
    slot_index: int     # slot index within the scope context
    lane: int           # flat SlotLayout lane (compact values/samples index)
    slot_id: str
    detector: str       # DETECT_TRIPWIRE | DETECT_SPIKE | DETECT_COLLAPSE

    @property
    def key(self) -> int:
        """Stable baseline key (the flat lane is unique spec-wide)."""
        return self.lane


@dataclasses.dataclass(frozen=True)
class SentinelSet:
    """A scope's compiled detector lanes — empty when the scope computes no
    detector-capable events (such scopes can only be woken by the global
    step-time detector)."""

    scope: str
    scope_index: int
    lanes: tuple[SentinelLane, ...]


@functools.lru_cache(maxsize=None)
def compile_sentinels(spec: MonitorSpec) -> tuple[SentinelSet, ...]:
    """Compile the spec's sentinel sets: per scope, the detector lanes the
    adaptive controller watches on every drained snapshot.

    Like the probe plans, this is static/trace-free and cached on the
    hashable spec: the controller pays O(#detector lanes) host arithmetic
    per drain — no report construction, no device work.  The lane index
    targets the spec-wide dense layout (``spec_layout``), i.e. the compact
    ``CompactDelta`` carriers Monitor rings snapshot; padded CounterState
    deltas are addressed via ``(scope_index, slot_index)`` instead.
    """
    lay = spec_layout(spec)
    out = []
    for si, ctx in enumerate(spec.contexts):
        lanes = []
        for i, slot in enumerate(ctx.slots):
            det = _DETECTOR_BY_EVENT.get(slot.event)
            if det is None:
                continue
            lanes.append(SentinelLane(
                scope=ctx.scope, scope_index=si, slot_index=i,
                lane=lay.offsets[si] + i, slot_id=slot.slot_id,
                detector=det,
            ))
        out.append(SentinelSet(scope=ctx.scope, scope_index=si,
                               lanes=tuple(lanes)))
    return tuple(out)


# ---------------------------------------------------------------------------
# Spec fingerprint — plans are part of the spec's identity
# ---------------------------------------------------------------------------

def describe_plans(spec: MonitorSpec, union: bool = False) -> str:
    """Human-readable plan table: scope → per-set slots + exact sweeps.

    Slot IDENTITIES (event:tensor/subevent) are spelled out per scope — the
    fingerprint hashes this text, and two specs whose slots differ only in
    which event a slot runs (e.g. two bespoke events with empty sweeps)
    must not collide.
    """
    lay = spec_layout(spec)
    lines = []
    deduped = 0
    for i, ctx in enumerate(spec.contexts):
        sp = compile_scope_plans(ctx, None, union)
        deduped += sp.plans_deduped
        ids = ", ".join(ctx.slot_ids)
        lines.append(
            f"{ctx.scope}: width {len(ctx.slots)}, {sp.n_sets} set(s), "
            f"{sp.n_branches} branch bodies, "
            f"footprint [{lay.offsets[i]}:{lay.offsets[i] + lay.widths[i]}]"
            f" slots [{ids}]"
        )
        for k, p in enumerate(sp.plans):
            lines.append(f"  {p.describe()} [body {sp.branch_index[k]}]")
    lines.append(f"total live footprint: {lay.total} slot(s)")
    lines.append(f"plans_deduped: {deduped}")
    return "\n".join(lines)


@functools.lru_cache(maxsize=None)
def spec_fingerprint(spec: MonitorSpec) -> str:
    """Stable hash over the compiled plans (scopes, sets, slots, sweeps).

    Anything that changes the traced probe graph changes this string;
    runtime mask/period/cadence swaps (dynamic inputs) do not.  Reports and
    telemetry streams carry it so a counter row is attributable to the plan
    that produced it.
    """
    text = describe_plans(spec)
    return hashlib.sha1(text.encode()).hexdigest()
