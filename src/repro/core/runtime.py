"""ScALPEL runtime — config reload via SIGUSR1, async counter access,
adaptive hooks (paper §3.3 + C5).

The runtime owns the live (MonitorSpec, MonitorParams, CounterState) triple.
The jitted step receives ``params`` and the carried ``state`` as ordinary
inputs, so everything the runtime mutates is swap-in-place between steps —
never a re-trace.

* ``SIGUSR1`` (or ``reload()``) re-reads the config file and rebuilds the
  masks/periods — the paper's "a new configuration file may be loaded at any
  time by sending a signal to the application".
* ``snapshot()`` gives asynchronous host access to the counters (C5).
* ``add_hook(fn)`` registers an adaptive callback ``fn(runtime, reports)``
  invoked every ``hook_every`` steps — the mechanism the paper motivates for
  "runtime decisions based on performance characteristics" (we use it for
  straggler detection and NaN tripwires in train/loop.py).
* at exit (or ``report()``) counters are written to stdout, the paper's
  default sink.
"""
from __future__ import annotations

import atexit
import signal
import threading
import time
from typing import Callable

import jax

from . import config_file, report as report_lib
from .context import MonitorSpec
from .counters import CounterState, MonitorParams


class ScalpelRuntime:
    def __init__(
        self,
        spec: MonitorSpec,
        params: MonitorParams | None = None,
        config_path: str | None = None,
        install_signal: bool = False,
        report_at_exit: bool = False,
        jsonl_path: str | None = None,
        hook_every: int = 1,
    ):
        self.spec = spec
        self._lock = threading.Lock()
        self.config_path = config_path
        self.jsonl_path = jsonl_path
        self.hook_every = max(1, hook_every)
        self._hooks: list[Callable] = []
        self._step = 0
        self.state = CounterState.zeros(spec)
        self.reload_count = 0
        self.last_reload_errors: list[str] = []
        self._wall: dict[str, float] = {}

        if params is not None:
            self.params = params
        elif config_path is not None:
            self.params = self._params_from_file(config_path)
        else:
            self.params = MonitorParams.all_on(spec)

        if install_signal:
            signal.signal(signal.SIGUSR1, self._on_sigusr1)
        if report_at_exit:
            atexit.register(self._exit_report)

    # -- config reload ----------------------------------------------------
    def _params_from_file(self, path: str) -> MonitorParams:
        cfg = config_file.parse_file(path)
        params, missing = config_file.apply_config(self.spec, cfg)
        self.last_reload_errors = missing
        return params

    def _on_sigusr1(self, signum, frame):  # pragma: no cover - signal path
        del signum, frame
        self.reload()

    def reload(self, path: str | None = None) -> None:
        """Swap in a new config — masks/periods only, never a re-trace."""
        path = path or self.config_path
        if path is None:
            raise ValueError("no config path to reload from")
        with self._lock:
            self.params = self._params_from_file(path)
            self.config_path = path
            self.reload_count += 1

    def set_params(self, params: MonitorParams) -> None:
        with self._lock:
            self.params = params

    # -- step bookkeeping ---------------------------------------------------
    def on_step(self, new_state: CounterState) -> None:
        """Called by the training/serving loop with the step's carried state."""
        self.state = new_state
        self._step += 1
        if self._hooks and self._step % self.hook_every == 0:
            reports = self.snapshot()
            for h in list(self._hooks):
                h(self, reports)
        if self.jsonl_path and self._step % self.hook_every == 0:
            report_lib.write_jsonl(self.jsonl_path, self._step, self.snapshot())

    # -- async access (C5) --------------------------------------------------
    def snapshot(self) -> list[report_lib.ScopeReport]:
        state = jax.tree.map(jax.device_get, self.state)
        return report_lib.build(self.spec, state)

    def estimates(self) -> dict[str, dict[str, float]]:
        state = jax.tree.map(jax.device_get, self.state)
        return report_lib.estimates(self.spec, state)

    def add_hook(self, fn: Callable) -> None:
        self._hooks.append(fn)

    # -- host-side wall-clock context (host_time backend feed) --------------
    def time_block(self, name: str):
        rt = self

        class _Timer:
            def __enter__(self_inner):
                self_inner.t0 = time.perf_counter()
                return self_inner

            def __exit__(self_inner, *exc):
                dt = time.perf_counter() - self_inner.t0
                rt._wall[name] = rt._wall.get(name, 0.0) + dt
                return False

        return _Timer()

    @property
    def wall_times(self) -> dict[str, float]:
        return dict(self._wall)

    # -- reporting ----------------------------------------------------------
    def report(self, title: str = "ScALPEL report") -> str:
        return report_lib.format_text(self.snapshot(), title=title)

    def _exit_report(self) -> None:  # pragma: no cover - atexit path
        try:
            print(self.report())
        except Exception:
            pass
