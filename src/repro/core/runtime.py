"""ScALPEL runtime — config reload via SIGUSR1, async counter access,
adaptive hooks (paper §3.3 + C5), now pull-based on the telemetry plane.

The runtime owns the live (MonitorSpec, MonitorParams, CounterState) triple
plus a ``TelemetryPlane`` (telemetry.py).  The jitted step receives
``params`` (and optionally ``telemetry.params`` + a carried ``SnapshotRing``)
as ordinary inputs, so everything the runtime mutates is swap-in-place
between steps — never a re-trace.

* ``SIGUSR1`` (or ``reload()``) re-reads the config file and rebuilds the
  masks/periods — the paper's "a new configuration file may be loaded at any
  time by sending a signal to the application".
* ``on_step(state[, ring])`` records the step WITHOUT host synchronization:
  it swaps the state reference and either publishes the carried ring or
  dispatches a device-side ring append.  All device→host transfers happen on
  the plane's background drain thread.
* ``add_hook(fn)`` registers an adaptive callback ``fn(runtime, reports)``
  that now runs on *drained snapshots* (a CallbackSink on the drain thread)
  instead of stalling the step loop — the mechanism the paper motivates for
  "runtime decisions based on performance characteristics" (straggler
  detection and NaN tripwires in train/loop.py).
* ``snapshot()``/``report()`` remain synchronous conveniences: they flush
  the ring (so sinks and hooks catch up) and read the current state.
* at exit (or ``report()``) counters are written to stdout, the paper's
  default sink.
"""
from __future__ import annotations

import atexit
import signal
import threading
import time
from typing import Callable

import jax

from . import config_file, report as report_lib, telemetry as telemetry_lib
from .context import MonitorSpec
from .counters import CounterState, MonitorParams


class ScalpelRuntime:
    def __init__(
        self,
        spec: MonitorSpec,
        params: MonitorParams | None = None,
        config_path: str | None = None,
        install_signal: bool = False,
        report_at_exit: bool = False,
        jsonl_path: str | None = None,
        hook_every: int = 1,
        ring_depth: int = 8,
        sinks: tuple = (),
        drain_interval_s: float = 0.01,
        graceful_shutdown: bool = False,
    ):
        self.spec = spec
        self._lock = threading.Lock()
        self.config_path = config_path
        self.jsonl_path = jsonl_path
        self._hooks: list[Callable] = []
        self._step = 0
        self._closed = False
        self.controller = None
        self.fleet_agent = None
        self._shutdown_installed = False
        self._prev_handlers: dict[int, object] = {}
        self.state = CounterState.zeros(spec)
        self.reload_count = 0
        self.last_reload_errors: list[str] = []
        self._wall: dict[str, float] = {}

        self.telemetry = telemetry_lib.TelemetryPlane(
            spec, depth=ring_depth, cadence=max(1, hook_every),
            sinks=sinks, interval_s=drain_interval_s,
        )
        if jsonl_path:
            self.telemetry.add_sink(telemetry_lib.JsonlSink(jsonl_path))

        if params is not None:
            self.params = params
        elif config_path is not None:
            self.params = self._params_from_file(config_path)
        else:
            self.params = MonitorParams.all_on(spec)

        if install_signal:
            signal.signal(signal.SIGUSR1, self._on_sigusr1)
        if report_at_exit:
            atexit.register(self._exit_report)
        if graceful_shutdown:
            self.install_shutdown()

    # -- config reload ----------------------------------------------------
    def _params_from_file(self, path: str) -> MonitorParams:
        cfg = config_file.parse_file(path)
        params, missing = config_file.apply_config(self.spec, cfg)
        self.last_reload_errors = missing
        return params

    def _on_sigusr1(self, signum, frame):
        del signum, frame
        self.reload()

    def reload(self, path: str | None = None) -> None:
        """Swap in a new config — masks/periods only, never a re-trace."""
        path = path or self.config_path
        if path is None:
            raise ValueError("no config path to reload from")
        with self._lock:
            self.params = self._params_from_file(path)
            self.config_path = path
            self.reload_count += 1

    def set_params(self, params: MonitorParams) -> None:
        with self._lock:
            self.params = params

    # -- probe plans (static — the traced half the runtime can NOT swap) ---
    @property
    def plan_fingerprint(self) -> str:
        """Hash of the compiled probe plans (plan.py).  Constant across
        reload()/set_params()/cadence swaps — the attestation that runtime
        reconfiguration re-selects among compiled per-set plans instead of
        re-tracing."""
        return self.spec.fingerprint

    def describe_plans(self) -> str:
        """The live spec's per-(scope, event set) plan table."""
        from . import plan as plan_lib

        return plan_lib.describe_plans(self.spec)

    # -- telemetry cadence (dynamic — swapping it never re-traces) --------
    @property
    def hook_every(self) -> int:
        return self.telemetry.cadence

    @hook_every.setter
    def hook_every(self, n: int) -> None:
        self.telemetry.set_cadence(max(1, int(n)))

    # -- step bookkeeping ---------------------------------------------------
    def on_step(self, new_state,
                ring: telemetry_lib.SnapshotRing | None = None) -> None:
        """Record a step's carried state — no host synchronization.

        ``new_state``: the padded CounterState or any compact carrier
        (``MonitorState.counters``) — reports read either layout directly.

        ``ring``: the loop-carried SnapshotRing if the jitted step appends
        in-graph (train/loop.py, serve/engine.py); its buffers are handed to
        the drain thread, so the ring argument must never be donated.
        Without one, a device-side append is dispatched against a
        plane-owned ring (host-driven mode).
        """
        self.state = new_state
        self._step += 1
        if ring is not None:
            self.telemetry.publish(ring)
        else:
            self.telemetry.append(new_state, step=self._step)

    def observe(self, state: CounterState) -> None:
        """Update the live state reference without ticking telemetry (used
        by consumers that accumulate counters outside on_step cadence)."""
        self.state = state

    # -- async access (C5) --------------------------------------------------
    def flush(self) -> list[telemetry_lib.TelemetrySnapshot]:
        """Drain every pending ring slot through the sinks, synchronously."""
        return self.telemetry.flush()

    def snapshot(self, flush: bool = True) -> list[report_lib.ScopeReport]:
        if flush:
            self.flush()
        state = jax.tree.map(jax.device_get, self.state)
        return report_lib.build(self.spec, state)

    def estimates(self) -> dict[str, dict[str, float]]:
        state = jax.tree.map(jax.device_get, self.state)
        return report_lib.estimates(self.spec, state)

    def add_hook(self, fn: Callable) -> None:
        """Register ``fn(runtime, reports)`` to run on drained snapshots."""
        if not self._hooks:
            self.telemetry.add_sink(
                telemetry_lib.CallbackSink(self._dispatch_hooks)
            )
        self._hooks.append(fn)

    def _dispatch_hooks(self, snap: telemetry_lib.TelemetrySnapshot) -> None:
        reports = snap.reports
        for fn in list(self._hooks):
            fn(self, reports)

    # -- adaptive controller (core/adaptive.py) ---------------------------
    def attach_controller(self, config=None):
        """Attach and install an ``AdaptiveController`` on this runtime's
        telemetry plane — the closed adaptive loop (escalate / de-escalate /
        budget) driving ``set_params``/``set_cadence`` from drained
        snapshots.  Returns the controller; the step loop's existing
        ``mon.sync(mstate, runtime=runtime)`` picks up its decisions."""
        from . import adaptive as adaptive_lib

        ctl = adaptive_lib.AdaptiveController(self, config=config)
        ctl.install()
        self.controller = ctl
        if self.fleet_agent is not None:
            # a fleet agent attached first still delivers downlink hints
            self.fleet_agent.controller = ctl
        return ctl

    # -- fleet telemetry (repro.telemetry) ---------------------------------
    def attach_fleet_agent(self, host_id: str, address, **kwargs):
        """Attach a ``repro.telemetry.FleetAgent`` as a sink on this
        runtime's plane: every drained snapshot ships one wire frame to the
        aggregator at ``address``.

        Rides the existing idempotent close path — ``close()``/
        ``shutdown()`` (and the SIGTERM/atexit route when
        ``graceful_shutdown`` is on) flush the agent's buffered frames and
        emit its final ``shutdown=true`` frame exactly once, because the
        plane closes each sink exactly once.  The current controller (if
        any) receives head-level escalation hints from the downlink.
        Returns the agent (also kept as ``self.fleet_agent``).
        """
        from repro.telemetry.agent import FleetAgent

        kwargs.setdefault("fingerprint", self.spec.fingerprint)
        kwargs.setdefault("controller", self.controller)
        agent = FleetAgent(host_id, address, **kwargs)
        self.telemetry.add_sink(agent)
        self.fleet_agent = agent
        return agent

    # -- graceful shutdown -------------------------------------------------
    def install_shutdown(self, signals=(signal.SIGTERM,)) -> None:
        """Install a SIGTERM + atexit path through ``shutdown()``.

        The signal handler chains to whatever handler was installed before
        (including re-raising a default-disposition signal after the flush,
        so the process still dies of SIGTERM).  Idempotent; a no-op off the
        main thread (signal.signal raises there)."""
        if self._shutdown_installed:
            return
        self._shutdown_installed = True
        atexit.register(self.shutdown)
        for sig in signals:
            try:
                self._prev_handlers[int(sig)] = signal.signal(
                    sig, self._on_shutdown_signal)
            except (ValueError, OSError):  # non-main thread / exotic signal
                pass

    def _on_shutdown_signal(self, signum, frame):
        self.shutdown()
        prev = self._prev_handlers.get(int(signum), signal.SIG_DFL)
        if callable(prev):
            prev(signum, frame)
        elif prev == signal.SIG_DFL:
            # restore the default disposition and re-deliver: the process
            # must still terminate from SIGTERM, just after the flush
            signal.signal(signum, signal.SIG_DFL)
            import os

            os.kill(os.getpid(), signum)

    def shutdown(self) -> str | None:
        """Graceful shutdown: flush the ring, drain pending snapshots,
        emit a final report, then close.  Idempotent with ``close()`` —
        whichever runs first wins and the other is a no-op.  Returns the
        final report text (None if already closed)."""
        if self._closed:
            return None
        try:
            report = self.report("ScALPEL final report")
            print(report)
        except Exception:  # pragma: no cover - shutdown robustness
            report = None
        self.close()
        return report

    def close(self) -> None:
        """Stop the drain thread and flush/close every sink.

        Idempotent: a second close is a no-op, and the ``report_at_exit``
        atexit hook skips after an explicit close — without the guard the
        exit path re-flushed already-closed sinks (double-flush)."""
        if self._closed:
            return
        self._closed = True
        self.telemetry.close()

    @property
    def closed(self) -> bool:
        return self._closed

    # -- checkpoint attestation (plan identity across restarts) -----------
    def save_metadata(self) -> dict:
        """Metadata for checkpoint manifests: which compiled probe plans
        produced the counters being saved."""
        return {
            "plan_fingerprint": self.spec.fingerprint,
            "n_scopes": self.spec.n_scopes,
        }

    def check_resume_metadata(self, meta: dict | None, strict: bool = True):
        """Resume-time plan check: raise (or warn, ``strict=False``) when a
        checkpoint's counters were produced by different compiled plans
        than the live spec.  Returns True on match, None when the metadata
        predates fingerprinting (one shared implementation —
        ``monitor.check_plan_metadata`` — backs this and
        ``Monitor.check_resume``)."""
        from .monitor import check_plan_metadata

        return check_plan_metadata(self.spec.fingerprint, meta,
                                   strict=strict)

    # -- host-side wall-clock context (host_time backend feed) --------------
    def time_block(self, name: str):
        rt = self

        class _Timer:
            def __enter__(self_inner):
                self_inner.t0 = time.perf_counter()
                return self_inner

            def __exit__(self_inner, *exc):
                dt = time.perf_counter() - self_inner.t0
                rt._wall[name] = rt._wall.get(name, 0.0) + dt
                return False

        return _Timer()

    @property
    def wall_times(self) -> dict[str, float]:
        return dict(self._wall)

    # -- reporting ----------------------------------------------------------
    def report(self, title: str = "ScALPEL report") -> str:
        text = report_lib.format_text(self.snapshot(), title=title)
        return text + "\n" + self._telemetry_footer()

    def _telemetry_footer(self) -> str:
        """One-line plane-health footer: the drop-accounting surface the
        fleet tier inspects (``TelemetryPlane.stats()``), human-readable."""
        st = self.telemetry.stats()
        parts = [
            f"drains={st['drain_count']}",
            f"drain_s={st['drain_seconds']:.3f}",
            f"dropped_snapshots={st['dropped_snapshots']}",
        ]
        if st["sink_errors"]:
            errs = ",".join(f"{k}:{v}" for k, v in st["sink_errors"].items())
            parts.append(f"sink_errors=[{errs}]")
        if st["dropped_sinks"]:
            parts.append(f"dropped_sinks={st['dropped_sinks']}")
        agent = self.fleet_agent
        if agent is not None:
            a = agent.stats()
            parts.append(
                f"fleet[sent={a['frames_sent']} "
                f"dropped={a['dropped_frames']} "
                f"reconnects={a['reconnects']}]")
        return "telemetry: " + " ".join(parts)

    def _exit_report(self) -> None:
        if self._closed:
            # an explicit close() already flushed and closed the sinks; the
            # atexit pass must not re-drive them
            return
        try:
            print(self.report())
        except Exception:  # pragma: no cover - atexit robustness
            pass
