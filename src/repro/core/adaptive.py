"""Self-retuning monitor controller — the closed adaptive loop (paper §3.3).

ScALPEL's pitch is *adaptive* monitoring: spend measurement budget only
where anomalies live, at run time, per function.  Every mechanism for that
already exists in this library — plan hot-swap without re-trace
(MonitorParams as dynamic jit inputs), drained-snapshot hooks
(telemetry.CallbackSink), dynamic ring cadence (TelemetryParams) — but the
policy was manual (SIGUSR1 + a hand-edited config file).  This module
closes the loop with an ``AdaptiveController`` in the Scalene/PerSyst
shape: watch cheap statistics, escalate on thresholds, decay when quiet.

The controller runs entirely ON THE TELEMETRY DRAIN THREAD, as a
``CallbackSink`` over drained ``CompactDelta`` snapshots.  It NEVER
dispatches device work (the ROADMAP invariant: new device work queues
behind in-flight steps and delays the very snapshots it reads) — every
action is a host-side reference swap (``runtime.set_params`` /
``TelemetryPlane.set_cadence``) that the step loop picks up at its next
``mon.sync``.

Three loops close per drained snapshot:

* **escalate** — a scope trips an anomaly detector (NaN/Inf tripwires,
  zero-fraction spikes, entropy collapse — all against running EWMA+MAD
  baselines from ``plan.compile_sentinels`` lanes; plus a global step-time
  outlier detector): widen that scope's event set (scope+slot masks all-on,
  multiplex period 1) and drop the ring cadence to ``escalated_cadence`` so
  snapshots arrive densely while the anomaly is live.
* **de-escalate** — a scope quiet for ``quiet_steps`` monitored STEPS
  (measured by the drained deltas' step-stamp spans, so a K-step megastep
  publishing one snapshot per K steps does not make the ladder K× more
  patient) steps DOWN the degradation ladder: WIDE → CONFIGURED (the
  params the controller was installed with) → SENTINEL.  The sentinel
  level is ``scope_mask = 0``: the probe path's ``lax.cond`` skips every
  event sweep while interception still counts calls — presence counters
  only, near-zero overhead.  Sentinel scopes are blind to tensor
  anomalies by construction; the global step-time detector wakes them
  back to CONFIGURED when the workload misbehaves.
* **budget** — a proportional controller retunes the global ring cadence
  to hold the measured monitoring overhead (drain-thread seconds from
  ``TelemetryPlane.drain_seconds`` against wall time between step stamps)
  within ``overhead_budget`` of step time.

The step-time and budget loops measure per-DRAIN, normalized by the step
span: snapshots drained in one batch arrive back-to-back (a K-step
megastep flushes several cadence snapshots at once) with ~zero wall time
between them, so per-snapshot intervals would feed the EWMA+MAD baselines
garbage.  Deltas accumulate into a window keyed by the plane's
``drain_count`` and the detectors tick once per closed window — the
wall-clock and step spans both cover the full drain interval, and the
per-step baselines survive a steps-per-commit swap.

Hysteresis: every level change arms a per-scope cooldown of
``cooldown_steps`` monitored steps during which further changes for that
scope are suppressed — a flapping scope cannot thrash plans.  The one
asymmetry: tripwire escalations (NaN/Inf) bypass the cooldown; losing a
step's NaN localization to hysteresis would defeat the point.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np

from . import plan as plan_lib
from . import telemetry as telemetry_lib
from .context import MonitorSpec
from .counters import MonitorParams

# degradation ladder levels, ordered: higher == more monitoring
SENTINEL, CONFIGURED, WIDE = 0, 1, 2
LEVEL_NAMES = {SENTINEL: "sentinel", CONFIGURED: "configured", WIDE: "wide"}


@dataclasses.dataclass
class AdaptiveConfig:
    """Controller knobs (all host-side; none affect the traced graph).

    Detector thresholds are in MAD-scaled deviations from a running EWMA
    baseline: trip when ``|x - mean| > sigma * max(mad, floor)``.
    """

    # -- baselines / detectors -------------------------------------------
    ewma_alpha: float = 0.25        # baseline update rate
    warmup_drains: int = 3          # snapshots before a baseline can trip
    spike_sigma: float = 8.0        # zero-fraction spike threshold
    spike_floor: float = 0.02       # MAD floor for fraction-valued lanes
    collapse_sigma: float = 8.0     # entropy-collapse threshold
    collapse_floor: float = 0.05    # MAD floor for entropy lanes (nats)
    step_time_sigma: float = 6.0    # global step-time outlier threshold
    step_time_floor_s: float = 1e-3  # MAD floor for step time (seconds)

    # -- hysteresis ladder ------------------------------------------------
    # Quiet/cooldown accounting is in monitored STEPS (snapshot step-stamp
    # spans), not drained snapshots: with one snapshot per K-step megastep
    # the ladder's patience stays constant in steps across a K swap.  The
    # legacy ``*_drains`` names remain the defaults for the step-valued
    # knobs (at cadence 1, one drain == one step — identical behavior).
    cooldown_drains: int = 3        # default for cooldown_steps (legacy name)
    quiet_drains: int = 8           # default for quiet_steps (legacy name)
    cooldown_steps: int | None = None  # suppress level changes this many
                                       # steps after a change
    quiet_steps: int | None = None     # consecutive quiet steps to step down
    sentinel_enabled: bool = True   # allow CONFIGURED → SENTINEL decay

    # -- escalated monitoring ---------------------------------------------
    escalated_period: int = 1       # multiplex period while WIDE
    escalated_cadence: int = 1      # ring cadence floor while any scope WIDE

    # -- overhead budget --------------------------------------------------
    overhead_budget: float = 0.05   # target monitoring fraction of step
                                    # time; >= 1.0 disables the budget loop
    max_cadence: int = 256          # cadence ceiling the budget loop may reach

    # -- fleet hints (repro.telemetry head → agents downlink) -------------
    accept_fleet_hints: bool = True  # apply head-level escalation hints
                                     # arriving via a FleetAgent downlink


@dataclasses.dataclass(frozen=True)
class Transition:
    """One level change on the degradation ladder (controller audit trail)."""

    drain: int          # controller drain index when it happened
    step: int           # step stamp of the triggering snapshot
    scope: str
    frm: str            # level name before
    to: str             # level name after
    reason: str


class _Baseline:
    """Running EWMA mean + EWMA absolute deviation (MAD-style scale)."""

    __slots__ = ("mean", "dev", "n")

    def __init__(self):
        self.mean = 0.0
        self.dev = 0.0
        self.n = 0

    def update(self, x: float, alpha: float) -> None:
        if self.n == 0:
            self.mean = x
        else:
            self.dev += alpha * (abs(x - self.mean) - self.dev)
            self.mean += alpha * (x - self.mean)
        self.n += 1

    def outlier(self, x: float, sigma: float, floor: float,
                warmup: int) -> bool:
        if self.n < warmup:
            return False
        return abs(x - self.mean) > sigma * max(self.dev, floor)

    def low_outlier(self, x: float, sigma: float, floor: float,
                    warmup: int) -> bool:
        if self.n < warmup:
            return False
        return (self.mean - x) > sigma * max(self.dev, floor)


class AdaptiveController:
    """The closed loop: drained snapshots in, mask/cadence swaps out.

    Construct from a ``ScalpelRuntime`` (or pass ``spec``/``params``/
    ``telemetry`` explicitly for standalone use) and ``install()`` — the
    controller registers itself as a ``CallbackSink`` on the plane and from
    then on runs once per drained snapshot, on the drain thread.  Step
    loops pick up its decisions through ``mon.sync(mstate,
    runtime=runtime)`` (or ``mon.sync(mstate, controller=ctl)`` when no
    runtime is involved) — the controller itself never touches the device.
    """

    def __init__(self, runtime=None, *, spec: MonitorSpec | None = None,
                 params: MonitorParams | None = None,
                 telemetry: telemetry_lib.TelemetryPlane | None = None,
                 config: AdaptiveConfig | None = None):
        if runtime is not None:
            spec = runtime.spec if spec is None else spec
            params = runtime.params if params is None else params
            telemetry = runtime.telemetry if telemetry is None else telemetry
        if spec is None or telemetry is None:
            raise ValueError(
                "AdaptiveController needs a runtime or explicit "
                "spec+telemetry"
            )
        self.spec = spec
        self.cfg = config or AdaptiveConfig()
        self.runtime = runtime
        self.telemetry = telemetry
        self.sentinels = plan_lib.compile_sentinels(spec)

        # the CONFIGURED rung: whatever params were live at install time
        self._base = params if params is not None else MonitorParams.all_on(
            spec)
        self._base_scope = np.asarray(self._base.scope_mask, np.float32)
        self._base_slot = np.asarray(self._base.slot_mask, np.float32)
        self._base_period = np.asarray(self._base.period, np.int32)
        self._params = self._base
        self._base_cadence = max(1, telemetry.cadence)

        n = spec.n_scopes
        self._level = np.full((n,), CONFIGURED, np.int32)
        # quiet/cooldown ride step stamps, not drain counts (megastep-safe)
        self._quiet = np.zeros((n,), np.int64)
        self._cooldown_until_step = np.zeros((n,), np.int64)
        self._baselines: dict[int, _Baseline] = {}
        self._step_time = _Baseline()
        self._drains = 0
        self._last_stamp = 0
        self._prev_wall: float | None = None
        self._prev_step: int | None = None
        self._prev_drain_s = float(getattr(telemetry, "drain_seconds", 0.0))
        # the per-drain measurement window (see module docstring): deltas
        # accumulate here; the step-time/budget detectors tick on close
        self._win_id: int | None = None
        self._acc_wall = 0.0
        self._acc_steps = 0
        self._acc_drain_s = 0.0
        self._overhead_frac = 0.0

        self._lock = threading.Lock()
        self._installed = False
        self.transitions: list[Transition] = []
        self.events: list[str] = []
        self.stats = {
            "drains": 0, "escalations": 0, "deescalations": 0,
            "plan_swaps": 0, "cadence_changes": 0, "suppressed": 0,
            "step_time_wakes": 0, "fleet_hints": 0, "fleet_hints_ignored": 0,
        }

    # -- wiring -----------------------------------------------------------
    def install(self) -> "AdaptiveController":
        """Register on the telemetry plane (idempotent)."""
        if not self._installed:
            self._installed = True
            self.telemetry.add_sink(telemetry_lib.CallbackSink(self.on_snapshot))
        return self

    @property
    def params(self) -> MonitorParams:
        """The live MonitorParams — what ``Monitor.sync(controller=...)``
        picks up each step."""
        return self._params

    @property
    def tparams(self) -> telemetry_lib.TelemetryParams:
        return self.telemetry.params

    @property
    def levels(self) -> dict[str, str]:
        return {
            s: LEVEL_NAMES[int(lv)]
            for s, lv in zip(self.spec.scopes, self._level)
        }

    @property
    def overhead_frac(self) -> float:
        """EWMA of measured monitoring overhead as a fraction of wall time."""
        return self._overhead_frac

    def escalate(self, scope: str, reason: str = "manual") -> None:
        """Force a scope to WIDE (same path the detectors take)."""
        with self._lock:
            self._escalate(self.spec.scope_index(scope), reason,
                           step=-1, tripwire=True)

    def apply_fleet_hint(self, scope: str | None, *,
                         reason: str = "fleet-hint",
                         tripwire: bool = False) -> bool:
        """Apply a fleet-head escalation hint (FleetAgent downlink path).

        Another host saw an anomaly the head judged fleet-relevant; this
        process escalates in sympathy so the anomaly's next occurrence is
        observed WIDE everywhere.  ``scope=None`` (a global hint) wakes
        sentinel scopes to CONFIGURED — the same move as the step-time
        wake.  A named scope takes the detectors' ``_escalate`` path;
        tripwire hints pierce cooldown exactly like local tripwires.
        Gated by ``AdaptiveConfig.accept_fleet_hints``; returns whether the
        hint was applied.  Runs on the agent's reader thread — host work
        only, same rule as ``on_snapshot``.
        """
        if not self.cfg.accept_fleet_hints:
            with self._lock:
                self.stats["fleet_hints_ignored"] += 1
            return False
        with self._lock:
            step = self._last_stamp
            if scope is None:
                self.stats["fleet_hints"] += 1
                for idx in range(self.spec.n_scopes):
                    if self._level[idx] == SENTINEL and \
                            (tripwire or
                             step >= self._cooldown_until_step[idx]):
                        self._set_level(idx, CONFIGURED, reason, step)
                return True
            try:
                idx = self.spec.scope_index(scope)
            except (KeyError, ValueError):
                # the hint names a scope this process doesn't monitor (a
                # heterogeneous fleet) — nothing to escalate here
                self.stats["fleet_hints_ignored"] += 1
                return False
            self.stats["fleet_hints"] += 1
            self._escalate(idx, reason, step=step, tripwire=tripwire)
            return True

    # -- resolved ladder knobs (legacy *_drains names are the defaults) ---
    @property
    def _quiet_steps(self) -> int:
        cfg = self.cfg
        return cfg.quiet_steps if cfg.quiet_steps is not None \
            else cfg.quiet_drains

    @property
    def _cooldown_steps(self) -> int:
        cfg = self.cfg
        return cfg.cooldown_steps if cfg.cooldown_steps is not None \
            else cfg.cooldown_drains

    # -- the drain-thread callback ----------------------------------------
    def on_snapshot(self, snap: telemetry_lib.TelemetrySnapshot) -> None:
        """One controller tick.  Runs on the drain thread; host work only."""
        now = time.perf_counter()
        with self._lock:
            self._drains += 1
            self.stats["drains"] = self._drains
            step = int(snap.step)
            # the step span this snapshot's delta covers — the stamp
            # distance to the previously drained snapshot (>= 1: a K-step
            # megastep at cadence K spans K steps per snapshot)
            span = max(1, step - self._last_stamp)
            anomalies = self._detect(snap)
            for idx, (reason, trip) in anomalies.items():
                self._escalate(idx, reason, step=step, tripwire=trip)
            self._decay(anomalies, step, span)
            self._interval_tick(snap, now)
            self._last_stamp = max(self._last_stamp, step)

    # -- detectors --------------------------------------------------------
    def _lane_value(self, delta, lane: int, scope_idx: int, slot_idx: int):
        vals = np.asarray(delta.values)
        smps = np.asarray(delta.samples)
        if vals.ndim == 1:       # compact dense layout
            return float(vals[lane]), int(smps[lane])
        return float(vals[scope_idx, slot_idx]), int(smps[scope_idx,
                                                         slot_idx])

    def _detect(self, snap) -> dict[int, tuple[str, bool]]:
        """Per-scope anomaly verdicts over the snapshot's counter DELTA.

        Reads raw detector lanes straight off the drained CompactDelta
        (no report construction): O(#detector lanes) host arithmetic.
        Returns {scope_index: (reason, is_tripwire)}.
        """
        out: dict[int, tuple[str, bool]] = {}
        delta = snap.delta
        cfg = self.cfg
        for sset in self.sentinels:
            if self._level[sset.scope_index] == SENTINEL:
                continue          # masked off — lanes carry nothing
            for lane in sset.lanes:
                v, s = self._lane_value(delta, lane.lane, sset.scope_index,
                                        lane.slot_index)
                if lane.detector == plan_lib.DETECT_TRIPWIRE:
                    if v > 0:
                        out[sset.scope_index] = (
                            f"{lane.slot_id} +{v:g}", True)
                        break
                    continue
                if s <= 0:
                    continue      # slot not sampled this interval
                x = v / s
                bl = self._baselines.setdefault(lane.key, _Baseline())
                if lane.detector == plan_lib.DETECT_SPIKE:
                    hit = bl.outlier(x, cfg.spike_sigma, cfg.spike_floor,
                                     cfg.warmup_drains)
                else:             # DETECT_COLLAPSE
                    hit = bl.low_outlier(x, cfg.collapse_sigma,
                                         cfg.collapse_floor,
                                         cfg.warmup_drains)
                if hit:
                    out[sset.scope_index] = (
                        f"{lane.slot_id} {x:.4g} vs baseline "
                        f"{bl.mean:.4g}±{bl.dev:.4g}", False)
                    break
                bl.update(x, cfg.ewma_alpha)   # only clean values feed it
        return out

    # -- per-drain measurement window -------------------------------------
    def _interval_tick(self, snap, now: float) -> None:
        """Accumulate this snapshot's wall/step/drain-seconds deltas into
        the current measurement window; close the window when the plane's
        ``drain_count`` moves on.

        Snapshots drained in one batch (a K-step megastep flushes several
        cadence appends at once) share a ``drain_count`` and arrive
        back-to-back — their per-snapshot wall deltas are ~0 and would
        poison the per-step baselines.  Summed over a whole window the
        deltas cover the full drain interval: total wall over total steps
        is the true per-step time, total drain seconds over total wall is
        the true overhead fraction, whatever steps-per-commit is.
        """
        step = int(snap.step)
        drain_s_total = float(getattr(self.telemetry, "drain_seconds", 0.0))
        win = getattr(self.telemetry, "drain_count", None)
        if self._prev_wall is None:
            self._prev_wall = now
            self._prev_step = step
            self._prev_drain_s = drain_s_total
            self._win_id = win
            return
        if win != self._win_id and self._acc_steps > 0 \
                and self._acc_wall > 0:
            self._step_time_tick(self._acc_wall / self._acc_steps, step)
            self._budget_tick(self._acc_drain_s, self._acc_wall)
            self._acc_wall = 0.0
            self._acc_steps = 0
            self._acc_drain_s = 0.0
        self._win_id = win
        self._acc_wall += now - self._prev_wall
        self._acc_steps += max(0, step - self._prev_step)
        self._acc_drain_s += max(0.0, drain_s_total - self._prev_drain_s)
        self._prev_wall = now
        self._prev_step = step
        self._prev_drain_s = drain_s_total

    def _step_time_tick(self, per_step: float, step: int) -> None:
        """Global step-time outlier detector — the wake path for sentinel
        scopes (which are blind to tensor anomalies by construction)."""
        cfg = self.cfg
        if self._step_time.outlier(per_step, cfg.step_time_sigma,
                                   cfg.step_time_floor_s, cfg.warmup_drains):
            self.stats["step_time_wakes"] += 1
            reason = (f"step time {per_step * 1e3:.1f}ms vs baseline "
                      f"{self._step_time.mean * 1e3:.1f}ms")
            woke = False
            for idx in range(self.spec.n_scopes):
                if self._level[idx] == SENTINEL and \
                        step >= self._cooldown_until_step[idx]:
                    self._set_level(idx, CONFIGURED, reason, step)
                    woke = True
            if not woke:
                self.events.append(
                    f"[drain {self._drains}] step-time outlier ({reason}), "
                    "no sentinel scopes to wake")
        else:
            self._step_time.update(per_step, cfg.ewma_alpha)

    # -- transitions ------------------------------------------------------
    def _escalate(self, idx: int, reason: str, step: int,
                  tripwire: bool) -> None:
        self._quiet[idx] = 0
        if self._level[idx] >= WIDE:
            return
        if not tripwire and step < self._cooldown_until_step[idx]:
            self.stats["suppressed"] += 1
            return
        self._set_level(idx, WIDE, reason, step)

    def _decay(self, anomalies: dict, step: int, span: int) -> None:
        cfg = self.cfg
        floor = SENTINEL if cfg.sentinel_enabled else CONFIGURED
        for idx in range(self.spec.n_scopes):
            if idx in anomalies:
                continue
            if self._level[idx] <= floor:
                continue
            # a scope whose CONFIGURED rung never monitors can't produce
            # detector evidence; don't cycle it through the ladder
            if self._level[idx] == CONFIGURED and \
                    self._base_scope[idx] == 0.0:
                continue
            # quiet accrues the snapshot's STEP span, not one-per-drain:
            # a K-step megastep's snapshot attests K quiet steps
            self._quiet[idx] += span
            if self._quiet[idx] >= self._quiet_steps and \
                    step >= self._cooldown_until_step[idx]:
                self._set_level(idx, int(self._level[idx]) - 1,
                                f"quiet for {int(self._quiet[idx])} steps",
                                step)
                self._quiet[idx] = 0

    def _set_level(self, idx: int, level: int, reason: str,
                   step: int) -> None:
        prev = int(self._level[idx])
        if level == prev:
            return
        self._level[idx] = level
        # manual escalate() passes step=-1 — anchor on the last stamp then
        self._cooldown_until_step[idx] = \
            max(int(step), self._last_stamp) + self._cooldown_steps
        t = Transition(
            drain=self._drains, step=int(step),
            scope=self.spec.scopes[idx],
            frm=LEVEL_NAMES[prev], to=LEVEL_NAMES[level], reason=reason,
        )
        self.transitions.append(t)
        self.events.append(
            f"[drain {t.drain}] {t.scope}: {t.frm} -> {t.to} ({t.reason})")
        if level > prev:
            self.stats["escalations"] += 1
        else:
            self.stats["deescalations"] += 1
        self._rebuild_params()
        self._retune_cadence_for_levels()

    def _rebuild_params(self) -> None:
        """Materialize the ladder into fresh MonitorParams and swap them in
        (host-side; the step loop's next ``mon.sync`` picks them up)."""
        scope_mask = self._base_scope.copy()
        slot_mask = self._base_slot.copy()
        period = self._base_period.copy()
        for idx, lv in enumerate(self._level):
            if lv == WIDE:
                scope_mask[idx] = 1.0
                slot_mask[idx, :] = 1.0
                period[idx] = max(1, self.cfg.escalated_period)
            elif lv == SENTINEL:
                scope_mask[idx] = 0.0
        self._params = MonitorParams(
            scope_mask=jnp.asarray(scope_mask),
            slot_mask=jnp.asarray(slot_mask),
            period=jnp.asarray(period),
        )
        self.stats["plan_swaps"] += 1
        if self.runtime is not None:
            self.runtime.set_params(self._params)

    # -- budget loop ------------------------------------------------------
    def _cadence_floor(self) -> int:
        if np.any(self._level == WIDE):
            return max(1, self.cfg.escalated_cadence)
        return self._base_cadence

    def _budget_tick(self, drain_s: float, wall: float) -> None:
        """Proportional cadence retune holding measured monitoring overhead
        within ``overhead_budget`` of wall time.

        Ticks once per closed measurement window (``_interval_tick``):
        overhead = drain-thread seconds accumulated over the window
        (``TelemetryPlane.drain_seconds`` deltas), over the window's wall
        time.

        A budget of 1.0 (100% of wall time) or more means "no budget":
        the loop is disabled outright rather than left one measurement
        blip away from firing — synchronous flush-per-step harnesses on
        trivial workloads measure drain fractions that legitimately graze
        (and, with tick/drain interval skew, transiently exceed) 1.0.
        """
        if self.cfg.overhead_budget >= 1.0 or wall <= 0:
            return
        frac = drain_s / wall
        a = self.cfg.ewma_alpha
        self._overhead_frac += a * (frac - self._overhead_frac)

        cadence = self.telemetry.cadence
        floor = self._cadence_floor()
        target = cadence
        if self._overhead_frac > self.cfg.overhead_budget:
            # proportional: scale cadence by the overshoot, clipped to 2x
            ratio = min(2.0, self._overhead_frac / self.cfg.overhead_budget)
            target = min(self.cfg.max_cadence,
                         max(cadence + 1, int(round(cadence * ratio))))
        elif self._overhead_frac < 0.5 * self.cfg.overhead_budget and \
                cadence > floor:
            # decay back toward the floor (halving, never below it)
            target = max(floor, cadence // 2)
        elif cadence < floor:
            pass  # an escalation lowered it on purpose; leave it
        if target != cadence:
            self.telemetry.set_cadence(target)
            self.stats["cadence_changes"] += 1
            self.events.append(
                f"[drain {self._drains}] cadence {cadence} -> {target} "
                f"(overhead {self._overhead_frac:.1%} vs budget "
                f"{self.cfg.overhead_budget:.0%})")

    def _retune_cadence_for_levels(self) -> None:
        """Escalations want dense snapshots NOW, not at the budget loop's
        pace: any WIDE scope pins cadence at ``escalated_cadence``; once
        the last one steps down, the base cadence is restored (the budget
        loop may still push it higher afterwards)."""
        cur = self.telemetry.cadence
        if np.any(self._level == WIDE):
            tgt = min(cur, max(1, self.cfg.escalated_cadence))
        else:
            tgt = max(cur, self._base_cadence)
        if tgt != cur:
            self.telemetry.set_cadence(tgt)
            self.stats["cadence_changes"] += 1
            self.events.append(
                f"[drain {self._drains}] cadence {cur} -> {tgt} "
                "(escalation ladder)")

    def describe(self) -> str:
        lines = [f"adaptive controller: {self._drains} drains, "
                 f"overhead {self._overhead_frac:.2%}"]
        for scope, lv in self.levels.items():
            lines.append(f"  {scope}: {lv}")
        lines.extend(f"  {e}" for e in self.events[-8:])
        return "\n".join(lines)
