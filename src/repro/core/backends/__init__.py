"""Pluggable counter backends (paper C6: reuse Perfmon/PAPI; ours reuse what
the JAX/XLA stack exposes).

* ``ingraph``   — event values computed inside the XLA program on live
                  tensors (implemented in core/instrument.py + core/events.py;
                  this package re-exports helpers).
* ``xla_cost``  — static per-program and per-scope FLOPs / bytes / collective
                  traffic from the compiled artifact (roofline source).
* ``host_time`` — wall-clock dispatch timing around jitted blocks.
* ``host_callback`` — a deliberately perfmon-like backend: an ``io_callback``
                  host round-trip on every scope entry/exit (the breakpoint
                  analogue).  Exists to reproduce the paper's overhead
                  hierarchy; do not use it in production.
"""
from . import host_callback, host_time, xla_cost  # noqa: F401
