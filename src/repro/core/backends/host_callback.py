"""'Perfmon mode' — a deliberately breakpoint-like backend.

The paper's central overhead result (Figs. 2–3) is that perfmon's
ptrace/breakpoint interception costs 2–3 orders of magnitude more than
compiler-directed callbacks, because every monitored call detours through
the kernel/monitor process.  The JAX analogue of that detour is an
``io_callback`` on every scope entry: the device round-trips to the host,
serializes the operands, runs Python, and stalls the dispatch queue.

This backend exists so benchmarks/overhead.py can reproduce the paper's
hierarchy (vanilla < selective <= all << perfmon) on our stack.  It is NOT
the production path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


class HostMonitor:
    """Host-side 'monitor process': receives one callback per scope call."""

    def __init__(self):
        self._lock = threading.Lock()
        self.calls: dict[str, int] = {}
        self.values: dict[str, float] = {}
        self.timestamps: dict[str, list[float]] = {}

    def on_call(self, scope: str, value: float) -> None:
        with self._lock:
            self.calls[scope] = self.calls.get(scope, 0) + 1
            self.values[scope] = self.values.get(scope, 0.0) + float(value)
            self.timestamps.setdefault(scope, []).append(time.perf_counter())

    def reset(self) -> None:
        with self._lock:
            self.calls.clear()
            self.values.clear()
            self.timestamps.clear()


_GLOBAL_MONITOR = HostMonitor()


def global_monitor() -> HostMonitor:
    return _GLOBAL_MONITOR


def breakpoint_probe(scope: str, value, monitor: HostMonitor | None = None):
    """Insert a host round-trip 'breakpoint' carrying one scalar.

    Returns ``value`` with a data dependency on the callback so XLA cannot
    elide it (mirrors how a real breakpoint serializes execution).
    """
    mon = monitor or _GLOBAL_MONITOR
    v = jnp.asarray(value, jnp.float32)
    if v.ndim > 0:
        v = jnp.mean(v)

    def cb(x):
        mon.on_call(scope, float(np.asarray(x)))
        return np.asarray(x, np.float32)

    out = jax.experimental.io_callback(cb, jax.ShapeDtypeStruct((), jnp.float32), v,
                                       ordered=True)
    return out


def instrument_breakpoint(fn: Callable, scope: str,
                          monitor: HostMonitor | None = None) -> Callable:
    """Wrap ``fn`` so every call fires entry+exit breakpoints (perfmon mode)."""

    def wrapped(*args, **kwargs):
        # entry breakpoint on the first array argument (or 0.0)
        first = next(
            (a for a in jax.tree.leaves((args, kwargs))
             if isinstance(a, (jax.Array, jnp.ndarray))),
            jnp.float32(0.0),
        )
        tick = breakpoint_probe(scope + "@entry", jnp.float32(0.0) * jnp.mean(
            jnp.asarray(first, jnp.float32).ravel()[0]), monitor)
        out = fn(*args, **kwargs)
        leaves = jax.tree.leaves(out)
        anchor = leaves[0] if leaves else jnp.float32(0.0)
        exit_v = breakpoint_probe(
            scope + "@exit",
            jnp.mean(jnp.asarray(anchor, jnp.float32)) + tick * 0,
            monitor,
        )
        # thread the exit value back so the callback stays in the graph
        if leaves and isinstance(leaves[0], (jax.Array, jnp.ndarray)):
            patched = leaves[0] + jnp.zeros_like(
                leaves[0], leaves[0].dtype
            ) * exit_v.astype(leaves[0].dtype)
            out = jax.tree.unflatten(jax.tree.structure(out),
                                     [patched] + leaves[1:])
        return out

    return wrapped
