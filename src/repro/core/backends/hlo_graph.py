"""HLO module graph analysis with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts a while-loop body ONCE — for a
scan-over-layers transformer that underreports FLOPs by O(n_layers * n_scan)
(measured: 1000x on our stacks).  This module parses the post-optimization
HLO text into a computation graph and computes:

  * flops            — dot/convolution FLOPs, x trip count for while bodies
  * hbm_bytes        — operand+output bytes of traffic-bearing top-level ops
                       (fusions count as one op: that IS the fusion's HBM
                       round-trip), x trip count
  * collective link bytes per kind (ring-algorithm per-chip estimates)
  * max over conditional branches (roofline-fair for predicated monitoring)

All numbers are per-device: the input is the SPMD-partitioned module.
Trip counts come from the loop-condition comparison constant (jax scans
count 0..N); loops whose bound cannot be parsed are scaled by 1 and counted
in ``unscaled_whiles``.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) + r")"
    r"\[([0-9,]*)\]"
)
# computation header: "%name (sig...) -> type {"; the signature may contain
# nested parens (tuple types), so match only the leading name
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_COUNT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_ATTR_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_ATTR_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_ATTR_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_ATTR_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"%([\w\.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPLICA_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
_PLUMBING = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "iota", "after-all", "opt-barrier", "partition-id", "replica-id",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "copy-done", "copy-start", "domain", "rng-get-and-update-state",
}


def _shape_bytes(text: str) -> float:
    return sum(
        _DTYPE_BYTES[d] * (eval("*".join(dims.split(",")))
                           if dims else 1)
        for d, dims in _SHAPE_RE.findall(text)
    )


def _shape_elems(text: str) -> float:
    tot = 0.0
    for _, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        tot += n
    return tot


def _first_shape_dims(text: str) -> list[int] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = m.group(2)
    return [int(x) for x in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_type: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    transcendental: float = 0.0
    coll: dict[str, float] | None = None
    coll_payload: float = 0.0
    n_coll: int = 0

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.transcendental += other.transcendental * mult
        self.coll_payload += other.coll_payload * mult
        self.n_coll += int(other.n_coll * mult)
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def collective_link_bytes(self) -> float:
        return sum(self.coll.values())


class HloModule:
    def __init__(self, text: str, default_group: int = 1):
        self.default_group = default_group
        self.computations: dict[str, list[Op]] = {}
        self.symbols: dict[str, str] = {}   # op name -> output type text
        self.constants: dict[str, int] = {}
        self.entry: str | None = None
        self.unscaled_whiles = 0
        self._parse(text)
        self._memo: dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: list[Op] | None = None
        cur_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_START_RE.match(line.strip())
                if m:
                    cur_name = m.group(1)
                    cur = []
                    self.computations[cur_name] = cur
                    if line.startswith("ENTRY"):
                        self.entry = cur_name
                    continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            mo = _OP_RE.match(line)
            if not mo:
                continue
            name, out_type, kind = mo.group(1), mo.group(2), mo.group(3)
            paren = line[mo.end():]
            # operands: %refs inside the first paren group (up to matching ')')
            depth = 1
            i = 0
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operand_text = paren[:i]
            operands = _OPERAND_RE.findall(operand_text)
            op = Op(name=name, kind=kind, out_type=out_type,
                    operands=operands, line=line)
            cur.append(op)
            self.symbols[name] = out_type
            mc = _CONST_RE.search(line)
            if mc:
                self.constants[mc.group(1)] = int(mc.group(2))

    # ------------------------------------------------------------------
    def _operand_bytes(self, op: Op) -> float:
        return sum(
            _shape_bytes(self.symbols.get(o, "")) for o in op.operands
        )

    _PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
    _SLICERS = ("dynamic-slice", "slice", "gather")

    def _fusion_in_traffic(self, comp_name: str, operands: list[str]) -> float:
        """HBM read traffic of a fusion: full operand bytes, EXCEPT
        * operands consumed only through (dynamic-)slice/gather — a scan body
          reads one layer slice of the stacked params per trip, not the stack;
        * operands consumed only as the TARGET buffer (operand 0) of a
          dynamic-update-slice — XLA updates in place, no read of the buffer.
        """
        ops = self.computations.get(comp_name, [])
        if not ops:
            return sum(
                _shape_bytes(self.symbols.get(o, "")) for o in operands
            )
        pidx: dict[str, int] = {}
        for o in ops:
            if o.kind == "parameter":
                m = self._PARAM_IDX_RE.search(o.line)
                if m:
                    pidx[o.name] = int(m.group(1))

        _TRANSPARENT = ("bitcast", "copy", "reshape", "transpose")

        def effective_consumers(name: str, depth: int = 0) -> list[Op]:
            """Consumers of ``name``, looking through layout-only ops."""
            out: list[Op] = []
            for c in ops:
                if name not in c.operands:
                    continue
                if c.kind in _TRANSPARENT and depth < 4:
                    out.extend(effective_consumers(c.name, depth + 1))
                else:
                    out.append(c)
            return out

        total = 0.0
        for pname, idx in pidx.items():
            consumers = effective_consumers(pname)
            if consumers and all(c.kind in self._SLICERS for c in consumers):
                total += sum(self._out_bytes(c) for c in consumers)
            elif consumers and all(
                c.kind == "dynamic-update-slice"
                and c.operands
                and (c.operands[0] == pname
                     or self.symbols.get(c.operands[0], "")
                     and _shape_elems(self.symbols.get(c.operands[0], ""))
                     == _shape_elems(self.symbols.get(pname, "x[1]")))
                for c in consumers
            ):
                pass  # in-place DUS target: buffer is not re-read
            else:
                if idx < len(operands):
                    total += _shape_bytes(
                        self.symbols.get(operands[idx], "")
                    )
        return total

    def _fusion_out_bytes(self, comp_name: str, op: Op) -> float:
        """Fusion write traffic: a DUS-carrying fusion whose output is the
        updated buffer writes only the slice (in-place aliasing).  Element
        counts are compared (converts may change the byte width)."""
        out_e = _shape_elems(op.out_type)
        for o in self.computations.get(comp_name, []):
            if o.kind == "dynamic-update-slice" and len(o.operands) > 1 \
                    and _shape_elems(o.out_type) == out_e:
                upd = _shape_bytes(self.symbols.get(o.operands[1], ""))
                if upd:
                    return upd
        return self._out_bytes(op)

    def _out_bytes(self, op: Op) -> float:
        return _shape_bytes(op.out_type)

    def _group_size(self, line: str) -> int:
        m = _REPLICA_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        m = _REPLICA_GROUPS_RE.search(line)
        if m:
            ids = [x for x in m.group(1).split(",") if x.strip()]
            return max(1, len(ids))
        return self.default_group

    def _dot_flops(self, op: Op) -> float:
        out_elems = _shape_elems(op.out_type)
        cd = _LHS_CDIMS_RE.search(op.line)
        k = 1.0
        if cd and op.operands:
            lhs_dims = _first_shape_dims(
                self.symbols.get(op.operands[0], "")
            )
            if lhs_dims is not None and cd.group(1):
                for idx in cd.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
        return 2.0 * out_elems * k

    def _conv_flops(self, op: Op) -> float:
        # depthwise/grouped convs in our stacks are small; approximate
        # 2 * out_elems * prod(kernel dims except output feature)
        out_elems = _shape_elems(op.out_type)
        k_elems = 1.0
        if len(op.operands) > 1:
            kd = _first_shape_dims(self.symbols.get(op.operands[1], ""))
            if kd:
                full = 1
                for d in kd:
                    full *= d
                # divide by output-feature dim (last of kernel by default)
                k_elems = full / max(1, kd[-1])
        return 2.0 * out_elems * k_elems

    def _fusion_flops(self, comp_name: str) -> float:
        """Dot/conv FLOPs inside a fusion computation (bytes NOT counted)."""
        total = 0.0
        for op in self.computations.get(comp_name, []):
            if op.kind == "dot":
                total += self._dot_flops(op)
            elif op.kind == "convolution":
                total += self._conv_flops(op)
            elif op.kind == "fusion":
                m = _ATTR_CALLS_RE.search(op.line)
                if m:
                    total += self._fusion_flops(m.group(1))
        return total

    def _trip_count(self, cond_name: str) -> int | None:
        best = None
        for op in self.computations.get(cond_name, []):
            for o in op.operands:
                if o in self.constants:
                    v = self.constants[o]
                    best = v if best is None else max(best, v)
            if op.name in self.constants:
                v = self.constants[op.name]
                best = v if best is None else max(best, v)
        return best

    # ------------------------------------------------------------------
    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        self._memo[comp_name] = total  # guard cycles
        for op in self.computations.get(comp_name, []):
            k = op.kind
            if k in _PLUMBING:
                continue
            if k == "while":
                mc = _ATTR_COND_RE.search(op.line)
                mb = _ATTR_BODY_RE.search(op.line)
                # XLA annotates loops it has bounded: the authoritative count
                mt = _TRIP_COUNT_RE.search(op.line)
                if mt:
                    trip = int(mt.group(1))
                else:
                    trip = self._trip_count(mc.group(1)) if mc else None
                if trip is None:
                    trip = 1
                    self.unscaled_whiles += 1
                if mb:
                    total.add(self.cost_of(mb.group(1)), mult=trip)
                if mc:
                    total.add(self.cost_of(mc.group(1)), mult=trip)
                continue
            if k == "conditional":
                mb = _ATTR_BRANCHES_RE.search(op.line)
                names = []
                if mb:
                    names = _OPERAND_RE.findall(mb.group(1)) or [
                        x.strip() for x in mb.group(1).split(",")
                    ]
                else:
                    names = [m for m in
                             (_ATTR_COND_RE.search(op.line),) if m]
                branch_costs = [self.cost_of(n) for n in names if n]
                if branch_costs:
                    mx = max(branch_costs,
                             key=lambda c: (c.flops, c.hbm_bytes))
                    total.add(mx)
                continue
            if k in ("call", "async-start"):
                m = _ATTR_TOAPPLY_RE.search(op.line) or \
                    _ATTR_CALLS_RE.search(op.line)
                if m:
                    total.add(self.cost_of(m.group(1)))
                continue
            if k in _COLLECTIVES:
                base = k[:-6] if k.endswith("-start") else k
                out_b = self._out_bytes(op)
                in_b = self._operand_bytes(op) or out_b
                n = self._group_size(op.line)
                f = (n - 1) / n if n > 1 else 0.0
                link = {
                    "all-reduce": 2.0 * in_b * f,
                    "all-gather": out_b * f,
                    "reduce-scatter": in_b * f,
                    "all-to-all": in_b * f,
                    "collective-permute": in_b if n > 1 else 0.0,
                }[base]
                total.coll[base] = total.coll.get(base, 0.0) + link
                total.coll_payload += max(in_b, out_b)
                total.n_coll += 1
                total.hbm_bytes += in_b + out_b
                continue
            if k == "fusion":
                m = _ATTR_CALLS_RE.search(op.line)
                if m:
                    total.flops += self._fusion_flops(m.group(1))
                    total.hbm_bytes += self._fusion_in_traffic(
                        m.group(1), op.operands
                    ) + self._fusion_out_bytes(m.group(1), op)
                else:
                    total.hbm_bytes += self._operand_bytes(op) + \
                        self._out_bytes(op)
                continue
            if k in self._SLICERS:
                # reads only the slice it produces (+ writes it)
                total.hbm_bytes += 2.0 * self._out_bytes(op)
                continue
            if k in ("dynamic-update-slice", "scatter"):
                # in-place update: traffic ~ 2x the update operand, not the
                # whole buffer (matters for decode KV-cache writes)
                upd = (
                    _shape_bytes(self.symbols.get(op.operands[1], ""))
                    if len(op.operands) > 1 else self._out_bytes(op)
                )
                total.hbm_bytes += 2.0 * upd
                continue
            if k == "dot":
                total.flops += self._dot_flops(op)
                total.hbm_bytes += self._operand_bytes(op) + \
                    self._out_bytes(op)
                continue
            if k == "convolution":
                total.flops += self._conv_flops(op)
                total.hbm_bytes += self._operand_bytes(op) + \
                    self._out_bytes(op)
                continue
            # generic traffic-bearing op
            total.hbm_bytes += self._operand_bytes(op) + self._out_bytes(op)
            if k in ("exponential", "tanh", "log", "rsqrt", "sqrt",
                     "logistic", "power", "sine", "cosine"):
                total.transcendental += _shape_elems(op.out_type)
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            # fall back: largest computation
            best = Cost()
            for name in self.computations:
                c = self.cost_of(name)
                if c.flops >= best.flops:
                    best = c
            return best
        return self.cost_of(self.entry)


def breakdown(hlo_text: str, default_group: int = 1, top: int = 25):
    """Top cost-contributing ops with their effective trip multipliers —
    the dry-run 'profile' used by the §Perf iterations."""
    mod = HloModule(hlo_text, default_group=default_group)
    entries: list[dict] = []

    def walk(comp: str, mult: float, path: str):
        for op in mod.computations.get(comp, []):
            k = op.kind
            if k in _PLUMBING:
                continue
            if k == "while":
                mt = _TRIP_COUNT_RE.search(op.line)
                mc = _ATTR_COND_RE.search(op.line)
                mb = _ATTR_BODY_RE.search(op.line)
                trip = int(mt.group(1)) if mt else (
                    mod._trip_count(mc.group(1)) if mc else None) or 1
                if mb:
                    walk(mb.group(1), mult * trip, path + f"/while×{trip}")
                continue
            if k in ("call", "async-start"):
                m = _ATTR_TOAPPLY_RE.search(op.line) or \
                    _ATTR_CALLS_RE.search(op.line)
                if m:
                    walk(m.group(1), mult, path)
                continue
            if k == "conditional":
                m = _ATTR_BRANCHES_RE.search(op.line)
                if m:
                    names = _OPERAND_RE.findall(m.group(1))
                    costs = [(n, mod.cost_of(n)) for n in names]
                    if costs:
                        n, _ = max(costs, key=lambda t: t[1].flops)
                        walk(n, mult, path + "/cond")
                continue
            flops = hbm = 0.0
            if k == "fusion":
                m = _ATTR_CALLS_RE.search(op.line)
                if m:
                    flops = mod._fusion_flops(m.group(1))
                    hbm = mod._fusion_in_traffic(
                        m.group(1), op.operands) + mod._fusion_out_bytes(
                        m.group(1), op)
            elif k == "dot":
                flops = mod._dot_flops(op)
                hbm = mod._operand_bytes(op) + mod._out_bytes(op)
            elif k in mod._SLICERS:
                hbm = 2.0 * mod._out_bytes(op)
            elif k in ("dynamic-update-slice", "scatter"):
                upd = (_shape_bytes(mod.symbols.get(op.operands[1], ""))
                       if len(op.operands) > 1 else mod._out_bytes(op))
                hbm = 2.0 * upd
            elif k in _COLLECTIVES:
                hbm = mod._operand_bytes(op) + mod._out_bytes(op)
            else:
                hbm = mod._operand_bytes(op) + mod._out_bytes(op)
            entries.append({
                "op": op.name, "kind": k, "path": path, "mult": mult,
                "flops": flops * mult, "hbm": hbm * mult,
                "line": op.line.strip()[:160],
            })

    walk(mod.entry or "", 1.0, "entry")
    entries.sort(key=lambda e: e["hbm"], reverse=True)
    by_hbm = entries[:top]
    entries2 = sorted(entries, key=lambda e: e["flops"], reverse=True)
    return {"by_hbm": by_hbm, "by_flops": entries2[:top]}


def analyze_text(hlo_text: str, default_group: int = 1):
    mod = HloModule(hlo_text, default_group=default_group)
    cost = mod.entry_cost()
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "transcendentals": cost.transcendental,
        "collectives_by_kind": dict(cost.coll),
        "collective_link_bytes": cost.collective_link_bytes,
        "collective_payload_bytes": cost.coll_payload,
        "n_collectives": cost.n_coll,
        "unscaled_whiles": mod.unscaled_whiles,
    }
