"""Host wall-clock backend: dispatch timing around jitted blocks.

The cheapest possible "effect" counter — equivalent to the paper's use of
UNIX ``time`` for the overhead study, but per named block and feeding the
runtime's adaptive hooks (straggler detection uses the per-step series).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax


@dataclasses.dataclass
class TimingStats:
    name: str
    calls: int
    total_s: float
    mean_s: float
    p50_s: float
    p95_s: float
    max_s: float


class HostTimer:
    def __init__(self):
        self.samples: dict[str, list[float]] = {}

    def wrap(self, fn: Callable, name: str, block: bool = True) -> Callable:
        """Wrap a (possibly jitted) callable with wall-clock timing.

        ``block=True`` calls ``block_until_ready`` on the outputs so the
        measurement covers device execution, not just dispatch.
        """

        def timed(*args, **kwargs):
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            if block:
                out = jax.block_until_ready(out)
            self.samples.setdefault(name, []).append(time.perf_counter() - t0)
            return out

        return timed

    def record(self, name: str, seconds: float) -> None:
        self.samples.setdefault(name, []).append(seconds)

    def stats(self, name: str) -> TimingStats:
        xs = sorted(self.samples.get(name, []))
        if not xs:
            return TimingStats(name, 0, 0.0, 0.0, 0.0, 0.0, 0.0)
        n = len(xs)
        return TimingStats(
            name=name,
            calls=n,
            total_s=sum(xs),
            mean_s=sum(xs) / n,
            p50_s=xs[n // 2],
            p95_s=xs[min(n - 1, int(0.95 * n))],
            max_s=xs[-1],
        )

    def all_stats(self) -> list[TimingStats]:
        return [self.stats(k) for k in sorted(self.samples)]

    def outliers(self, name: str, sigma: float = 3.0) -> list[int]:
        """Indices of samples more than ``sigma`` stdevs above the median —
        the straggler-detection primitive."""
        xs = self.samples.get(name, [])
        if len(xs) < 4:
            return []
        med = statistics.median(xs)
        sd = statistics.pstdev(xs) or 1e-12
        return [i for i, x in enumerate(xs) if (x - med) / sd > sigma]


def time_compiled(fn: Callable, *args, iters: int = 10, warmup: int = 2,
                  **kwargs) -> dict[str, Any]:
    """Benchmark helper: median wall time of a callable over ``iters`` runs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return {
        "median_s": ts[len(ts) // 2],
        "min_s": ts[0],
        "mean_s": sum(ts) / len(ts),
        "iters": iters,
    }
