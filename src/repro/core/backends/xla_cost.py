"""XLA-cost backend: static FLOPs / bytes / collective traffic per program
and per scope, read from the compiled artifact.

This is the TPU-native replacement for the paper's MSR counters that count
*causes*: on a TPU the compiler knows, ahead of time, the FLOPs each fused
region executes, the HBM traffic it schedules and the collective bytes it
moves.  ``analyze()`` is also the data source of the roofline analysis
(benchmarks/roofline.py, EXPERIMENTS.md §Roofline).

Per-scope attribution works because core/instrument.py opens a
``jax.named_scope`` for every ScALPEL scope — the scope path lands in each
HLO op's ``metadata.op_name``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# ---------------------------------------------------------------------------
# dtype widths
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) + r")"
    r"\[([0-9,]*)\]"
)

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# instruction position: "%x = <shape(s)> <opname>(" or "<opname>-start("
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+("
    + "|".join(_COLLECTIVES)
    + r")(?:-start)?\("
)

_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPLICA_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shapes_in(text: str) -> list[float]:
    return [_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(text)]


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    # Per-chip bytes that traverse ICI links for this op (ring algorithm
    # estimate: see _link_bytes).
    link_bytes: float
    payload_bytes: float
    group_size: int
    scope: str  # best-effort attribution from op_name metadata


@dataclasses.dataclass
class CostReport:
    flops: float
    bytes_accessed: float
    transcendentals: float
    collectives: list[CollectiveOp]
    per_scope_flops: dict[str, float]
    memory_analysis: dict[str, float] | None = None

    @property
    def collective_link_bytes(self) -> float:
        return sum(c.link_bytes for c in self.collectives)

    @property
    def collective_payload_bytes(self) -> float:
        return sum(c.payload_bytes for c in self.collectives)

    def collective_bytes_by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0.0) + c.link_bytes
        return out


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,S]<=[N]: G groups of S participants
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


def _link_bytes(kind: str, out_bytes: float, in_bytes: float, n: int) -> float:
    """Per-chip bytes through ICI for ring-style collectives of group size n."""
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * in_bytes * f        # reduce-scatter + all-gather
    if kind == "all-gather":
        return out_bytes * f             # each chip receives all other shards
    if kind == "reduce-scatter":
        return in_bytes * f
    if kind == "all-to-all":
        return in_bytes * f
    if kind == "collective-permute":
        return in_bytes                  # point-to-point
    return in_bytes


def parse_collectives(hlo_text: str, default_group: int,
                      scopes: tuple[str, ...] = ()) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # output shapes: between '=' and the op name; operands: inside parens
        eq = line.index("=")
        op_pos = m.start(1)
        out_bytes = sum(_shapes_in(line[eq:op_pos])) or 0.0
        # operand section: from "(" after op name to end (covers operands;
        # attribute strings contain no shape tokens)
        operand_sec = line[op_pos:]
        in_bytes = sum(_shapes_in(operand_sec)) or out_bytes
        n = _group_size(line, default_group)
        scope = ""
        om = _OPNAME_RE.search(line)
        if om and scopes:
            path = om.group(1)
            for s in scopes:
                if f"/{s}" in path or path.endswith(s) or f"{s}/" in path:
                    scope = s
                    break
        ops.append(
            CollectiveOp(
                kind=kind,
                link_bytes=_link_bytes(kind, out_bytes, in_bytes, n),
                payload_bytes=max(out_bytes, in_bytes),
                group_size=n,
                scope=scope,
            )
        )
    return ops


_DOT_LINE_RE = re.compile(r"=\s*\S+\s+(dot|convolution)\(")


def per_scope_flops(hlo_text: str, scopes: tuple[str, ...]) -> dict[str, float]:
    """Best-effort attribution of dot FLOPs to ScALPEL scopes via op_name.

    XLA's cost_analysis has the authoritative total; this splits the dominant
    (dot) component by named scope so reports can say *which* scope is
    compute-heavy — the per-function view the paper insists on.
    """
    out: dict[str, float] = {s: 0.0 for s in scopes}
    for line in hlo_text.splitlines():
        if not _DOT_LINE_RE.search(line):
            continue
        om = _OPNAME_RE.search(line)
        if not om:
            continue
        path = om.group(1)
        hit = None
        for s in scopes:
            if f"/{s}/" in path or path.endswith(f"/{s}") or f"/{s}." in path:
                hit = s
                break
        if hit is None:
            continue
        # FLOPs of a dot: 2 * out_elems * contracted_dim. We do not re-derive
        # the contraction here; approximate with 2*out*k by reading operand
        # dims is fragile — instead count 2 * (in0_elems * in1_elems / shared)
        # Conservative: use 2 * sqrt(in0*in1) * sqrt(out) is wrong; so just
        # record output bytes-weighted presence. Simpler & honest: count the
        # number of dot ops per scope (weight 1); xla_cost totals stay with
        # cost_analysis.
        out[hit] = out.get(hit, 0.0) + 1.0
    return out


def analyze(compiled: Any, *, default_group: int = 1,
            scopes: tuple[str, ...] = (),
            hlo_text: str | None = None) -> CostReport:
    """Build a CostReport from a ``jax.stages.Compiled`` object."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    ca = dict(ca or {})
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text, default_group, scopes)
    scope_flops = per_scope_flops(text, scopes) if scopes else {}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_size_in_bytes": float(
                    getattr(ma, "argument_size_in_bytes", 0)
                ),
                "output_size_in_bytes": float(
                    getattr(ma, "output_size_in_bytes", 0)
                ),
                "temp_size_in_bytes": float(
                    getattr(ma, "temp_size_in_bytes", 0)
                ),
                "generated_code_size_in_bytes": float(
                    getattr(ma, "generated_code_size_in_bytes", 0)
                ),
            }
    except Exception:
        mem = None
    return CostReport(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        transcendentals=float(ca.get("transcendentals", 0.0)),
        collectives=colls,
        per_scope_flops=scope_flops,
        memory_analysis=mem,
    )
