"""Trace-time scope instrumentation — the paper's compiler-directed callbacks.

GCC planted entry/exit handlers in the object code; we plant event
computations in the traced JAX program.  Model code stays unmodified in the
paper's sense: it only names its scopes (``with scalpel.function("attn")`` or
the decorator/auto-walker) — which events run, for which scopes, with which
multiplex schedule is decided by the MonitorSpec/MonitorParams, not the model.

Execution model
---------------
* ``monitor.Monitor.wrap`` (the public API) — or the DEPRECATED
  ``collecting(spec, params, state)`` shim — opens a root Collector for a
  step.
* ``function(name)`` pushes a scope; entering a scope that is in the
  compile-time set increments its call counter *in-graph* (interception).
* ``probe(**tensors)`` evaluates the current scope's context: a ``lax.cond``
  on the runtime scope mask (un-monitored scopes pay only the predicated
  branch — the paper's cheap interception), then a ``lax.switch`` over the
  scope's event sets keyed by ``(calls // period) % n_sets`` — call-count
  multiplexing, phase-exact even inside ``lax.scan`` loops.  Each branch
  executes its compiled ``MomentPlan`` (core/plan.py): exactly the channels
  THAT event set finalizes from, swept once per probed tensor
  (kernels/probe_reduce.py — the optional ``ent_sum`` channel folds
  ATTN_ENTROPY into the same pass), landing via one batched scatter over
  the set's live slots.  A sparse active set never pays for the union.
* ``capture(fn, ...)`` runs ``fn`` under a child collector and returns
  ``(out, CounterState delta)`` — the bridge that lets ``lax.scan`` carry
  counters through stacked layers (in compact form: the scan carry sums
  only the spec's live-slot footprint, ``plan.CompactDelta``).

When no collector is active every call here is a no-op: an uninstrumented
("vanilla") program pays nothing.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from . import events as events_lib
from . import plan as plan_lib
from .context import EventSpec, MonitorSpec, ScopeContext
from .counters import CounterState, MonitorParams

_TLS = threading.local()
_KOPS = None


def _kernel_ops():
    """repro.kernels.ops, resolved once (imported lazily: kernels are an
    optional heavyweight import and must not load at repro.core import
    time), then cached so the per-probe trace path skips the module lookup.
    """
    global _KOPS
    if _KOPS is None:
        from repro.kernels import ops as _KOPS  # noqa: N811
    return _KOPS


def _stack() -> list:
    if not hasattr(_TLS, "stack"):
        _TLS.stack = []
    return _TLS.stack


def current_collector():
    st = _stack()
    return st[-1] if st else None


SEP = "/"


class Collector:
    """Accumulates an in-graph CounterState delta during tracing.

    Counter updates are COALESCED: per-call event values are collected in
    trace-time Python lists and materialized as ONE scatter-add per scope
    when the region finalizes (``delta``).  A scope probed k times per step
    costs k event computations but only one dynamic-update-slice — without
    this, the per-call scatters dominated the monitoring overhead
    (EXPERIMENTS.md §Perf, instrumentation iteration 1).

    Event evaluation is PLAN-DRIVEN: every (scope, event set) pair executes
    its compiled ``plan.MomentPlan`` — the exact channel sweep per probed
    tensor that set's slots finalize from, plus the set's bespoke slots,
    landing through one batched scatter over the set's live-slot footprint.
    ``plan_mode="union"`` widens each set's sweeps to the cross-set union
    (the pre-plan behaviour) — the benchmark baseline, not a hot path.
    """

    def __init__(self, spec: MonitorSpec, params: MonitorParams,
                 calls_base, backends: tuple = (),
                 plan_mode: str = "per_set"):
        if plan_mode not in ("per_set", "union"):
            raise ValueError(f"unknown plan_mode {plan_mode!r}")
        self.spec = spec
        self.params = params
        # calls_base: i32[n_scopes] — global call counts *before* this
        # collector's region (threading through scan carries keeps the
        # multiplex schedule exact across iterations).
        self.calls_base = calls_base
        self.scope_path: list[str] = []
        self._extended: list[bool] = []
        self.backends = backends
        self.plan_mode = plan_mode
        # deferred accumulators (trace-time); _vals/_smps hold per-scope
        # vectors of the SCOPE's width (dense plan layout), not max_slots
        self._counts: dict[int, int] = {}
        self._vals: dict[int, list] = {}
        self._smps: dict[int, list] = {}
        self._ingested: list[CounterState] = []
        self._final: CounterState | None = None

    # -- scope management -------------------------------------------------
    def push(self, name: str) -> str:
        # Paper §3.3: the context is *retained* across recursive calls to the
        # same function — direct re-entry does not open a new scope path, so
        # a recursive `foo` accumulates into one "foo" context rather than
        # foo/foo/foo (which would fall outside the compile-time set).
        if self.scope_path and self.scope_path[-1] == name:
            self._extended.append(False)
            return SEP.join(self.scope_path)
        self.scope_path.append(name)
        self._extended.append(True)
        return SEP.join(self.scope_path)

    def pop(self) -> None:
        if self._extended.pop():
            self.scope_path.pop()

    @property
    def current_scope(self) -> str:
        return SEP.join(self.scope_path)

    # -- in-graph counter updates -----------------------------------------
    def _counts_arrays(self):
        idxs = sorted(self._counts)
        return (
            jnp.asarray(idxs, jnp.int32),
            jnp.asarray([self._counts[i] for i in idxs], jnp.int32),
        )

    def total_calls(self):
        c = self.calls_base
        for d in self._ingested:
            c = c + d.calls
        if self._counts:
            idxs, cnts = self._counts_arrays()
            c = c.at[idxs].add(cnts)
        return c

    def intercept(self, scope: str) -> None:
        """Count a call of ``scope`` (always-on, cheap — paper's 'all').

        The count is a trace-time Python increment — interception of
        statically-unrolled calls is FREE in the compiled program (one
        scatter of constants at region exit)."""
        if scope not in self.spec:
            return
        idx = self.spec.scope_index(scope)
        self._counts[idx] = self._counts.get(idx, 0) + 1
        self._final = None

    def probe(self, scope: str, tensors: dict[str, Any]) -> None:
        if scope not in self.spec:
            return
        idx = self.spec.scope_index(scope)
        ctx = self.spec.context(scope)
        if not ctx.slots:
            return
        params = self.params
        # call count *before* this call was intercepted (python-side count
        # of prior interceptions in this region + carried base).
        calls_here = self.calls_base[idx] + (self._counts.get(idx, 1) - 1)

        tensors = {k: jax.lax.stop_gradient(v) for k, v in tensors.items()}
        # Compile (or fetch the cached) per-set plans for this probe call:
        # a scope may probe several times per invocation with different
        # tensors, so plans are keyed on the available tensor names too.
        plans = plan_lib.compile_scope_plans(
            ctx, frozenset(tensors), self.plan_mode == "union"
        )
        if not plans.any_live:
            return
        w = plans.width

        def _body_branch(pl):
            # ``pl`` is a deduped branch BODY: it fixes the computation
            # (slot events, exact sweeps) while the scatter indices arrive
            # as data (``midx``), so sets that do identical work over
            # different slots share one traced branch.
            def br(ops):
                ts, midx = ops
                vals = jnp.zeros((w,), jnp.float32)
                smp = jnp.zeros((w,), jnp.int32)
                if not pl.slots:
                    return vals, smp
                # THIS set's sweeps only: each probed tensor is read once,
                # computing exactly the channels this set finalizes from
                # (sets-dependent graphs are the price; only the selected
                # branch executes at run time).
                _kops = _kernel_ops()
                moms = {
                    sw.tensor: _kops.tensor_moments(ts[sw.tensor],
                                                    sw.channels)
                    for sw in pl.sweeps
                }
                vs = []
                for s in pl.slots:
                    if s.fused:
                        vs.append(events_lib.finalize_event(
                            ctx.slots[s.index], moms[s.tensor]
                        ))
                    else:
                        vs.append(events_lib.compute(ctx.slots[s.index], ts))
                # one batched scatter over the set's live-slot footprint
                idxs = midx[: len(pl.slots)]
                sms = params.slot_mask[idx, idxs]
                vals = vals.at[idxs].set(jnp.stack(vs) * sms)
                smp = smp.at[idxs].set((sms > 0).astype(jnp.int32))
                return vals, smp

            return br

        def _monitored(ts):
            if ctx.n_sets == 1:
                pl = plans.plans[0]
                midx = jnp.asarray(pl.members, jnp.int32)
                return _body_branch(pl)((ts, midx))
            set_idx = (calls_here // jnp.maximum(params.period[idx], 1)) % ctx.n_sets
            midx = jnp.asarray(plans.member_table, jnp.int32)[set_idx]
            if plans.n_branches == 1:
                # every set runs the same body; only the scatter footprint
                # (already selected into ``midx``) differs — no switch at all
                return _body_branch(plans.bodies[0])((ts, midx))
            bidx = jnp.asarray(plans.branch_index, jnp.int32)[set_idx]
            return jax.lax.switch(
                bidx, [_body_branch(b) for b in plans.bodies], (ts, midx)
            )

        def _skipped(ts):
            del ts
            return jnp.zeros((w,), jnp.float32), jnp.zeros((w,), jnp.int32)

        vals, smp = jax.lax.cond(
            params.scope_mask[idx] > 0, _monitored, _skipped, tensors
        )
        self._vals.setdefault(idx, []).append(vals)
        self._smps.setdefault(idx, []).append(smp)
        self._final = None

    def ingest(self, delta) -> None:
        """Fold a child region's delta (e.g. a scan's summed carry).

        Accepts either layout — a padded ``CounterState`` or a compact
        ``plan.CompactDelta`` — and defers the conversion to whichever
        finalization runs: ``compact_delta()`` keeps compact ingests
        compact (a scan feeding a Monitor-wrapped step never touches the
        padded block), while ``delta`` expands them once."""
        self._ingested.append(delta)
        self._final = None

    # -- finalization -------------------------------------------------------
    @property
    def delta(self) -> CounterState:
        """The region's CounterState delta (coalesced, built lazily)."""
        if self._final is not None:
            return self._final
        n, m = self.spec.n_scopes, self.spec.max_slots
        calls = jnp.zeros((n,), jnp.int32)
        if self._counts:
            idxs, cnts = self._counts_arrays()
            calls = calls.at[idxs].add(cnts)
        values = jnp.zeros((n, m), jnp.float32)
        samples = jnp.zeros((n, m), jnp.int32)
        for idx, lst in self._vals.items():
            tot = lst[0]
            for v in lst[1:]:
                tot = tot + v
            values = values.at[idx, : tot.shape[0]].add(tot)
        for idx, lst in self._smps.items():
            tot = lst[0]
            for v in lst[1:]:
                tot = tot + v
            samples = samples.at[idx, : tot.shape[0]].add(tot)
        d = CounterState(calls=calls, values=values, samples=samples)
        for ing in self._ingested:
            if isinstance(ing, plan_lib.CompactDelta):
                ing = ing.expand(self.spec)
            d = d.add(ing)
        self._final = d
        return d

    def compact_delta(self) -> plan_lib.CompactDelta:
        """The region's delta in the dense slot layout (plan.SlotLayout).

        The scan-carry form: ``lax.scan`` bodies sum only the spec's
        live-slot footprint per iteration and expand to a full CounterState
        once at region exit (scan_with_counters) — instead of carrying the
        padded ``[n_scopes, max_slots]`` block through every iteration.
        """
        lay = plan_lib.spec_layout(self.spec)
        n = self.spec.n_scopes
        calls = jnp.zeros((n,), jnp.int32)
        if self._counts:
            idxs, cnts = self._counts_arrays()
            calls = calls.at[idxs].add(cnts)
        values = jnp.zeros((lay.total,), jnp.float32)
        samples = jnp.zeros((lay.total,), jnp.int32)
        for idx, lst in self._vals.items():
            tot = lst[0]
            for v in lst[1:]:
                tot = tot + v
            off = lay.offsets[idx]
            values = values.at[off : off + tot.shape[0]].add(tot)
        for idx, lst in self._smps.items():
            tot = lst[0]
            for v in lst[1:]:
                tot = tot + v
            off = lay.offsets[idx]
            samples = samples.at[off : off + tot.shape[0]].add(tot)
        d = plan_lib.CompactDelta(calls=calls, values=values, samples=samples)
        for ing in self._ingested:
            if not isinstance(ing, plan_lib.CompactDelta):
                ing = plan_lib.CompactDelta.compress(self.spec, ing)
            d = d.add(ing)
        return d


class DiscoveryCollector:
    """Records scope/probe structure without computing anything.

    Used under ``jax.eval_shape`` to enumerate the compile-time set — the
    analogue of the paper's 'instrument all functions' compiler pass.
    """

    def __init__(self):
        self.scope_path: list[str] = []
        self._extended: list[bool] = []
        self.seen: dict[str, tuple[str, ...]] = {}

    def push(self, name: str) -> str:
        if self.scope_path and self.scope_path[-1] == name:
            self._extended.append(False)
        else:
            self.scope_path.append(name)
            self._extended.append(True)
        scope = SEP.join(self.scope_path)
        self.seen.setdefault(scope, ())
        return scope

    def pop(self) -> None:
        if self._extended.pop():
            self.scope_path.pop()

    @property
    def current_scope(self) -> str:
        return SEP.join(self.scope_path)

    def intercept(self, scope: str) -> None:
        self.seen.setdefault(scope, ())

    def probe(self, scope: str, tensors: dict[str, Any]) -> None:
        old = self.seen.get(scope, ())
        merged = tuple(dict.fromkeys(list(old) + sorted(tensors)))
        self.seen[scope] = merged

    def ingest(self, delta) -> None:  # pragma: no cover - structure only
        del delta

    total_calls = None  # discovery has no call counts


# --------------------------------------------------------------------------
# Public API used by model / application code.
# --------------------------------------------------------------------------

@contextlib.contextmanager
def collecting(spec: MonitorSpec, params: MonitorParams,
               state: CounterState | None = None, *,
               plan_mode: str = "per_set"):
    """DEPRECATED: open a root collection region; yields the Collector.

    This is the legacy hand-threaded API — every call site must fold
    ``col.delta`` into its own carried CounterState.  New code should use
    the functional ``scalpel.Monitor`` transformation (core/monitor.py):
    ``mon.wrap(step_fn)`` threads one MonitorState pytree (compact
    counters, telemetry ring, step stamp, params) automatically and
    cross-device-reduces over the mesh.  ``collecting`` survives as a thin
    shim over ``Monitor.open`` for existing call sites and as the manual
    baseline the overhead benchmark measures ``Monitor.wrap`` against; see
    the migration table in README.md.

    ``state`` supplies the call-count base so multiplex schedules continue
    across steps.  ``plan_mode="union"`` compiles every event set against
    the cross-set channel union (the pre-plan probe behaviour) — the
    benchmark baseline, not a hot path.
    """
    import warnings

    from . import monitor as monitor_lib

    warnings.warn(
        "scalpel.collecting() is deprecated; use scalpel.Monitor(spec).wrap"
        "(step_fn) (or @scalpel.monitored) — see the README migration table",
        DeprecationWarning, stacklevel=3,
    )
    mon = monitor_lib.Monitor(spec, params=params, counter_axes=(),
                              plan_mode=plan_mode)
    with mon.open(params, calls_base=state.calls if state is not None
                  else None) as col:
        yield col


@contextlib.contextmanager
def discovering():
    col = DiscoveryCollector()
    _stack().append(col)
    try:
        yield col
    finally:
        _stack().pop()


@contextlib.contextmanager
def breakpoint_mode(monitor=None, scopes=None):
    """'Perfmon mode': every scope entry/exit fires a host round-trip.

    Deliberately reproduces the ptrace/breakpoint technique the paper
    measures against (perfmon was 2-3 orders of magnitude slower than
    compiler-directed callbacks).  Must be active while the step is TRACED
    so the ``io_callback``s are planted in the graph.  ``scopes``: restrict
    breakpoints to the named scopes (None = all).
    """
    from .backends import host_callback as hc

    prev = getattr(_TLS, "bp", None)
    _TLS.bp = (monitor or hc.global_monitor(),
               frozenset(scopes) if scopes else None)
    try:
        yield _TLS.bp[0]
    finally:
        _TLS.bp = prev


def _fire_breakpoint(name: str, edge: str) -> None:
    bp = getattr(_TLS, "bp", None)
    if bp is None:
        return
    monitor, only = bp
    if only is not None and name not in only:
        return
    from .backends import host_callback as hc

    hc.breakpoint_probe(f"{name}@{edge}", 0.0, monitor)


@contextlib.contextmanager
def function(name: str):
    """Scope context manager — the entry/exit callback pair (paper C1).

    Entering counts one interception of the full scope path.  Also opens a
    ``jax.named_scope`` so the scope name lands in HLO op metadata, which the
    xla_cost backend uses for per-scope static cost attribution.
    """
    _fire_breakpoint(name, "entry")
    col = current_collector()
    if col is None:
        try:
            yield None
        finally:
            _fire_breakpoint(name, "exit")
        return
    scope = col.push(name)
    try:
        with jax.named_scope(name):
            col.intercept(scope)
            yield scope
    finally:
        col.pop()
        _fire_breakpoint(name, "exit")


def probe(**tensors) -> None:
    """Evaluate the current scope's monitoring context on named tensors."""
    col = current_collector()
    if col is None:
        return
    col.probe(col.current_scope, tensors)


def probe_scope(name: str, **tensors) -> None:
    """One-shot scope: function(name) + probe(**tensors)."""
    with function(name):
        probe(**tensors)


def instrument(fn: Callable, name: str, probes: Callable | None = None):
    """Wrap ``fn`` so each call is an intercepted scope (decorator form).

    ``probes(out, *args, **kwargs) -> dict`` optionally derives probe tensors
    from the call; by default the output tensor is probed as 'out'.
    """

    def wrapped(*args, **kwargs):
        with function(name):
            out = fn(*args, **kwargs)
            if current_collector() is not None:
                if probes is not None:
                    t = probes(out, *args, **kwargs)
                else:
                    t = {"out": out} if isinstance(out, jax.Array) else {}
                if t:
                    probe(**t)
            return out

    wrapped.__name__ = f"scalpel[{name}]"
    return wrapped


def capture(fn: Callable, calls_base=None, compact: bool = False):
    """Run ``fn`` under a child collector; returns ``fn' -> (out, delta)``.

    The bridge for ``lax.scan``: the scan body wraps its work in ``capture``
    with ``calls_base = outer_base + carried_delta.calls`` so call-count
    multiplexing stays exact across iterations.  ``compact=True`` returns
    the delta as a ``plan.CompactDelta`` (the dense live-slot layout) — the
    form scan carries sum per iteration.
    """
    parent = current_collector()

    def run(*args, **kwargs):
        if parent is None or isinstance(parent, DiscoveryCollector):
            # Discovery or vanilla: no counters; keep structure cheap.
            if isinstance(parent, DiscoveryCollector):
                out = fn(*args, **kwargs)
                return out, None
            return fn(*args, **kwargs), None
        base = calls_base if calls_base is not None else parent.total_calls()
        child = Collector(parent.spec, parent.params, calls_base=base,
                          plan_mode=parent.plan_mode)
        child.scope_path = list(parent.scope_path)
        _stack().append(child)
        try:
            out = fn(*args, **kwargs)
        finally:
            _stack().pop()
        return out, (child.compact_delta() if compact else child.delta)

    return run


def scan_with_counters(body: Callable, init, xs, length: int | None = None,
                       unroll: int | bool = 1, remat=None):
    """``lax.scan`` that threads ScALPEL counters through the carry.

    ``body(carry, x) -> (carry, y)`` is ordinary scan-body code that may call
    ``function``/``probe``.  Counter deltas from every iteration are summed
    and folded into the ambient collector.  With no active collector this is
    a plain ``lax.scan``.

    ``remat`` (optional): a rematerialization decorator (e.g.
    ``jax.checkpoint`` with a policy).  It is applied *inside* the counter
    capture so the counter delta is an explicit output of the checkpointed
    region — counters never leak across the remat boundary.

    The per-iteration delta rides the carry in COMPACT form
    (``plan.CompactDelta``): the scan sums only the spec's live-slot
    footprint each step — the dense slot layout the probe-plan layer
    compiles — and expands to a full ``CounterState`` once, at scan exit.
    """
    col = current_collector()
    if col is None or isinstance(col, DiscoveryCollector):
        b = body if remat is None else (lambda c, x: remat(body)(c, x))
        return jax.lax.scan(b, init, xs, length=length, unroll=unroll)

    spec = col.spec
    base = col.total_calls()

    def work(inner, x, calls_base):
        run = capture(lambda: body(inner, x), calls_base=calls_base,
                      compact=True)
        (inner2, y), d = run()
        return inner2, y, d

    if remat is not None:
        work = remat(work)

    def wrapped(carry, x):
        inner, dsum = carry
        inner2, y, d = work(inner, x, base + dsum.calls)
        return (inner2, dsum.add(d)), y

    (out, dtotal), ys = jax.lax.scan(
        wrapped, (init, plan_lib.CompactDelta.zeros(spec)), xs,
        length=length, unroll=unroll,
    )
    # ingest the summed carry in COMPACT form: a collector finalized
    # compactly (Monitor.wrap) never materializes the padded block at all;
    # the legacy padded delta expands it once here instead.
    col.ingest(dtotal)
    return out, ys


# --------------------------------------------------------------------------
# Discovery — build the compile-time set by walking the traced program.
# --------------------------------------------------------------------------

def discover(fn: Callable, *args, **kwargs) -> dict[str, tuple[str, ...]]:
    """Trace ``fn`` abstractly and return {scope: probed tensor names}."""
    with discovering() as col:
        jax.eval_shape(fn, *args, **kwargs)
    return dict(col.seen)


DEFAULT_TENSOR_EVENTS = ("ACT_RMS", "ACT_MEAN_ABS")


def spec_from_discovery(
    seen: dict[str, tuple[str, ...]],
    tensor_events: Sequence[str] = DEFAULT_TENSOR_EVENTS,
    include: Callable[[str], bool] | None = None,
) -> MonitorSpec:
    """Auto-build a MonitorSpec: every discovered scope becomes interceptable,
    every probed tensor gets the generic ``tensor_events`` — the analogue of
    compiling with '-finstrument-functions' on everything."""
    ctxs = []
    for scope, tnames in sorted(seen.items()):
        if include is not None and not include(scope):
            continue
        slots = [
            EventSpec(event=ev, tensor=t)
            for t in tnames
            for ev in tensor_events
        ]
        ctxs.append(ScopeContext.exhaustive(scope, slots))
    return MonitorSpec.of(ctxs)
