"""Reporting: turn raw CounterState into per-scope counter reports.

Reproduces the paper's reporting semantics: results are the function (scope)
name, the events and their counter values (§3.3), written to stdout on
termination by default, with the multiplexed→exhaustive estimate used in the
case study (Fig. 4): an event monitored on ``samples`` of ``calls`` calls is
scaled to an exhaustive estimate by ``calls/samples`` if EXTENSIVE (counts)
or reported as the per-call mean ``value/samples`` if INTENSIVE.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

from . import events as events_lib
from .context import MonitorSpec


@dataclasses.dataclass
class SlotReport:
    slot_id: str
    kind: str
    raw: float          # accumulated value
    samples: int        # calls on which the slot was computed
    calls: int          # total interceptions of the scope
    estimate: float     # exhaustive estimate (extensive) or per-call mean

    @property
    def coverage(self) -> float:
        return self.samples / self.calls if self.calls else 0.0


@dataclasses.dataclass
class ScopeReport:
    scope: str
    calls: int
    slots: list[SlotReport]


def build(spec: MonitorSpec, state) -> list[ScopeReport]:
    """Per-scope reports from any counter carrier.

    Accepts the legacy padded ``CounterState`` ([n_scopes, max_slots]
    values) or any compact dense-layout carrier — ``plan.CompactDelta``,
    ``MonitorState``, drained compact telemetry snapshots — whose flat
    ``values``/``samples`` lanes are read DIRECTLY through the spec's
    ``SlotLayout``: no expansion to the padded block anywhere on the
    reporting path.
    """
    calls = np.asarray(state.calls)
    values = np.asarray(state.values)
    samples = np.asarray(state.samples)
    offsets = None
    if values.ndim == 1:  # compact dense layout
        from . import plan as plan_lib

        offsets = plan_lib.spec_layout(spec).offsets
    out: list[ScopeReport] = []
    for si, ctx in enumerate(spec.contexts):
        srs: list[SlotReport] = []
        for i, slot in enumerate(ctx.slots):
            kind = events_lib.kind_of(slot)
            if offsets is not None:
                raw = float(values[offsets[si] + i])
                smp = int(samples[offsets[si] + i])
            else:
                raw = float(values[si, i])
                smp = int(samples[si, i])
            c = int(calls[si])
            if smp == 0:
                est = float("nan")
            elif kind == events_lib.EXTENSIVE:
                est = raw * (c / smp)
            else:
                est = raw / smp
            srs.append(
                SlotReport(
                    slot_id=slot.slot_id, kind=kind, raw=raw,
                    samples=smp, calls=c, estimate=est,
                )
            )
        out.append(ScopeReport(scope=ctx.scope, calls=int(calls[si]), slots=srs))
    return out


def format_text(reports: list[ScopeReport], title: str = "ScALPEL report") -> str:
    lines = [f"=== {title} ==="]
    for r in reports:
        lines.append(f"[{r.scope}] calls={r.calls}")
        for s in r.slots:
            lines.append(
                f"  {s.slot_id:<32s} {s.kind:<9s} est={s.estimate:.6e} "
                f"raw={s.raw:.6e} samples={s.samples} "
                f"coverage={s.coverage:.2%}"
            )
    return "\n".join(lines)


def to_json(reports: list[ScopeReport]) -> str:
    def enc(o: Any):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        raise TypeError(type(o))

    return json.dumps([dataclasses.asdict(r) for r in reports], indent=1,
                      default=enc)


class JsonlWriter:
    """Buffered JSONL report writer: one open handle, amortized writes.

    ``write_jsonl``'s open-per-call made the report path part of the step
    loop's critical path; the telemetry plane's JsonlSink keeps one of these
    on the drain thread instead.  Lines are buffered until ``buffer_lines``
    accumulate (0 = write through), flushed on ``flush()``/``close()``.
    """

    def __init__(self, path: str, buffer_lines: int = 64):
        self.path = path
        self.buffer_lines = max(0, int(buffer_lines))
        self._buf: list[str] = []
        self._f = open(path, "a")

    def write(self, step: int, reports: list[ScopeReport],
              plan: str | None = None) -> None:
        """Append one line per scope report.  ``plan``: the producing spec's
        plan fingerprint (MonitorSpec.fingerprint) — recorded per line so a
        counter stream spanning config hot-swaps stays attributable to the
        compiled probe plans that measured it."""
        for r in reports:
            row = {
                "step": step,
                "scope": r.scope,
                "calls": r.calls,
                "slots": [dataclasses.asdict(s) for s in r.slots],
            }
            if plan is not None:
                row["plan"] = plan
            self._buf.append(json.dumps(row))
        if len(self._buf) > self.buffer_lines:
            self._drain()

    def _drain(self) -> None:
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._buf.clear()

    def flush(self) -> None:
        if self._f.closed:
            return
        self._drain()
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self.flush()
            self._f.close()

    def __enter__(self) -> "JsonlWriter":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def write_jsonl(path: str, step: int, reports: list[ScopeReport]) -> None:
    """One-shot convenience (opens/closes the file per call); prefer
    ``JsonlWriter``/``telemetry.JsonlSink`` anywhere near a hot path."""
    with JsonlWriter(path, buffer_lines=0) as w:
        w.write(step, reports)


def estimates(spec: MonitorSpec, state) -> dict[str, dict[str, float]]:
    """{scope: {slot_id: exhaustive estimate}} — handy for assertions.
    ``state``: any carrier ``build`` accepts (padded or compact)."""
    return {
        r.scope: {s.slot_id: s.estimate for s in r.slots}
        for r in build(spec, state)
    }
