"""Monitoring contexts — the static ("compile-time set") half of ScALPEL.

The paper defines a *context* per monitored function: the function name, its
events and subevents (Table 1).  Here a "function" is a named scope of the
traced JAX program and a context enumerates the event *slots* computed for
that scope plus their grouping into multiplexed *event sets* (§3.2/§4.2 of
the paper: event sets are cycled every N calls of the scope).

Everything in this module is static/hashable: it determines the traced graph.
The runtime-mutable half (masks, periods) lives in ``counters.MonitorParams``
and can change *without* re-tracing — the paper's compile-time-set /
runtime-subset split (C2).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class EventSpec:
    """One event slot: an event id plus optional subevent qualifier.

    ``event`` names an entry in the event registry (events.py).  ``tensor``
    optionally names the probe tensor the event applies to (the paper's
    events are bound to whatever the counter hardware observes; ours bind to
    a named intermediate tensor).  ``subevent`` selects a component for
    multi-valued events (paper's [SUBEVENT] blocks).
    """

    event: str
    tensor: str = ""
    subevent: str = ""

    @property
    def slot_id(self) -> str:
        sid = self.event
        if self.tensor:
            sid += f":{self.tensor}"
        if self.subevent:
            sid += f"/{self.subevent}"
        return sid

    @staticmethod
    def parse(slot_id: str) -> "EventSpec":
        sub = ""
        if "/" in slot_id:
            slot_id, sub = slot_id.split("/", 1)
        tensor = ""
        if ":" in slot_id:
            slot_id, tensor = slot_id.split(":", 1)
        return EventSpec(event=slot_id, tensor=tensor, subevent=sub)


@dataclasses.dataclass(frozen=True)
class ScopeContext:
    """Per-scope monitoring context (paper: [FUNCTION] block).

    ``event_sets`` partitions the slots for call-count multiplexing; a scope
    with a single event set is monitored exhaustively.  ``default_period`` is
    only the initial multiplex period — the live period is runtime-mutable
    (MonitorParams.period).
    """

    scope: str
    slots: tuple[EventSpec, ...]
    event_sets: tuple[tuple[int, ...], ...]  # indices into ``slots``
    default_period: int = 1

    def __post_init__(self):
        seen: set[int] = set()
        for s in self.event_sets:
            for i in s:
                if i >= len(self.slots) or i < 0:
                    raise ValueError(
                        f"event set index {i} out of range for scope {self.scope}"
                    )
                if i in seen:
                    raise ValueError(
                        f"slot {i} appears in more than one event set "
                        f"(scope {self.scope})"
                    )
                seen.add(i)
        if len(seen) != len(self.slots):
            raise ValueError(
                f"event sets must cover every slot exactly once (scope {self.scope})"
            )

    @property
    def n_sets(self) -> int:
        return len(self.event_sets)

    @property
    def slot_ids(self) -> tuple[str, ...]:
        return tuple(s.slot_id for s in self.slots)

    @staticmethod
    def exhaustive(scope: str, slots: Sequence[EventSpec]) -> "ScopeContext":
        slots = tuple(slots)
        return ScopeContext(
            scope=scope,
            slots=slots,
            event_sets=(tuple(range(len(slots))),) if slots else ((),),
        )

    @staticmethod
    def multiplexed(
        scope: str,
        sets: Sequence[Sequence[EventSpec]],
        period: int = 1,
    ) -> "ScopeContext":
        flat: list[EventSpec] = []
        idx_sets: list[tuple[int, ...]] = []
        for s in sets:
            idxs = []
            for ev in s:
                idxs.append(len(flat))
                flat.append(ev)
            idx_sets.append(tuple(idxs))
        return ScopeContext(
            scope=scope,
            slots=tuple(flat),
            event_sets=tuple(idx_sets),
            default_period=period,
        )


@dataclasses.dataclass(frozen=True)
class MonitorSpec:
    """The compile-time monitoring set: every interceptable scope + context.

    A scope listed here with an empty context is *intercepted* (calls are
    counted — the paper's "all" mode) but computes no events until a context
    says otherwise.  Scopes not listed here are invisible; adding them
    requires a re-trace — exactly the paper's "new functions can be added as
    long as they are from the set specified at compile time".
    """

    contexts: tuple[ScopeContext, ...]

    def __post_init__(self):
        names = [c.scope for c in self.contexts]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate scope names in spec: {names}")

    # -- lookups ---------------------------------------------------------
    @property
    def scopes(self) -> tuple[str, ...]:
        return tuple(c.scope for c in self.contexts)

    @property
    def n_scopes(self) -> int:
        return len(self.contexts)

    @property
    def max_slots(self) -> int:
        return max((len(c.slots) for c in self.contexts), default=0) or 1

    def scope_index(self, scope: str) -> int:
        try:
            return self.scopes.index(scope)
        except ValueError:
            raise KeyError(
                f"scope {scope!r} is not in the compile-time set {self.scopes}"
            ) from None

    def context(self, scope: str) -> ScopeContext:
        return self.contexts[self.scope_index(scope)]

    def __contains__(self, scope: str) -> bool:
        return scope in self.scopes

    def slot_index(self, scope: str, slot_id: str) -> int:
        ctx = self.context(scope)
        try:
            return ctx.slot_ids.index(slot_id)
        except ValueError:
            raise KeyError(f"slot {slot_id!r} not in scope {scope!r}") from None

    # -- construction helpers -------------------------------------------
    @staticmethod
    def of(contexts: Sequence[ScopeContext]) -> "MonitorSpec":
        return MonitorSpec(contexts=tuple(contexts))

    def with_context(self, ctx: ScopeContext) -> "MonitorSpec":
        """Replace (or append) the context for ``ctx.scope``."""
        out = [c for c in self.contexts if c.scope != ctx.scope]
        out.append(ctx)
        return MonitorSpec(contexts=tuple(out))

    @property
    def layout(self):
        """The spec-wide dense slot layout (plan.SlotLayout) — the lane
        order every compact counter carrier (MonitorState, CompactDelta,
        compact telemetry rings) uses."""
        from . import plan as plan_lib  # lazy: plan imports this module

        return plan_lib.spec_layout(self)

    def slot_lane(self, scope: str, slot_id: str) -> int:
        """Flat dense-layout lane of one slot — index straight into a
        compact carrier's ``values``/``samples`` vectors."""
        si = self.scope_index(scope)
        return self.layout.offsets[si] + self.slot_index(scope, slot_id)

    @property
    def fingerprint(self) -> str:
        """Stable hash over this spec's compiled probe plans (plan.py).

        Two specs with equal fingerprints trace identical probe graphs;
        runtime mask/period swaps never change it — the attestation that a
        config hot-swap re-selected plans without re-tracing anything.
        """
        from . import plan as plan_lib  # lazy: plan imports this module

        return plan_lib.spec_fingerprint(self)

    def describe(self) -> str:
        lines = []
        for c in self.contexts:
            lines.append(
                f"{c.scope}: {len(c.slots)} slots, {c.n_sets} event set(s), "
                f"period {c.default_period}"
            )
            for k, s in enumerate(c.event_sets):
                ids = ", ".join(c.slots[i].slot_id for i in s)
                lines.append(f"  set {k}: [{ids}]")
        return "\n".join(lines)


def spec_from_mapping(
    mapping: Mapping[str, Sequence[Sequence[str]] | Sequence[str]],
    periods: Mapping[str, int] | None = None,
) -> MonitorSpec:
    """Build a MonitorSpec from ``{scope: [slot_ids...]}`` (exhaustive) or
    ``{scope: [[set0 ids...], [set1 ids...]]}`` (multiplexed)."""
    periods = dict(periods or {})
    ctxs = []
    for scope, spec in mapping.items():
        spec = list(spec)
        if spec and isinstance(spec[0], (list, tuple)):
            sets = [[EventSpec.parse(s) for s in group] for group in spec]
            ctxs.append(
                ScopeContext.multiplexed(scope, sets, period=periods.get(scope, 1))
            )
        else:
            ctxs.append(
                ScopeContext.exhaustive(scope, [EventSpec.parse(s) for s in spec])
            )
    return MonitorSpec.of(ctxs)
