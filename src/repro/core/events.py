"""In-graph event library — two-stage: raw moments, then scalar finalizers.

The paper reads MSR-backed counters (DTLB_MISSES, L2_LINES_IN, RESOURCE_STALLS
...) through libpfm.  On a TPU there is no user-readable MSR file, but the
*causes* the paper is after are visible to the compiler (FLOPs / bytes /
collective traffic — see backends/xla_cost.py) and to the program itself:
statistics of the live tensors flowing through each scope.  This module is the
registry of those in-graph events.

Architecture (stage 1 → stage 2)
--------------------------------
Most events are statistics of ONE probed tensor, and every one of them is a
cheap scalar function of a shared raw *channel vector*: the sweep channels

    [sum, sum_sq, sum_abs, max_abs, zero_count, nan_count, inf_count,
     ent_sum]

(kernels/probe_reduce.py — one fused pass over the tensor, Pallas on TPU;
``ent_sum`` is the optional entropy channel) plus the trace-time-constant
channels ``numel``/``rows`` that cost nothing.  Such *moment-derived* events
declare the channels they need (``moments=``) plus a *finalizer*
``(moments: dict) -> f32 scalar``, e.g. ``ACT_RMS = sqrt(sum_sq / numel)``.

This registry only declares PER-SLOT requirements; grouping them into the
per-(scope, event set) sweep a probe call actually performs is the job of
the probe-plan compiler (core/plan.py): each event set sweeps exactly the
channels ITS slots need, never the union across sets — a scope probing six
activation statistics reads its tensor from HBM once, and a sparse active
set pays only for its own channels.  Events that are NOT per-tensor channel
functions (MOE_LOAD, SSM_STATE_RMS, ...) keep their bespoke ``fn`` path.

Every event also keeps a direct (unfused) implementation ``fn: (tensor |
tensors-dict) -> f32 scalar`` — the numerical reference the planned path is
checked against (allclose: accumulation order differs between the fused
single pass and independent reductions — benchmarks/overhead.py,
tests/test_probe_reduce, tests/test_plan).

Events are tagged EXTENSIVE (accumulates by summation across calls: counts,
bytes, flops) or INTENSIVE (accumulates as a mean across monitored calls:
rms, entropy, fractions).  report.py uses the tag to turn multiplexed samples
back into exhaustive estimates, reproducing the paper's Fig. 4 methodology.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax.numpy as jnp
import numpy as np

from .context import EventSpec

Array = jnp.ndarray

EXTENSIVE = "extensive"
INTENSIVE = "intensive"

# Canonical channel vocabulary.  SWEEP_CHANNELS need a pass over the data
# (kernels/probe_reduce computes them in one fused sweep; ``ent_sum`` is the
# optional entropy channel); STATIC_CHANNELS are trace-time constants of the
# tensor's shape and are always free.  kernels/probe_reduce mirrors this
# vocabulary — tests assert the two stay in sync.
SWEEP_CHANNELS = (
    "sum",
    "sum_sq",
    "sum_abs",
    "max_abs",
    "zero_count",
    "nan_count",
    "inf_count",
    "ent_sum",
)
STATIC_CHANNELS = ("numel", "rows")
CHANNELS = SWEEP_CHANNELS + STATIC_CHANNELS


@dataclasses.dataclass(frozen=True)
class EventDef:
    name: str
    kind: str  # EXTENSIVE | INTENSIVE
    fn: Callable[..., Array]  # (tensor) or (tensors-dict) — see wants_dict
    wants_dict: bool = False  # True: fn(tensors, subevent); False: fn(tensor)
    subevents: tuple[str, ...] = ()
    requires: tuple[str, ...] = ()  # probe tensor names a dict-event needs
    # stage-2 half of moment-derived events: which raw moments stage 1 must
    # provide, and the scalar finalizer over them.  Empty/None = bespoke.
    moments: tuple[str, ...] = ()
    finalize: Callable[[Mapping[str, Array]], Array] | None = None
    doc: str = ""


_REGISTRY: dict[str, EventDef] = {}


def register(
    name: str,
    kind: str,
    *,
    wants_dict: bool = False,
    subevents: tuple[str, ...] = (),
    requires: tuple[str, ...] = (),
    moments: tuple[str, ...] = (),
    finalize: Callable[[Mapping[str, Array]], Array] | None = None,
    doc: str = "",
):
    unknown = set(moments) - set(CHANNELS)
    if unknown:
        raise ValueError(f"event {name!r}: unknown channels {sorted(unknown)}")
    if bool(moments) != (finalize is not None):
        raise ValueError(
            f"event {name!r}: moments and finalize must be given together"
        )
    if moments and wants_dict:
        raise ValueError(f"event {name!r}: dict events cannot be moment-derived")

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"event {name!r} already registered")
        _REGISTRY[name] = EventDef(
            name=name, kind=kind, fn=fn, wants_dict=wants_dict,
            subevents=subevents, requires=requires, moments=moments,
            finalize=finalize, doc=doc,
        )
        return fn

    return deco


def computable(spec: EventSpec, tensor_names) -> bool:
    """Can this slot be evaluated from a probe call providing ``tensor_names``?

    A scope may issue several probe() calls per invocation (e.g. MoE probes
    router stats mid-block and 'out' at the end); each call computes only the
    slots its tensors satisfy.
    """
    ev = lookup(spec.event)
    names = set(tensor_names)
    if ev.wants_dict:
        return all(r in names for r in ev.requires)
    if spec.tensor:
        return spec.tensor in names
    return len(names) == 1


def lookup(name: str) -> EventDef:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown event {name!r}; known: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


def registered() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def kind_of(spec: EventSpec) -> str:
    return lookup(spec.event).kind


def compute(spec: EventSpec, tensors: dict[str, Array]) -> Array:
    """Evaluate one event slot on the probed tensors (traced)."""
    ev = lookup(spec.event)
    if ev.wants_dict:
        val = ev.fn(tensors, spec.subevent)
    else:
        if spec.tensor:
            if spec.tensor not in tensors:
                raise KeyError(
                    f"event {spec.slot_id}: probe tensor {spec.tensor!r} not "
                    f"provided (have {sorted(tensors)})"
                )
            x = tensors[spec.tensor]
        else:
            if len(tensors) != 1:
                raise KeyError(
                    f"event {spec.event} needs an explicit ':tensor' qualifier "
                    f"when the scope probes multiple tensors {sorted(tensors)}"
                )
            (x,) = tensors.values()
        val = ev.fn(x)
    return jnp.asarray(val, jnp.float32)


# --------------------------------------------------------------------------
# Two-stage evaluation helpers — consumed by the probe-plan compiler
# (core/plan.py) and the planned probe path (instrument.Collector.probe).
# --------------------------------------------------------------------------

def moment_based(spec: EventSpec) -> bool:
    """Is this slot a stage-2 finalizer over the shared channel sweep?"""
    ev = lookup(spec.event)
    return ev.finalize is not None and not ev.wants_dict


def slot_channels(spec: EventSpec) -> tuple[str, ...]:
    """The raw channels ONE slot needs (empty for bespoke events)."""
    return lookup(spec.event).moments


def channels_for(specs) -> tuple[str, ...]:
    """Exact channels the given slot group needs, in canonical order.

    The probe-plan compiler (core/plan.py) calls this PER EVENT SET — the
    resulting sweep covers only what the active set's slots finalize from,
    not the union across every set of the scope.
    """
    need: set[str] = set()
    for s in specs:
        need.update(lookup(s.event).moments)
    return tuple(m for m in CHANNELS if m in need)


def finalize_event(spec: EventSpec, moments: Mapping[str, Array]) -> Array:
    """Stage 2: one event value from the shared moment vector (traced)."""
    ev = lookup(spec.event)
    if ev.finalize is None:
        raise TypeError(f"event {spec.event!r} is not moment-derived")
    missing = [m for m in ev.moments if m not in moments]
    if missing:
        raise KeyError(
            f"event {spec.event}: moments {missing} not provided "
            f"(have {sorted(moments)})"
        )
    return jnp.asarray(ev.finalize(moments), jnp.float32)


# --------------------------------------------------------------------------
# Generic per-tensor events (apply to any probed tensor via "NAME:tensor").
# --------------------------------------------------------------------------

def _f32(x: Array) -> Array:
    return x.astype(jnp.float32)


@register(
    "ACT_RMS", INTENSIVE, moments=("sum_sq", "numel"),
    finalize=lambda m: jnp.sqrt(m["sum_sq"] / m["numel"] + 1e-30),
    doc="root-mean-square of the tensor",
)
def _act_rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(_f32(x))) + 1e-30)


@register(
    "ACT_MEAN_ABS", INTENSIVE, moments=("sum_abs", "numel"),
    finalize=lambda m: m["sum_abs"] / m["numel"],
    doc="mean |x|",
)
def _act_mean_abs(x):
    return jnp.mean(jnp.abs(_f32(x)))


@register(
    "ACT_MAX_ABS", INTENSIVE, moments=("max_abs",),
    finalize=lambda m: m["max_abs"],
    doc="max |x| (overflow watch)",
)
def _act_max_abs(x):
    return jnp.max(jnp.abs(_f32(x)))


@register(
    "ACT_ZERO_FRAC", INTENSIVE, moments=("zero_count", "numel"),
    finalize=lambda m: m["zero_count"] / m["numel"],
    doc="fraction of exact zeros (sparsity)",
)
def _act_zero_frac(x):
    return jnp.mean((x == 0).astype(jnp.float32))


@register(
    "NAN_COUNT", EXTENSIVE, moments=("nan_count",),
    finalize=lambda m: m["nan_count"],
    doc="number of NaN entries",
)
def _nan_count(x):
    return jnp.sum(jnp.isnan(_f32(x)).astype(jnp.float32))


@register(
    "INF_COUNT", EXTENSIVE, moments=("inf_count",),
    finalize=lambda m: m["inf_count"],
    doc="number of +-Inf entries",
)
def _inf_count(x):
    return jnp.sum(jnp.isinf(_f32(x)).astype(jnp.float32))


@register(
    "NUMEL", EXTENSIVE, moments=("numel",),
    finalize=lambda m: m["numel"],
    doc="number of elements seen (token/elt count)",
)
def _numel(x):
    return jnp.float32(np.prod(x.shape) if x.shape else 1)


@register(
    "L2NORM", INTENSIVE, moments=("sum_sq",),
    finalize=lambda m: jnp.sqrt(m["sum_sq"] + 1e-30),
    doc="L2 norm of the tensor",
)
def _l2norm(x):
    return jnp.sqrt(jnp.sum(jnp.square(_f32(x))) + 1e-30)


@register(
    "MEAN", INTENSIVE, moments=("sum", "numel"),
    finalize=lambda m: m["sum"] / m["numel"],
    doc="mean value",
)
def _mean(x):
    return jnp.mean(_f32(x))


# --------------------------------------------------------------------------
# Specialized events (bind to specific probe names).
# --------------------------------------------------------------------------

@register(
    "ATTN_ENTROPY", INTENSIVE, moments=("ent_sum", "rows"),
    finalize=lambda m: -m["ent_sum"] / m["rows"],
    doc="mean entropy (nats) of attention rows; probe tensor = probabilities "
        "over the last axis.  Fused: rides the sweep's optional ent_sum "
        "channel (sum of p*log(p+eps)) divided by the static row count",
)
def _attn_entropy(p):
    p = _f32(p)
    return jnp.mean(-jnp.sum(p * jnp.log(p + 1e-9), axis=-1))


@register(
    "MOE_LOAD", INTENSIVE, wants_dict=True,
    subevents=("MAX_FRAC", "MIN_FRAC", "CV", "AUX_LOSS"),
    requires=("router_probs",),
    doc="expert load statistics; needs probe 'router_probs' "
        "[tokens, experts] and optionally 'expert_mask' [tokens, experts]",
)
def _moe_load(tensors, subevent):
    probs = _f32(tensors["router_probs"])  # [tokens, experts]
    if "expert_mask" in tensors:
        load = jnp.mean(_f32(tensors["expert_mask"]), axis=0)  # frac per expert
    else:
        load = jnp.mean(probs, axis=0)
    n_e = load.shape[-1]
    if subevent == "MAX_FRAC":
        return jnp.max(load) * n_e  # 1.0 == perfectly balanced
    if subevent == "MIN_FRAC":
        return jnp.min(load) * n_e
    if subevent == "CV":
        return jnp.std(load) / (jnp.mean(load) + 1e-9)
    if subevent == "AUX_LOSS":
        # Switch-transformer style load-balancing loss.
        importance = jnp.mean(probs, axis=0)
        return jnp.float32(n_e) * jnp.sum(load * importance)
    raise KeyError(f"MOE_LOAD subevent {subevent!r}")


@register(
    "SSM_STATE_RMS", INTENSIVE,
    doc="RMS of the recurrent state (probe 'state')",
)
def _ssm_state_rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(_f32(x))) + 1e-30)


@register(
    "GRAD_GLOBAL_NORM", INTENSIVE, moments=("sum_sq",),
    finalize=lambda m: jnp.sqrt(m["sum_sq"] + 1e-30),
    doc="global norm of a gradient tensor (probe per-group flattened grads)",
)
def _grad_global_norm(x):
    return jnp.sqrt(jnp.sum(jnp.square(_f32(x))) + 1e-30)


# --------------------------------------------------------------------------
# Static "cost-model" events: per-call constants supplied by the scope at
# probe time (e.g. a Pallas kernel reporting its schedule's HBM->VMEM traffic).
# These are the closest analogue of the paper's Table-2 counters for the GEMM
# case study: the *cause* metrics of a kernel schedule.
# --------------------------------------------------------------------------

def _sum_finalizer(m):
    return m["sum"]


@register("FLOPS", EXTENSIVE, moments=("sum",), finalize=_sum_finalizer,
          doc="floating-point ops (probe provides scalar)")
def _flops(x):
    return jnp.sum(_f32(x))


@register("HBM_BYTES", EXTENSIVE, moments=("sum",), finalize=_sum_finalizer,
          doc="bytes moved HBM<->VMEM by the schedule (scalar probe) — "
              "analogue of L2_LINES_IN")
def _hbm_bytes(x):
    return jnp.sum(_f32(x))


@register("VMEM_TILE_REFILLS", EXTENSIVE, moments=("sum",),
          finalize=_sum_finalizer,
          doc="number of HBM->VMEM tile fetches — analogue of DTLB_MISSES")
def _vmem_refills(x):
    return jnp.sum(_f32(x))


@register("MXU_PASSES", EXTENSIVE, moments=("sum",), finalize=_sum_finalizer,
          doc="number of 128x128 MXU systolic passes — analogue of "
              "SIMD_INST_RETIRED")
def _mxu_passes(x):
    return jnp.sum(_f32(x))


@register("EST_STALL_CYCLES", EXTENSIVE, moments=("sum",),
          finalize=_sum_finalizer,
          doc="estimated memory-stall cycles (max(0, mem_time-compute_time) "
              "* clock) — analogue of RESOURCE_STALLS")
def _stall_cycles(x):
    return jnp.sum(_f32(x))
