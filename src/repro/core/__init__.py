"""ScALPEL-JAX core: the paper's contribution as a composable JAX module.

Public API (see DESIGN.md §2 for the paper mapping):

    spec     = scalpel.MonitorSpec / spec_from_mapping / spec_from_discovery
    params   = scalpel.MonitorParams.all_on(spec) / .selective(...)
    state    = scalpel.CounterState.zeros(spec)

    with scalpel.collecting(spec, params, state) as col:
        ... model code calling scalpel.function(...) / scalpel.probe(...) ...
    state = state.add(col.delta)

    runtime  = scalpel.ScalpelRuntime(spec, config_path=..., install_signal=True)
"""
from .config_file import (  # noqa: F401
    ConfigError,
    ScalpelConfig,
    apply_config,
    parse,
    parse_file,
    serialize,
)
from .context import (  # noqa: F401
    EventSpec,
    MonitorSpec,
    ScopeContext,
    spec_from_mapping,
)
from .counters import CounterState, MonitorParams  # noqa: F401
from .events import (  # noqa: F401
    CHANNELS,
    EXTENSIVE,
    INTENSIVE,
    channels_for,
    compute,
    lookup,
    registered,
)
from .instrument import (  # noqa: F401
    breakpoint_mode,
    capture,
    collecting,
    current_collector,
    discover,
    discovering,
    function,
    instrument,
    probe,
    probe_scope,
    scan_with_counters,
    spec_from_discovery,
)
from .plan import (  # noqa: F401
    CompactDelta,
    MomentPlan,
    ScopePlans,
    SlotLayout,
    compile_scope_plans,
    describe_plans,
    spec_fingerprint,
    spec_layout,
)
from .report import (  # noqa: F401
    JsonlWriter,
    build,
    estimates,
    format_text,
    to_json,
    write_jsonl,
)
from .runtime import ScalpelRuntime  # noqa: F401
from .telemetry import (  # noqa: F401
    CallbackSink,
    JsonlSink,
    Sink,
    SnapshotRing,
    TelemetryParams,
    TelemetryPlane,
    TelemetrySnapshot,
    TextSink,
    ring_append,
)
