"""ScALPEL-JAX core: the paper's contribution as a composable JAX module.

Public API (see DESIGN.md §2 for the paper mapping):

    spec    = scalpel.MonitorSpec / spec_from_mapping / spec_from_discovery
    mon     = scalpel.Monitor(spec, params, telemetry=...)
    step    = mon.wrap(step_fn)          # or @scalpel.monitored(spec)
    mstate  = mon.init()

    out, mstate = jax.jit(step)(mstate, *args)   # one pytree, compact
    print(mon.report(mstate))                    # counters read directly

    runtime = scalpel.ScalpelRuntime(spec, config_path=..., install_signal=True)

The legacy hand-threaded region API (``collecting`` + ``state.add(col.delta)``)
is DEPRECATED — it survives as a shim over ``Monitor.open``; see the README
migration table.
"""
from .adaptive import (  # noqa: F401
    AdaptiveConfig,
    AdaptiveController,
)
from .config_file import (  # noqa: F401
    ConfigError,
    ScalpelConfig,
    apply_config,
    parse,
    parse_file,
    serialize,
)
from .context import (  # noqa: F401
    EventSpec,
    MonitorSpec,
    ScopeContext,
    spec_from_mapping,
)
from .counters import CounterState, MonitorParams  # noqa: F401
from .events import (  # noqa: F401
    CHANNELS,
    EXTENSIVE,
    INTENSIVE,
    channels_for,
    compute,
    lookup,
    registered,
)
from .instrument import (  # noqa: F401
    breakpoint_mode,
    capture,
    collecting,
    current_collector,
    discover,
    discovering,
    function,
    instrument,
    probe,
    probe_scope,
    scan_with_counters,
    spec_from_discovery,
)
from .monitor import (  # noqa: F401
    LaneMonitorState,
    Monitor,
    MonitorState,
    monitored,
)
from .plan import (  # noqa: F401
    CompactDelta,
    MomentPlan,
    ScopePlans,
    SentinelLane,
    SentinelSet,
    SlotLayout,
    compile_scope_plans,
    compile_sentinels,
    describe_plans,
    lane_slot_ids,
    spec_fingerprint,
    spec_layout,
)
from .report import (  # noqa: F401
    JsonlWriter,
    build,
    estimates,
    format_text,
    to_json,
    write_jsonl,
)
from .runtime import ScalpelRuntime  # noqa: F401
from .telemetry import (  # noqa: F401
    CallbackSink,
    JsonlSink,
    Sink,
    SnapshotRing,
    TelemetryParams,
    TelemetryPlane,
    TelemetrySnapshot,
    TextSink,
    TokenRing,
    ring_append,
    token_ring_append,
)
