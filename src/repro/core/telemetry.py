"""Asynchronous telemetry plane — decouple counter collection from consumption.

The paper's runtime pays a synchronous full-``CounterState`` device→host
transfer every time a report/adapt decision is made (the "host readback
cadence" cost in ROADMAP's probe cost model).  Production monitoring stacks
split measurement from collection with an agent/transport/collector design
(LIKWID Monitoring Stack, PerSyst); this module brings that split to the
jitted hot path:

* **Device side** — ``SnapshotRing``: ``[depth, ...]`` copies of the counter
  pytree plus a step stamp, written by a ``lax.cond``-guarded
  ``ring_append`` at a runtime-configurable cadence.  The cadence lives in
  ``TelemetryParams`` — a dynamic input to the jitted step (MonitorParams
  style), so changing it never re-traces.  Appends are pure device work: the
  step loop never blocks on the ring.

* **Host side** — ``TelemetryPlane``: a background drain thread pulls ring
  slots incrementally past its drain cursor — an idle tick costs one scalar
  head probe; a drain that kept up (one new slot) copies the ring's O(1)
  ``last`` mirror instead of the depth-sized ring; only a multi-slot
  catch-up copies the stacked ring, whose slots are then mostly live.  All
  of it is pure buffer transfer (``copy_to_host_async`` then a
  ``device_get`` on the *drain* thread, never the step loop) — never
  device-side compute, which would queue behind in-flight steps.  Slots are
  delta-decoded into consecutive snapshots and fanned out to pluggable
  ``Sink``s
  (stdout text, buffered JSONL, in-process callbacks — the mechanism behind
  ``ScalpelRuntime.add_hook``).

Two integration modes:

* carried ring — the jitted step threads a ``SnapshotRing`` through its
  carry (``ring_append`` in-graph) and the loop hands the fresh ring to
  ``plane.publish``; the ring argument must NOT be donated so the drain
  thread can read the previous buffers while the next step runs.
* host-driven — ``plane.append(counters)`` dispatches a tiny jitted append
  against a plane-owned ring (what ``ScalpelRuntime.on_step`` uses when the
  caller does not carry a ring).
"""
from __future__ import annotations

import atexit
import dataclasses
import sys
import threading
import time
import weakref
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import report as report_lib
from .context import MonitorSpec
from .counters import CounterState

Array = Any


# ---------------------------------------------------------------------------
# Device side: snapshot ring + dynamic telemetry params
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TelemetryParams:
    """Runtime-mutable telemetry knobs (dynamic jit inputs — no re-trace).

    cadence  scalar i32 — ring-append every ``cadence`` steps; 0 disables.
    """

    cadence: Array

    @staticmethod
    def of(cadence: int) -> "TelemetryParams":
        return TelemetryParams(cadence=jnp.asarray(max(0, int(cadence)),
                                                   jnp.int32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SnapshotRing:
    """Device-side ring of counter snapshots + step stamps.

    The ring is generic over the counter pytree it snapshots — anything with
    ``calls``/``values``/``samples`` leaves: the legacy padded
    ``CounterState`` ([n_scopes, max_slots] values) or the compact
    dense-layout ``plan.CompactDelta`` ([total] lanes) that ``Monitor``
    threads, in which case telemetry snapshots stay compact end-to-end and
    reports read the dense layout directly.

    steps     [depth]                 i32 — step stamp per slot (-1 empty)
    calls     [depth, *calls_shape]   i32
    values    [depth, *values_shape]  f32
    samples   [depth, *samples_shape] i32
    last      counter pytree — O(1) mirror of the NEWEST snapshot
    last_step scalar i32 — step stamp of ``last``
    head      scalar i32 — total writes ever (monotonic; slot = seq % depth)

    ``last`` duplicates the most recent append into fixed, depth-independent
    buffers.  It exists for the drain's incremental fast path: when exactly
    one slot is newer than the drain cursor (the steady state of a drain
    that keeps up — and the case where re-copying a deep ring wastes
    (depth-1)/depth of the transfer), the host copies the mirror alone.
    Pure buffer transfers either way: the drain must never dispatch device
    computation (e.g. a gather of pending slots), because new device work
    queues behind every in-flight training step and delays snapshots — and
    the adaptive hooks riding them — by the whole dispatch window.
    """

    steps: Array
    calls: Array
    values: Array
    samples: Array
    last: CounterState
    last_step: Array
    head: Array

    @staticmethod
    def for_counters(counters, depth: int = 8) -> "SnapshotRing":
        """A ring templated on an arbitrary counter pytree (zeroed)."""
        d = int(depth)
        if d < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        zero = jax.tree.map(jnp.zeros_like, counters)
        stack = jax.tree.map(
            lambda x: jnp.zeros((d,) + x.shape, x.dtype), zero
        )
        return SnapshotRing(
            steps=jnp.full((d,), -1, jnp.int32),
            calls=stack.calls,
            values=stack.values,
            samples=stack.samples,
            last=zero,
            last_step=jnp.full((), -1, jnp.int32),
            head=jnp.zeros((), jnp.int32),
        )

    @staticmethod
    def zeros(spec: MonitorSpec, depth: int = 8) -> "SnapshotRing":
        """Legacy padded template ([n_scopes, max_slots] CounterState)."""
        return SnapshotRing.for_counters(CounterState.zeros(spec), depth)

    @staticmethod
    def zeros_compact(spec: MonitorSpec, depth: int = 8) -> "SnapshotRing":
        """Compact dense-layout template (what ``Monitor`` states carry)."""
        from . import plan as plan_lib

        return SnapshotRing.for_counters(
            plan_lib.CompactDelta.zeros(spec), depth
        )

    @property
    def depth(self) -> int:
        return int(self.steps.shape[0])

    def slot_state(self, slot: int):
        """The counter pytree stored in ring slot ``slot`` (host or device),
        of the same type as the ring's template."""
        return type(self.last)(
            calls=self.calls[slot],
            values=self.values[slot],
            samples=self.samples[slot],
        )


def ring_append(ring: SnapshotRing, counters,
                tparams: TelemetryParams, step) -> SnapshotRing:
    """``lax.cond``-guarded ring append — pure device work, jit/scan safe.

    Writes a snapshot of ``counters`` (any counter pytree matching the
    ring's template — CounterState or compact CompactDelta) stamped
    ``step`` when ``step`` is a multiple of the (dynamic) cadence;
    otherwise a no-op.  ``step`` is a traced i32 scalar (e.g.
    ``tstate.step + 1``), so neither the cadence nor the step value ever
    re-traces the caller.  Besides the ring slot, the O(1) ``last`` mirror
    is refreshed — the drain's one-slot fast path.
    """
    step = jnp.asarray(step, jnp.int32)
    cadence = jnp.maximum(tparams.cadence, 1)
    do = (tparams.cadence > 0) & (step % cadence == 0)

    def write(r: SnapshotRing) -> SnapshotRing:
        slot = r.head % r.steps.shape[0]
        return SnapshotRing(
            steps=jax.lax.dynamic_update_index_in_dim(
                r.steps, step, slot, 0),
            calls=jax.lax.dynamic_update_index_in_dim(
                r.calls, counters.calls, slot, 0),
            values=jax.lax.dynamic_update_index_in_dim(
                r.values, counters.values, slot, 0),
            samples=jax.lax.dynamic_update_index_in_dim(
                r.samples, counters.samples, slot, 0),
            last=counters,
            last_step=step,
            head=r.head + 1,
        )

    return jax.lax.cond(do, write, lambda r: r, ring)


# ---------------------------------------------------------------------------
# Token egress (serving): per-lane sampled tokens ride the telemetry plane
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TokenRing:
    """Device-side ring of per-lane sampled tokens — the serve engine's
    egress lane.

    Counters tolerate a cadence (``ring_append`` samples the cumulative
    state); sampled tokens do NOT — dropping one corrupts the request's
    output stream.  So the token ring is appended UNCONDITIONALLY once per
    decode step inside the megastep scan, and the engine sizes ``depth``
    to cover more inner steps than ever elapse between drains.

    steps  [depth]           i32 — decode-step stamp per slot (-1 empty)
    toks   [depth, n_lanes]  i32 — the token each lane CONSUMED this step
    live   [depth, n_lanes]  i32 — 1 where the lane was active (the other
                                   lanes' slots are decode garbage)
    head   scalar            i32 — total appends ever (slot = seq % depth)
    """

    steps: Array
    toks: Array
    live: Array
    head: Array

    @staticmethod
    def zeros(n_lanes: int, depth: int = 32) -> "TokenRing":
        d, n = int(depth), int(n_lanes)
        if d < 1 or n < 1:
            raise ValueError(f"token ring needs depth/lanes >= 1, got "
                             f"{depth}/{n_lanes}")
        return TokenRing(
            steps=jnp.full((d,), -1, jnp.int32),
            toks=jnp.zeros((d, n), jnp.int32),
            live=jnp.zeros((d, n), jnp.int32),
            head=jnp.zeros((), jnp.int32),
        )

    @property
    def depth(self) -> int:
        return int(self.steps.shape[0])

    @property
    def n_lanes(self) -> int:
        return int(self.toks.shape[1])


def token_ring_append(ring: TokenRing, toks, live, step) -> TokenRing:
    """Unconditional token append — pure device work, jit/scan safe.

    ``toks``/``live``: [n_lanes] i32; ``step``: traced i32 scalar.
    """
    slot = ring.head % ring.steps.shape[0]
    return TokenRing(
        steps=jax.lax.dynamic_update_index_in_dim(
            ring.steps, jnp.asarray(step, jnp.int32), slot, 0),
        toks=jax.lax.dynamic_update_index_in_dim(
            ring.toks, jnp.asarray(toks, jnp.int32), slot, 0),
        live=jax.lax.dynamic_update_index_in_dim(
            ring.live, jnp.asarray(live, jnp.int32), slot, 0),
        head=ring.head + 1,
    )


# ---------------------------------------------------------------------------
# Host side: snapshots and sinks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TelemetrySnapshot:
    """One drained ring slot, delta-decoded against its predecessor.

    state/delta are host (numpy) counter pytrees — CounterState for legacy
    padded rings, compact ``plan.CompactDelta`` for Monitor rings (reports
    are built straight off the dense layout either way): ``state`` is the
    cumulative counters at ``step``; ``delta`` is the increment since the
    previously drained snapshot (== ``state`` for the first one).
    """

    step: int
    seq: int                    # monotonic ring sequence number
    state: Any
    delta: Any
    spec: MonitorSpec

    def __post_init__(self):
        self._reports: list | None = None

    @property
    def reports(self) -> list[report_lib.ScopeReport]:
        """Cumulative per-scope reports (built lazily, cached)."""
        if self._reports is None:
            self._reports = report_lib.build(self.spec, self.state)
        return self._reports

    @property
    def delta_reports(self) -> list[report_lib.ScopeReport]:
        return report_lib.build(self.spec, self.delta)


class Sink:
    """Pluggable consumer of drained snapshots (emit on the drain thread)."""

    def emit(self, snap: TelemetrySnapshot) -> None:  # pragma: no cover
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()

    def stats(self) -> dict:
        """Sink-specific health counters, merged into
        ``TelemetryPlane.stats()['sinks']`` — e.g. the fleet agent's
        ``dropped_frames``/``reconnects``.  Default: nothing to report."""
        return {}


class TextSink(Sink):
    """Paper's default sink — human-readable text, one block per snapshot."""

    def __init__(self, stream=None, title: str = "ScALPEL telemetry"):
        self.stream = stream
        self.title = title

    def emit(self, snap: TelemetrySnapshot) -> None:
        out = self.stream or sys.stdout
        text = report_lib.format_text(
            snap.reports, title=f"{self.title} @ step {snap.step}"
        )
        print(text, file=out)


class JsonlSink(Sink):
    """Buffered JSONL sink — one open file handle, writes off the hot path
    (replaces ``report_lib.write_jsonl``'s per-call ``open()``)."""

    def __init__(self, path: str, buffer_lines: int = 64):
        self._writer = report_lib.JsonlWriter(path, buffer_lines=buffer_lines)

    def emit(self, snap: TelemetrySnapshot) -> None:
        # stamp each line with the producing spec's plan fingerprint so the
        # stream stays attributable across config hot-swaps
        self._writer.write(snap.step, snap.reports,
                           plan=snap.spec.fingerprint[:12])

    def flush(self) -> None:
        self._writer.flush()

    def close(self) -> None:
        self._writer.close()


class CallbackSink(Sink):
    """In-process adaptive hook: ``fn(snapshot)`` per drained snapshot."""

    def __init__(self, fn: Callable[[TelemetrySnapshot], None]):
        self.fn = fn

    def emit(self, snap: TelemetrySnapshot) -> None:
        self.fn(snap)


# ---------------------------------------------------------------------------
# The plane: background drain + fan-out
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SinkRecord:
    """Per-sink failure accounting (drain-thread hardening).

    ``retry_at`` is in units of ``drain_count`` — exponential backoff in
    drains, not wall time, so a paused producer doesn't burn retries."""

    name: str
    errors: int = 0
    consecutive: int = 0
    retry_at: int = 0
    dropped: bool = False
    logged: bool = False


_PLANES: "weakref.WeakSet[TelemetryPlane]" = weakref.WeakSet()
_ATEXIT_INSTALLED = False


def _close_all_planes() -> None:  # pragma: no cover - atexit path
    for p in list(_PLANES):
        try:
            p.close()
        except Exception:
            pass


class TelemetryPlane:
    """Owns the telemetry cadence, the drain thread, and the sink fan-out.

    The step loop only ever (a) dispatches a device-side ring append and
    (b) swaps a ring reference into the plane — no host synchronization.
    The drain thread performs every device→host transfer.
    """

    def __init__(self, spec: MonitorSpec, depth: int = 8, cadence: int = 1,
                 sinks: tuple = (), interval_s: float = 0.02):
        self.spec = spec
        self.depth = max(1, int(depth))
        self.interval_s = float(interval_s)
        self.sinks: list[Sink] = []
        self._cadence = max(0, int(cadence))
        self.params = TelemetryParams.of(self._cadence)

        # drain-thread hardening: per-sink failure records — a raising sink
        # is retried with exponential backoff and dropped after
        # ``max_sink_failures`` consecutive failures, never killing drains
        self._sink_records: dict[int, _SinkRecord] = {}
        self._sink_seq = 0
        self.max_sink_failures = 5
        self.dropped_sinks: list[str] = []
        for s in sinks:
            self.add_sink(s)

        self._ring: SnapshotRing | None = None      # latest published ring
        self._own_ring: SnapshotRing | None = None  # host-driven mode
        self._append_fn = jax.jit(ring_append)
        self._appends = 0

        # token-egress lineage (serving): independent ring + cursor — the
        # engine's host loop drains it explicitly (pipelined one megastep
        # behind the dispatch), it never rides the background drain thread
        self._tok_ring: TokenRing | None = None
        self._tok_cursor = 0
        self.tok_slots_copied = 0
        self.dropped_tokens = 0
        self.token_drains = 0

        self._drained_head = 0
        self._prev_state: CounterState | None = None  # last drained (host)
        self._last_step = -1
        self.dropped_snapshots = 0
        self.drain_count = 0
        # device→host transfer accounting: ring slots actually copied (the
        # incremental drain copies only slots newer than the cursor, so at
        # depth ≫ pending this is far below drain_count * depth)
        self.slots_copied = 0
        # host seconds spent inside _drain_once (transfers + sink emits) —
        # the adaptive budget loop's measured monitoring overhead
        self.drain_seconds = 0.0

        self._lock = threading.Lock()          # ring ref + counters
        # RLock: a hook/sink may call runtime.report()/flush() from inside
        # its own emit (on the drain thread) — the re-entrant drain sees an
        # up-to-date cursor and returns empty instead of deadlocking.
        self._drain_lock = threading.RLock()   # serializes drains
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._closed = False

        global _ATEXIT_INSTALLED
        _PLANES.add(self)
        if not _ATEXIT_INSTALLED:
            atexit.register(_close_all_planes)
            _ATEXIT_INSTALLED = True

    # -- configuration ----------------------------------------------------
    @property
    def cadence(self) -> int:
        return self._cadence

    def set_cadence(self, cadence: int) -> None:
        """Swap the ring-append cadence — a dynamic-input swap, no re-trace."""
        self._cadence = max(0, int(cadence))
        self.params = TelemetryParams.of(self._cadence)

    def add_sink(self, sink: Sink) -> Sink:
        self.sinks.append(sink)
        self._sink_seq += 1
        self._sink_records.setdefault(
            id(sink),
            _SinkRecord(name=f"{type(sink).__name__}#{self._sink_seq}"),
        )
        return sink

    @property
    def sink_errors(self) -> dict[str, int]:
        """Cumulative emit/flush failures per sink (empty when healthy)."""
        return {
            r.name: r.errors for r in self._sink_records.values()
            if r.errors
        }

    def stats(self) -> dict:
        """One uniform health dict for the whole plane (fleet-inspectable).

        Fixes the old accounting asymmetry: drain counters, per-sink error
        records, AND sink-specific extras (``Sink.stats()`` — the fleet
        agent's ``dropped_frames``/``reconnects``) all surface here, so
        ``report()`` and the fleet head read one shape.
        """
        sinks: dict[str, dict] = {}
        for s in list(self.sinks):
            rec = self._sink_records.get(id(s))
            name = rec.name if rec is not None else type(s).__name__
            entry = {"errors": rec.errors if rec is not None else 0,
                     "dropped": False}
            try:
                entry.update(s.stats() or {})
            except Exception:  # pragma: no cover - sink bug isolation
                entry["stats_error"] = True
            sinks[name] = entry
        for name in self.dropped_sinks:
            sinks.setdefault(name, {})["dropped"] = True
            rec = next((r for r in self._sink_records.values()
                        if r.name == name), None)
            if rec is not None:
                sinks[name].setdefault("errors", rec.errors)
        return {
            "cadence": self._cadence,
            "drain_count": self.drain_count,
            "drain_seconds": round(self.drain_seconds, 6),
            "slots_copied": self.slots_copied,
            "dropped_snapshots": self.dropped_snapshots,
            "dropped_tokens": self.dropped_tokens,
            "sink_errors": dict(self.sink_errors),
            "dropped_sinks": list(self.dropped_sinks),
            "sinks": sinks,
        }

    def _sink_failed(self, sink: Sink, rec: _SinkRecord,
                     where: str = "emit") -> None:
        rec.errors += 1
        rec.consecutive += 1
        if not rec.logged:
            rec.logged = True
            print(
                f"scalpel telemetry: sink {rec.name} raised in {where} "
                f"({sys.exc_info()[0].__name__}: {sys.exc_info()[1]}); "
                "retrying with backoff (logged once)",
                file=sys.stderr,
            )
        if rec.consecutive >= self.max_sink_failures:
            rec.dropped = True
            self.dropped_sinks.append(rec.name)
            try:
                self.sinks.remove(sink)
            except ValueError:
                pass
            print(
                f"scalpel telemetry: sink {rec.name} dropped after "
                f"{rec.consecutive} consecutive failures",
                file=sys.stderr,
            )
            try:
                sink.close()
            except Exception:
                pass
        else:
            # exponential backoff in drains: skip 2, 4, 8, ... drains
            rec.retry_at = self.drain_count + (1 << rec.consecutive)

    def _reset_epoch(self) -> None:
        """Drain pending slots, then reset the drain cursor + delta base."""
        self._drain_once()
        with self._lock:
            self._ring = None
            self._own_ring = None
            self._drained_head = 0
            self._prev_state = None

    def make_ring(self, compact: bool = False) -> SnapshotRing:
        """A fresh device ring for loops that carry it through their step.

        ``compact=True`` templates the ring on the spec's dense slot layout
        (what ``Monitor`` states carry) instead of the padded CounterState.

        Starts a new ring *epoch*: pending slots of the previously published
        ring are drained first, then the drain cursor and delta base reset —
        a fresh ring's ``head`` restarts at 0, so carrying the old cursor
        over would silently stop draining.  The plane tracks one ring
        lineage at a time; producers that need independent lineages (e.g.
        two serve engines) should each own a runtime/plane.
        """
        self._reset_epoch()
        if compact:
            return SnapshotRing.zeros_compact(self.spec, self.depth)
        return SnapshotRing.zeros(self.spec, self.depth)

    def make_token_ring(self, n_lanes: int, depth: int = 32) -> TokenRing:
        """A fresh token-egress ring; starts a new token lineage (the
        cursor resets — a fresh ring's head restarts at 0)."""
        with self._lock:
            self._tok_ring = None
            self._tok_cursor = 0
        return TokenRing.zeros(n_lanes, depth)

    def publish_tokens(self, ring: TokenRing) -> None:
        """Hand the latest carried token ring to the plane (ref swap only).

        Same contract as ``publish``: the ring's buffers must never be
        donated to a later megastep — ``drain_tokens`` reads them while the
        next megastep runs.
        """
        with self._lock:
            self._tok_ring = ring

    def drain_tokens(self) -> list[tuple[int, int, np.ndarray, np.ndarray]]:
        """Drain pending token-ring slots past the token cursor.

        Returns ``(seq, step, toks[n_lanes], live[n_lanes])`` per slot, in
        append order.  Pure buffer transfers, exactly like the counter
        drain: one scalar head probe when idle, one stacked copy
        (``copy_to_host_async`` + host gather) when slots are pending —
        NEVER device computation (the ROADMAP drain invariant; the np
        materialization blocks only until the producing megastep retires,
        which is the engine's sanctioned request-completion sync point).
        """
        with self._lock:
            ring = self._tok_ring
        self.token_drains += 1
        if ring is None:
            return []
        head = int(jax.device_get(ring.head))
        if head < self._tok_cursor:
            # fresh lineage published without make_token_ring()
            self._tok_cursor = 0
        if head <= self._tok_cursor:
            return []
        depth = ring.depth
        first = max(self._tok_cursor, head - depth)
        # tokens are outputs, not samples: an overrun is data loss, so the
        # engine sizes depth > steps-per-drain; account it loudly anyway
        self.dropped_tokens += first - self._tok_cursor
        try:
            jax.tree.map(
                lambda x: x.copy_to_host_async()
                if hasattr(x, "copy_to_host_async") else None,
                (ring.steps, ring.toks, ring.live),
            )
        except Exception:  # pragma: no cover - backend-dependent
            pass
        steps_h = np.asarray(ring.steps)
        toks_h = np.asarray(ring.toks)
        live_h = np.asarray(ring.live)
        out = []
        for seq in range(first, head):
            s = seq % depth
            out.append((seq, int(steps_h[s]), toks_h[s], live_h[s]))
        self.tok_slots_copied += depth
        self._tok_cursor = head
        return out

    # -- producer side (step loop; never blocks on device) ----------------
    def publish(self, ring: SnapshotRing) -> None:
        """Hand the latest carried ring to the drain thread (ref swap only).

        Deliberately does NOT wake the drain thread: draining is paced by
        ``interval_s`` (and by explicit ``flush()``), so a hot step loop
        publishing every step never induces per-step drain work.  The ring's
        buffers must not be donated to a later step — the drain thread reads
        them concurrently with subsequent dispatches.
        """
        with self._lock:
            self._ring = ring
        self._ensure_thread()

    def append(self, counters, step: int | None = None) -> None:
        """Host-driven mode: dispatch a jitted ring append (async, device).

        The plane-owned ring is templated on the first ``counters`` pytree
        appended (padded CounterState or compact), so either layout works.
        """
        if self._own_ring is None:
            # outside the lock: the reset drains (its own locks) first
            self._reset_epoch()
            ring = SnapshotRing.for_counters(counters, self.depth)
            with self._lock:
                self._own_ring = ring
        with self._lock:
            self._appends += 1
            stamp = self._appends if step is None else int(step)
            self._own_ring = self._append_fn(
                self._own_ring, counters, self.params,
                jnp.asarray(stamp, jnp.int32),
            )
            self._ring = self._own_ring
        self._ensure_thread()

    # -- consumer side ----------------------------------------------------
    @property
    def last_state(self) -> CounterState | None:
        """Most recently drained cumulative CounterState (host numpy)."""
        return self._prev_state

    @property
    def last_step(self) -> int:
        return self._last_step

    def flush(self) -> list[TelemetrySnapshot]:
        """Synchronously drain every pending ring slot and flush sinks."""
        snaps = self._drain_once()
        for s in list(self.sinks):
            try:
                s.flush()
            except Exception:
                rec = self._sink_records.get(id(s))
                if rec is not None:
                    self._sink_failed(s, rec, where="flush")
        return snaps

    def close(self) -> None:
        """Stop the drain thread, flush remaining slots, close sinks."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._wake.set()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout=5.0)
        self._drain_once()
        for s in list(self.sinks):
            try:
                s.close()
            except Exception:
                pass

    # -- drain machinery ---------------------------------------------------
    def _ensure_thread(self) -> None:
        if self._closed or (self._thread is not None and
                            self._thread.is_alive()):
            return
        self._thread = threading.Thread(
            target=self._drain_loop, name="scalpel-telemetry-drain",
            daemon=True,
        )
        self._thread.start()

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(timeout=self.interval_s)
            self._wake.clear()
            try:
                self._drain_once()
            except Exception:  # pragma: no cover - keep draining on errors
                pass

    def _drain_once(self) -> list[TelemetrySnapshot]:
        # time INSIDE the lock: drain_seconds is the budget loop's measured
        # monitoring overhead, and lock-wait is not work — two threads
        # racing a drain must not double-count the same wall time
        with self._drain_lock:
            t0 = time.perf_counter()
            try:
                return self._drain_once_inner()
            finally:
                self.drain_seconds += time.perf_counter() - t0

    def _drain_once_inner(self) -> list[TelemetrySnapshot]:
        with self._drain_lock:
            with self._lock:
                ring = self._ring
            if ring is None:
                return []
            # Probe the scalar head first: an idle tick (nothing appended
            # since the last drain) costs one scalar transfer, not a full
            # depth x CounterState ring copy.
            head = int(jax.device_get(ring.head))
            if head < self._drained_head:
                # a fresh ring lineage was published without make_ring():
                # its head restarted below our cursor — start a new epoch
                # rather than silently never draining again.
                self._drained_head = 0
                self._prev_state = None
            if head <= self._drained_head:
                return []
            depth = ring.depth
            first = max(self._drained_head, head - depth)
            self.dropped_snapshots += first - self._drained_head
            pending = head - first
            # Incremental drain, as pure buffer transfers (never device
            # compute — new device work queues behind in-flight steps and
            # delays snapshots by the whole dispatch window):
            #   pending == 1 — the steady state of a drain keeping up with
            #     the append cadence: copy the O(1) ``last`` mirror alone,
            #     one slot's worth of bytes no matter how deep the ring is.
            #   pending > 1 — catching up: copy the stacked ring once; the
            #     pending slots are the bulk of it anyway.
            out: list[TelemetrySnapshot] = []

            def emit(seq: int, step_no: int, state) -> None:
                prev = self._prev_state
                delta = state if prev is None else state.sub(prev)
                snap = TelemetrySnapshot(
                    step=step_no, seq=seq, state=state, delta=delta,
                    spec=self.spec,
                )
                self._prev_state = state
                self._last_step = snap.step
                out.append(snap)

            def start_copies(tree) -> None:
                # Non-blocking device→host: start the copies, then gather
                # on THIS (drain) thread — the step loop never waits.
                try:
                    jax.tree.map(
                        lambda x: x.copy_to_host_async()
                        if hasattr(x, "copy_to_host_async") else None,
                        tree,
                    )
                except Exception:  # pragma: no cover - backend-dependent
                    pass

            if pending == 1:
                start_copies((ring.last, ring.last_step))
                state = jax.tree.map(np.asarray, ring.last)
                emit(head - 1, int(np.asarray(ring.last_step)), state)
                self.slots_copied += 1
            else:
                start_copies((ring.steps, ring.calls, ring.values,
                              ring.samples))
                steps_h = np.asarray(ring.steps)
                calls_h = np.asarray(ring.calls)
                values_h = np.asarray(ring.values)
                samples_h = np.asarray(ring.samples)
                mk = type(ring.last)  # ring template: padded or compact
                for seq in range(first, head):
                    s = seq % depth  # host-side slicing of the host copy
                    state = mk(calls=calls_h[s], values=values_h[s],
                               samples=samples_h[s])
                    emit(seq, int(steps_h[s]), state)
                self.slots_copied += depth
            self._drained_head = head
            self.drain_count += 1
            # hardened fan-out: a raising sink never kills the drain loop —
            # its failure is recorded, it backs off exponentially (in
            # drains), and after max_sink_failures consecutive failures it
            # is dropped; healthy sinks are untouched either way.
            for s in list(self.sinks):
                rec = self._sink_records.get(id(s))
                if rec is None:     # registered behind add_sink's back
                    self._sink_seq += 1
                    rec = _SinkRecord(
                        name=f"{type(s).__name__}#{self._sink_seq}")
                    self._sink_records[id(s)] = rec
                if rec.retry_at > self.drain_count:
                    continue        # backing off
                for snap in out:
                    try:
                        s.emit(snap)
                        rec.consecutive = 0
                        rec.retry_at = 0
                    except Exception:
                        self._sink_failed(s, rec)
                        break       # this drain is over for this sink
            return out
