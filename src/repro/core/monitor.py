"""Functional ``Monitor`` transformation — one pytree, compact end-to-end.

The paper's promise is *transparent* monitoring: no source modifications
beyond naming scopes.  The legacy ``collecting()`` API kept a seam open —
every call site had to hand-thread counters (``state = state.add(col.delta)``)
and nobody aggregated them across devices.  This module closes both:

* ``mon = Monitor(spec, params, telemetry=...)`` and ``step = mon.wrap(fn)``
  (or ``@monitored(spec)``) turn an ordinary step function into a pure
  function of ONE ``MonitorState`` pytree: compact dense counters
  (``plan.SlotLayout`` lanes — never the padded ``[n_scopes, max_slots]``
  block), the telemetry snapshot ring, the step stamp, and the runtime
  ``MonitorParams``/``TelemetryParams``.  The pytree threads through ``jit``,
  ``scan_with_counters`` and nested calls; user code never touches
  ``col.delta`` again.

* Inside ``wrap`` the step's counter delta is cross-device-reduced with
  ``lax.psum`` over whatever mesh axes ``dist/partition.py`` resolves AND the
  current trace actually binds (``counter_reduce_axes``): under ``shard_map``
  each shard's counters sum into cluster-wide totals — the paper's "MPI
  support", now in the transport; under plain jit (already-global semantics)
  or on a 1-device laptop mesh the reduction resolves to a no-op, so the
  same wrapped step runs anywhere.

* Counters stay COMPACT end-to-end: the collector's delta, the accumulate,
  the ring snapshot, and ``report.build``/``estimates`` all work in the
  spec-wide dense layout; the per-step expand to the padded block that the
  legacy path paid per ``capture()`` is gone (``CounterState`` survives as a
  convertible view — ``Monitor.counter_state``/``CounterState.from_compact``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# NOTE: the package re-exports a *function* named ``instrument``, which
# shadows the module attribute — import the needed symbols directly.
from .instrument import Collector, _stack
from . import plan as plan_lib
from . import report as report_lib
from . import telemetry as telemetry_lib
from .context import MonitorSpec
from .counters import CounterState, MonitorParams

Array = Any


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("calls", "values", "samples", "sched_calls", "step", "ring",
                 "params", "tparams"),
    meta_fields=("fingerprint",),
)
@dataclasses.dataclass
class MonitorState:
    """The one pytree a wrapped step threads — counters compact end-to-end.

    calls       [n_scopes] i32 — interception counts, mesh-REDUCED (what
                reports scale estimates by: cluster-wide totals)
    values      [total]    f32 — accumulated event values, SlotLayout lanes
    samples     [total]    i32 — monitored-call counts, SlotLayout lanes
    sched_calls [n_scopes] i32 | None — PER-SHARD interception counts: the
                multiplex schedule base.  Never mesh-reduced — under
                ``shard_map`` every shard advances its own schedule by its
                own calls; feeding the psum-reduced totals back into
                ``(calls // period) % n_sets`` would skip event sets on
                every multi-device mesh.  ``None`` (and ``calls`` doubles
                as the base) when the monitor performs no reduction
                (``counter_axes=()``): with nothing reduced the two would
                be identical lanes, and the state should not pay for both.
    step        scalar     i32 — wrapped-step stamp (telemetry cadence input)
    ring        SnapshotRing | None — compact-layout telemetry ring
    params      MonitorParams    — runtime masks/periods (dynamic: no re-trace)
    tparams     TelemetryParams  — ring-append cadence (dynamic: no re-trace)

    ``fingerprint`` is static metadata (a jit-constant string): the hash of
    the compiled probe plans that produced these counters — carried so
    checkpoints can attest plan identity at resume (``save_metadata``).
    """

    calls: Array
    values: Array
    samples: Array
    sched_calls: Array | None
    step: Array
    ring: telemetry_lib.SnapshotRing | None
    params: MonitorParams
    tparams: telemetry_lib.TelemetryParams
    fingerprint: str = ""

    @property
    def counters(self) -> plan_lib.CompactDelta:
        """The cumulative counters as a compact (dense-layout) pytree."""
        return plan_lib.CompactDelta(
            calls=self.calls, values=self.values, samples=self.samples
        )

    def save_metadata(self) -> dict:
        """Checkpoint metadata attesting which compiled plans produced the
        counters — checked against the live spec at resume
        (``ScalpelRuntime.check_resume_metadata`` / ``Monitor.check_resume``).
        """
        return {
            "plan_fingerprint": self.fingerprint,
            "monitor_step": int(jax.device_get(self.step)),
            "slot_lanes": int(self.values.shape[0]),
        }


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("lane_calls", "lane_values", "lane_samples", "lane_sched",
                 "calls", "values", "samples", "step", "ring", "params",
                 "tparams"),
    meta_fields=("fingerprint",),
)
@dataclasses.dataclass
class LaneMonitorState:
    """Per-batch-lane monitor state — the continuous-batching serve engine's
    carry.

    Scopes stay the compile-time spec; the LANE axis is what's new: every
    decode lane (one request slot in the packed slab) accumulates its own
    copy of the compact counters, so anomalies attribute to individual
    requests under load.  The aggregate lanes-summed counters are kept
    alongside in the spec's ordinary compact shapes — they are what the
    telemetry ring snapshots and the adaptive controller drains, so the
    whole existing reporting/adaptive stack works unchanged.

    lane_calls   [n_lanes, n_scopes] i32 — per-lane interception counts
    lane_values  [n_lanes, total]    f32 — per-lane event values
    lane_samples [n_lanes, total]    i32 — per-lane monitored-call counts
    lane_sched   [n_lanes, n_scopes] i32 — per-lane multiplex schedule base
                 (each lane advances its own event-set schedule; resets with
                 the lane at admission — and, like ``sched_calls``, is never
                 mesh-reduced)
    calls/values/samples — lane-summed cumulative counters (compact layout)
    step         scalar i32 — decode-step stamp (every inner megastep step)
    ring         SnapshotRing | None — aggregate-counter telemetry ring
    params/tparams — runtime knobs (dynamic inputs; megastep constants)
    """

    lane_calls: Array
    lane_values: Array
    lane_samples: Array
    lane_sched: Array
    calls: Array
    values: Array
    samples: Array
    step: Array
    ring: telemetry_lib.SnapshotRing | None
    params: MonitorParams
    tparams: telemetry_lib.TelemetryParams
    fingerprint: str = ""

    @property
    def n_lanes(self) -> int:
        return int(self.lane_calls.shape[0])

    @property
    def counters(self) -> plan_lib.CompactDelta:
        """Aggregate (lane-summed) cumulative counters — what reports, the
        ring, and the adaptive controller consume."""
        return plan_lib.CompactDelta(
            calls=self.calls, values=self.values, samples=self.samples
        )

    def lane_counters(self, lane: int) -> plan_lib.CompactDelta:
        """One lane's cumulative counters (prefill + decode so far) — the
        per-request attribution view.  Device arrays; eager slicing, so
        calling this off the host loop is async until materialized."""
        return plan_lib.CompactDelta(
            calls=self.lane_calls[lane],
            values=self.lane_values[lane],
            samples=self.lane_samples[lane],
        )


class Monitor:
    """The functional monitoring transformation over a compile-time spec.

    ``wrap(fn)`` returns ``wrapped(mstate, *args, **kw) -> (out, mstate')``:
    ``fn`` runs under a collector (its ``scalpel.function``/``probe`` calls
    land in-graph), the step's delta is mesh-reduced and folded into the
    compact counters, the step stamp advances, and — when the monitor owns a
    telemetry plane — the counters ring-append at the dynamic cadence.

    ``counter_axes``: mesh axes to ``psum`` counter deltas over.  The
    default ``"auto"`` resolves the ambient ``dist.partition`` mesh and
    reduces over whichever of its axes the trace actually binds — i.e. the
    reduction engages inside ``shard_map``/``pmap`` and melts away under
    plain jit or with no mesh (replicated-safe on a laptop).  Pass an
    explicit tuple to restrict, or ``()`` to disable.
    """

    def __init__(self, spec: MonitorSpec, params: MonitorParams | None = None,
                 *, telemetry: telemetry_lib.TelemetryPlane | None = None,
                 counter_axes="auto", plan_mode: str = "per_set"):
        self.spec = spec
        self.params = params if params is not None \
            else MonitorParams.all_on(spec)
        self.telemetry = telemetry
        self.counter_axes = counter_axes
        self.plan_mode = plan_mode

    @property
    def _carries_sched(self) -> bool:
        """Whether states carry a separate per-shard schedule base — only
        monitors that may reduce need one (otherwise ``calls`` IS it)."""
        return self.counter_axes not in ((), None)

    # -- state construction ----------------------------------------------
    def init(self, step: int = 0) -> MonitorState:
        """A fresh MonitorState (zero counters, ring from the plane)."""
        lay = plan_lib.spec_layout(self.spec)
        if self.telemetry is not None:
            ring = self.telemetry.make_ring(compact=True)
            tparams = self.telemetry.params
        else:
            ring = None
            tparams = telemetry_lib.TelemetryParams.of(0)
        return MonitorState(
            calls=jnp.zeros((self.spec.n_scopes,), jnp.int32),
            values=jnp.zeros((lay.total,), jnp.float32),
            samples=jnp.zeros((lay.total,), jnp.int32),
            sched_calls=(jnp.zeros((self.spec.n_scopes,), jnp.int32)
                         if self._carries_sched else None),
            step=jnp.asarray(int(step), jnp.int32),
            ring=ring,
            params=self.params,
            tparams=tparams,
            fingerprint=self.spec.fingerprint,
        )

    def sync(self, mstate: MonitorState,
             params: MonitorParams | None = None,
             tparams: telemetry_lib.TelemetryParams | None = None,
             runtime=None, controller=None) -> MonitorState:
        """Refresh the dynamic knobs riding in the state (host-side swap —
        same shapes, never a re-trace).  Pass a ``ScalpelRuntime`` to pick
        up both its live params and telemetry cadence in one call, or an
        ``AdaptiveController`` (adaptive.py) to pick up the closed loop's
        latest mask/period/cadence decisions without a runtime."""
        if controller is not None:
            params = controller.params if params is None else params
            tparams = controller.tparams if tparams is None else tparams
        if runtime is not None:
            params = runtime.params if params is None else params
            tparams = runtime.telemetry.params if tparams is None else tparams
        return dataclasses.replace(
            mstate,
            params=mstate.params if params is None else params,
            tparams=mstate.tparams if tparams is None else tparams,
        )

    # -- the raw collection region (what collecting() shims onto) ---------
    @contextlib.contextmanager
    def open(self, params: MonitorParams | None = None, calls_base=None):
        """Open a collection region; yields the Collector.

        The low-level primitive ``wrap`` is built on (and the deprecated
        ``collecting()`` shims onto): callers that need custom threading —
        e.g. collection inside a ``value_and_grad`` aux — use this and fold
        ``col.compact_delta()`` through ``commit`` themselves.
        """
        params = params if params is not None else self.params
        base = calls_base if calls_base is not None else jnp.zeros(
            (self.spec.n_scopes,), jnp.int32
        )
        col = Collector(
            self.spec, params, calls_base=base, plan_mode=self.plan_mode
        )
        _stack().append(col)
        try:
            yield col
        finally:
            _stack().pop()

    # -- delta folding ----------------------------------------------------
    def reduce_delta(self, delta: plan_lib.CompactDelta
                     ) -> plan_lib.CompactDelta:
        """Cross-device-reduce a compact delta over the resolved mesh axes
        (trace-time decision; a no-op when no mapped axis is bound)."""
        from repro.dist import partition

        axes = partition.counter_reduce_axes(self.counter_axes)
        return delta.psum(axes) if axes else delta

    def commit(self, mstate: MonitorState, delta: plan_lib.CompactDelta,
               reduce: bool = True) -> MonitorState:
        """Fold a region's compact delta into the state: mesh-reduce,
        accumulate, advance the step stamp, ring-append at the cadence.

        The schedule base (``sched_calls``) accumulates the UNREDUCED
        per-shard call delta — the multiplex set index must follow this
        shard's own call count, not the cluster-wide psum (which would
        advance the schedule N× per call on an N-way mesh and silently
        skip event sets).
        """
        sched_calls = None if mstate.sched_calls is None \
            else mstate.sched_calls + delta.calls
        if reduce:
            delta = self.reduce_delta(delta)
        calls = mstate.calls + delta.calls
        values = mstate.values + delta.values
        samples = mstate.samples + delta.samples
        step = mstate.step + 1
        ring = mstate.ring
        if ring is not None:
            ring = telemetry_lib.ring_append(
                ring,
                plan_lib.CompactDelta(calls=calls, values=values,
                                      samples=samples),
                mstate.tparams, step,
            )
        return dataclasses.replace(
            mstate, calls=calls, values=values, samples=samples,
            sched_calls=sched_calls, step=step, ring=ring,
        )

    # -- per-lane states (continuous-batching serving) ---------------------
    def lane_init(self, n_lanes: int, step: int = 0) -> LaneMonitorState:
        """A fresh LaneMonitorState: ``n_lanes`` zeroed counter rows plus
        zeroed aggregate lanes (ring templated on the aggregate — compact
        spec shapes, so drains/reports/adaptive see the usual layout)."""
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        lay = plan_lib.spec_layout(self.spec)
        if self.telemetry is not None:
            ring = self.telemetry.make_ring(compact=True)
            tparams = self.telemetry.params
        else:
            ring = None
            tparams = telemetry_lib.TelemetryParams.of(0)
        n, t = self.spec.n_scopes, lay.total
        return LaneMonitorState(
            lane_calls=jnp.zeros((n_lanes, n), jnp.int32),
            lane_values=jnp.zeros((n_lanes, t), jnp.float32),
            lane_samples=jnp.zeros((n_lanes, t), jnp.int32),
            lane_sched=jnp.zeros((n_lanes, n), jnp.int32),
            calls=jnp.zeros((n,), jnp.int32),
            values=jnp.zeros((t,), jnp.float32),
            samples=jnp.zeros((t,), jnp.int32),
            step=jnp.asarray(int(step), jnp.int32),
            ring=ring,
            params=self.params,
            tparams=tparams,
            fingerprint=self.spec.fingerprint,
        )

    def commit_lanes(self, lstate: LaneMonitorState,
                     delta: plan_lib.CompactDelta,
                     active) -> LaneMonitorState:
        """Fold one decode step's LANE-STACKED delta into the state.

        ``delta`` leaves carry a leading ``[n_lanes]`` axis (a vmapped
        collector's output); ``active`` is the ``[n_lanes]`` i32 lane mask.
        Inactive lanes decode garbage under vmap — their deltas are masked
        to zero, so retired/empty lanes contribute nothing to either the
        per-lane rows or the aggregate.  The aggregate is the lane sum,
        mesh-reduced like ``commit``; ``lane_sched`` advances by the
        UNREDUCED masked calls (the per-shard schedule invariant).  The
        step stamp advances once per decode step and the aggregate
        cumulative counters ring-append at the dynamic cadence.
        """
        m = jnp.asarray(active, jnp.int32)
        d_calls = delta.calls * m[:, None]
        d_values = delta.values * m[:, None].astype(delta.values.dtype)
        d_samples = delta.samples * m[:, None]
        agg = self.reduce_delta(plan_lib.CompactDelta(
            calls=d_calls.sum(axis=0),
            values=d_values.sum(axis=0),
            samples=d_samples.sum(axis=0),
        ))
        calls = lstate.calls + agg.calls
        values = lstate.values + agg.values
        samples = lstate.samples + agg.samples
        step = lstate.step + 1
        ring = lstate.ring
        if ring is not None:
            ring = telemetry_lib.ring_append(
                ring,
                plan_lib.CompactDelta(calls=calls, values=values,
                                      samples=samples),
                lstate.tparams, step,
            )
        return dataclasses.replace(
            lstate,
            lane_calls=lstate.lane_calls + d_calls,
            lane_values=lstate.lane_values + d_values,
            lane_samples=lstate.lane_samples + d_samples,
            lane_sched=lstate.lane_sched + d_calls,
            calls=calls, values=values, samples=samples,
            step=step, ring=ring,
        )

    def admit_lane(self, lstate: LaneMonitorState, lane,
                   delta: plan_lib.CompactDelta,
                   owned=None) -> LaneMonitorState:
        """Seed lane ``lane`` with an admitted request's prefill delta.

        Pure and trace-safe (``lane`` may be a traced i32 scalar — the
        serve driver jits this into its admission program, so admitting
        never re-traces or runs eager device ops).

        The lane's counter rows RESET to the delta (the previous occupant
        was harvested at retirement), its schedule base restarts with it,
        and the delta folds into the aggregate — so the aggregate matches
        what a serial engine would have accumulated over the same
        requests.  Advances the step stamp (an admission is a monitored
        event, like the serial engine's wrapped prefill).

        ``owned`` (traced bool scalar, sharded serving): the lane rows are
        PER-SHARD under ``shard_map`` — only the shard owning the (local,
        clamped) ``lane`` index takes the row reset; the REPLICATED
        aggregate/ring still folds the delta in unconditionally, exactly
        once per shard's own copy (the prefill delta is replicated, never
        psum-reduced — a psum would count it N times).
        """

        def seed(rows, row):
            if owned is None:
                return rows.at[lane].set(row)
            return rows.at[lane].set(jnp.where(owned, row, rows[lane]))

        calls = lstate.calls + delta.calls
        values = lstate.values + delta.values
        samples = lstate.samples + delta.samples
        step = lstate.step + 1
        ring = lstate.ring
        if ring is not None:
            ring = telemetry_lib.ring_append(
                ring,
                plan_lib.CompactDelta(calls=calls, values=values,
                                      samples=samples),
                lstate.tparams, step,
            )
        return dataclasses.replace(
            lstate,
            lane_calls=seed(lstate.lane_calls, delta.calls),
            lane_values=seed(lstate.lane_values, delta.values),
            lane_samples=seed(lstate.lane_samples, delta.samples),
            lane_sched=seed(lstate.lane_sched, delta.calls),
            calls=calls, values=values, samples=samples,
            step=step, ring=ring,
        )

    @staticmethod
    def lane_counters_host(delta: plan_lib.CompactDelta
                           ) -> plan_lib.CompactDelta:
        """Materialize a (possibly still in-flight) lane delta to host
        numpy — the request-completion sync point."""
        return plan_lib.CompactDelta(
            calls=np.asarray(delta.calls),
            values=np.asarray(delta.values),
            samples=np.asarray(delta.samples),
        )

    # -- the transformation ----------------------------------------------
    def scan(self, body: Callable, steps_per_commit: int | None = None, *,
             wrapped: bool = False, unroll: int = 1) -> Callable:
        """The K-step **megastep** driver: one commit-boundary crossing per
        ``steps_per_commit`` monitored steps.

        ``wrap`` pays the per-call fixed cost — open a collector, commit,
        round-trip the host dispatch path — once per step; once steps are
        short (~100µs) that cost dominates.  ``scan`` drives K steps inside
        ONE ``lax.scan`` over a single ``MonitorState`` carry instead:

        * compact ``CompactDelta`` counters accumulate in-carry (the same
          dense-lane machinery ``scan_with_counters`` rides);
        * the multiplex schedule base ``sched_calls`` advances K× PER-SHARD
          inside the scan — the mesh-reduced totals never feed the schedule
          (the ROADMAP invariant);
        * ``ring_append`` runs INSIDE the scan body, once per inner step, so
          ``TelemetryParams.cadence`` snapshots land on their true step
          stamps even when the cadence does not divide K.

        ``body(carry, x) -> (carry', y)`` is an ordinary scan body using
        ``scalpel.function``/``probe``; the driver opens the collection
        region and commits per inner step.  With ``wrapped=True`` the body
        instead has the wrapped signature ``body(mstate, carry, x) ->
        ((carry', y), mstate')`` and owns its regions — it must fold its
        delta through ``commit`` exactly once (custom threading, e.g. the
        train step's ``value_and_grad`` aux collection).

        Returns ``mega(mstate, carry, xs=None) -> ((carry', ys), mstate')``.
        ``xs`` (per-step inputs stacked on a leading axis) sets the step
        count when given; otherwise ``steps_per_commit`` does.  Dynamic
        knob swaps (``mon.sync``) take effect at the next megastep boundary
        — params/tparams are scan constants, so the adaptive loop reacts at
        megastep granularity (see README).
        """
        if steps_per_commit is not None and steps_per_commit < 1:
            raise ValueError(
                f"steps_per_commit must be >= 1, got {steps_per_commit}")

        def mega(mstate: MonitorState, carry, xs=None):
            if xs is None and steps_per_commit is None:
                raise ValueError(
                    "Monitor.scan needs steps_per_commit or per-step xs")
            # params/tparams are loop constants, not carries: they cannot
            # change inside a megastep, and keeping them out of the carry
            # is what lets the jit boundary drop them from the outputs
            params, tparams = mstate.params, mstate.tparams

            def rebuild(leaves):
                calls, values, samples, sched, step, ring = leaves
                return MonitorState(
                    calls=calls, values=values, samples=samples,
                    sched_calls=sched, step=step, ring=ring,
                    params=params, tparams=tparams,
                    fingerprint=self.spec.fingerprint,
                )

            def sbody(c, x):
                leaves, cur = c
                ms = rebuild(leaves)
                if wrapped:
                    (cur2, y), ms2 = body(ms, cur, x)
                else:
                    base = ms.sched_calls if ms.sched_calls is not None \
                        else ms.calls
                    with self.open(params, calls_base=base) as col:
                        cur2, y = body(cur, x)
                    ms2 = self.commit(ms, col.compact_delta())
                return ((ms2.calls, ms2.values, ms2.samples,
                         ms2.sched_calls, ms2.step, ms2.ring), cur2), y

            init = ((mstate.calls, mstate.values, mstate.samples,
                     mstate.sched_calls, mstate.step, mstate.ring), carry)
            (leaves, carry2), ys = jax.lax.scan(
                sbody, init, xs,
                length=steps_per_commit if xs is None else None,
                unroll=unroll,
            )
            return (carry2, ys), rebuild(leaves)

        mega.__name__ = f"scalpel_megastep[{getattr(body, '__name__', 'fn')}]"
        mega.monitor = self
        return mega

    def wrap(self, fn: Callable, steps_per_commit: int = 1) -> Callable:
        """``fn(*args, **kw) -> out``  ⟶  ``(mstate, *args, **kw) -> (out,
        mstate')`` — the functional monitored step.

        ``fn`` is ordinary model/step code using ``scalpel.function`` /
        ``probe`` / ``scan_with_counters``; nested wrapped calls compose
        (the inner region folds into the outer collector's stack).

        ``steps_per_commit > 1`` turns the wrapped call into a K-step
        megastep on the ``scan`` driver: ``fn`` must then be a self-map of
        ONE positional argument (``fn(x) -> x'`` with the output matching
        the input's structure — a step function whose result feeds the next
        step), and one wrapped call advances the state by K steps while
        crossing the commit/dispatch boundary once.
        """
        if steps_per_commit > 1:
            mega = self.scan(lambda c, _: (fn(c), None),
                             steps_per_commit=steps_per_commit)

            def wrapped(mstate: MonitorState, x):
                (x2, _), ms2 = mega(mstate, x)
                return x2, ms2

            wrapped.__name__ = \
                f"scalpel_monitor[{getattr(fn, '__name__', 'fn')}" \
                f"/K={steps_per_commit}]"
            wrapped.monitor = self
            return wrapped

        def wrapped(mstate: MonitorState, *args, **kwargs):
            # the collector's call-count base is the PER-SHARD schedule
            # base, never the mesh-reduced totals (``calls`` doubles as it
            # for monitors that never reduce)
            base = mstate.sched_calls if mstate.sched_calls is not None \
                else mstate.calls
            with self.open(mstate.params, calls_base=base) as col:
                out = fn(*args, **kwargs)
            return out, self.commit(mstate, col.compact_delta())

        wrapped.__name__ = f"scalpel_monitor[{getattr(fn, '__name__', 'fn')}]"
        wrapped.monitor = self
        return wrapped

    def jit(self, fn: Callable, *, steps_per_commit: int = 1,
            donate_argnums=(), donate_state: bool = False,
            **jit_kwargs) -> Callable:
        """``jax.jit(wrap(fn))`` with the state boundary drawn leaf-wise.

        ``wrap`` alone returns the whole MonitorState from the jitted
        program — including the runtime ``params``/``tparams`` it only
        READS, which jit must then copy into fresh output buffers every
        call.  ``Monitor.jit`` keeps those knobs as inputs only and
        reattaches the caller's objects outside the graph, so the compiled
        step outputs exactly what changed: the compact counter lanes, the
        step stamp, and the ring.  Semantically identical to
        ``jax.jit(mon.wrap(fn))``; measurably cheaper per call.

        ``donate_argnums`` refer to ``fn``'s OWN positional args (e.g. a
        decode cache), and are remapped past the state leaves.
        ``donate_state=True`` additionally donates the counter lanes and
        step stamp (XLA reuses their buffers for the outputs — the
        steady-state loop allocates nothing for counters).  Only safe when
        nothing else holds the previous state's counter arrays: runtime
        observers (``runtime.on_step(mstate.counters)``) keep such
        references, so leave it off in loops that publish to a runtime.
        The ring is NEVER donated (the telemetry drain thread reads it).

        ``steps_per_commit > 1`` compiles the K-step megastep form of
        ``wrap`` (see there for the self-map contract): one dispatch per K
        steps, with the same leaf-wise boundary.
        """
        return self.jit_wrapped(
            self.wrap(fn, steps_per_commit=steps_per_commit),
            donate_argnums=donate_argnums, donate_state=donate_state,
            _name=getattr(fn, "__name__", "fn"), **jit_kwargs,
        )

    def jit_wrapped(self, wrapped: Callable, *, donate_argnums=(),
                    donate_state: bool = False, _name: str | None = None,
                    **jit_kwargs) -> Callable:
        """Draw the leaf-wise jit boundary around an ALREADY-wrapped step.

        ``wrapped(mstate, *args) -> (out, mstate')`` — anything with the
        wrapped signature: ``mon.wrap(fn)``, a ``mon.scan`` megastep, or a
        hand-built step (e.g. ``train.make_train_megastep``) that opens its
        own regions and commits itself.  The compiled program takes the
        state leaf-wise, keeps the read-only ``params``/``tparams`` as
        inputs only (reattached outside the graph — they stop round-tripping
        the step), and outputs exactly what changed: counter lanes, step
        stamp, ring.  Donation semantics as in ``jit``.

        The returned callable exposes the underlying ``jax.jit`` object as
        ``._cjit`` (for cache-stats/no-retrace assertions and lowering/HLO
        inspection: the donation checks the benchmarks record).
        """

        def core(calls, values, samples, sched_calls, step, ring, params,
                 tparams, *args):
            ms = MonitorState(
                calls=calls, values=values, samples=samples,
                sched_calls=sched_calls, step=step, ring=ring,
                params=params, tparams=tparams,
                fingerprint=self.spec.fingerprint,
            )
            out, ms2 = wrapped(ms, *args)
            return out, (ms2.calls, ms2.values, ms2.samples,
                         ms2.sched_calls, ms2.step, ms2.ring)

        n_state = 8
        donate = tuple(n_state + int(i) for i in donate_argnums)
        if donate_state:
            # counters + step (+ the schedule base when carried — a None
            # leaf has no buffers to donate)
            sched = (3,) if self._carries_sched else ()
            donate = (0, 1, 2) + sched + (4,) + donate
        cjit = jax.jit(core, donate_argnums=donate, **jit_kwargs)

        def stepped(mstate: MonitorState, *args):
            out, (calls, values, samples, sched_calls, step, ring) = cjit(
                mstate.calls, mstate.values, mstate.samples,
                mstate.sched_calls, mstate.step, mstate.ring,
                mstate.params, mstate.tparams, *args,
            )
            # direct construction (not dataclasses.replace): this wrapper
            # runs once per step on the host, keep it lean
            return out, MonitorState(
                calls=calls, values=values, samples=samples,
                sched_calls=sched_calls, step=step, ring=ring,
                params=mstate.params, tparams=mstate.tparams,
                fingerprint=mstate.fingerprint,
            )

        stepped.__name__ = "scalpel_monitor_jit[{}]".format(
            _name if _name is not None
            else getattr(wrapped, "__name__", "fn"))
        stepped.monitor = self
        stepped._cjit = cjit
        return stepped

    def shard_wrap(self, fn: Callable, mesh, in_specs, out_specs) -> Callable:
        """``wrap(fn)`` run per-shard under ``shard_map`` with cluster-wide
        counters.

        ``in_specs``/``out_specs`` describe ``fn``'s own args/outputs; the
        MonitorState is replicated automatically (counters are identical on
        every shard after the in-body ``psum``).  ``check_rep=False`` is
        required: the probe path's mask ``lax.cond`` confuses shard_map's
        replication checker (a JAX limitation, not a semantic one — the
        2-device test asserts exact equality with the per-shard sum).
        """
        import copy

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        mon = self
        if self.counter_axes == "auto":
            # resolve against THIS mesh, not the ambient partition context
            mon = copy.copy(self)
            mon.counter_axes = tuple(mesh.axis_names)
        wrapped = mon.wrap(fn)
        # NB: PartitionSpec subclasses tuple — a bare spec is ONE spec, not
        # a sequence of per-arg specs
        if isinstance(in_specs, PartitionSpec) or not isinstance(
                in_specs, (tuple, list)):
            in_specs = (in_specs,)
        rep = PartitionSpec()
        sharded = shard_map(
            wrapped, mesh=mesh, in_specs=(rep,) + tuple(in_specs),
            out_specs=(out_specs, rep), check_rep=False,
        )
        sharded.monitor = mon
        return sharded

    # -- views / reporting -------------------------------------------------
    def counter_state(self, mstate: MonitorState) -> CounterState:
        """The legacy padded-view CounterState (for interop only — reports
        read the compact layout directly)."""
        return mstate.counters.expand(self.spec)

    def reports(self, mstate) -> list[report_lib.ScopeReport]:
        return report_lib.build(self.spec, mstate)

    def report(self, mstate, title: str = "ScALPEL report") -> str:
        return report_lib.format_text(self.reports(mstate), title=title)

    def estimates(self, mstate) -> dict[str, dict[str, float]]:
        return report_lib.estimates(self.spec, mstate)

    # -- checkpoint integration -------------------------------------------
    def checkpoint_payload(self, mstate: MonitorState) -> dict:
        """The array leaves worth persisting (counters + the per-shard
        schedule base + step; the ring is transient device state, params
        are config)."""
        payload = {
            "calls": mstate.calls,
            "values": mstate.values,
            "samples": mstate.samples,
            "step": mstate.step,
        }
        if mstate.sched_calls is not None:
            payload["sched_calls"] = mstate.sched_calls
        return payload

    def restore(self, mstate: MonitorState, payload: dict) -> MonitorState:
        """Graft a checkpoint payload back onto a live state pytree."""
        return dataclasses.replace(
            mstate,
            calls=payload["calls"], values=payload["values"],
            samples=payload["samples"],
            sched_calls=payload.get("sched_calls", mstate.sched_calls),
            step=payload["step"],
        )

    def check_resume(self, meta: dict | None, strict: bool = True):
        """Validate checkpoint metadata against the live compiled plans
        (see ``check_plan_metadata`` for the contract)."""
        return check_plan_metadata(self.spec.fingerprint, meta,
                                   strict=strict)


def check_plan_metadata(fingerprint: str, meta: dict | None,
                        strict: bool = True):
    """The shared resume-time plan attestation.

    Returns True on match, None when the metadata carries no fingerprint
    (pre-Monitor checkpoints — the caller decides whether the rest of the
    payload is even readable).  On mismatch: raises (``strict=True``) or
    warns and returns False — resuming counters produced by different
    probe plans silently mis-attributes every accumulated lane.
    """
    fp = (meta or {}).get("plan_fingerprint")
    if not fp:
        return None
    if fp == fingerprint:
        return True
    msg = (
        f"resume plan mismatch: checkpoint counters come from plan "
        f"{fp[:12]}, live spec compiles to {fingerprint[:12]} — the "
        "monitoring spec changed since the checkpoint was written"
    )
    if strict:
        raise RuntimeError(msg)
    import warnings

    warnings.warn(msg, RuntimeWarning, stacklevel=3)
    return False


def monitored(spec: MonitorSpec, params: MonitorParams | None = None, **kw):
    """Decorator form: ``@scalpel.monitored(spec)`` over a step function.

    The decorated function gains the wrapped signature
    ``(mstate, *args, **kwargs) -> (out, mstate')`` plus ``.monitor`` (the
    Monitor) and ``.init`` (fresh-state constructor)::

        @scalpel.monitored(spec)
        def step(x):
            with scalpel.function("f"):
                scalpel.probe(x=x)
            return x * 2

        mstate = step.init()
        out, mstate = jax.jit(step)(mstate, x)
    """

    def deco(fn: Callable) -> Callable:
        mon = Monitor(spec, params, **kw)
        wrapped = mon.wrap(fn)
        wrapped.init = mon.init
        return wrapped

    return deco
