"""ScALPEL configuration-file grammar (paper Table 1), parse + serialize.

The format is kept byte-compatible with the paper's layout::

    BINARY=my_a.out          // name of the binary
    NO_FUNCTIONS=1           // number of functions
    [FUNCTION]
    FUNC_NAME=foo            // name of the function (scope path here)
    NO_EVENTS=2              // total number of events
    [EVENT]
    ID=DATA_CACHE_MISSES     // the event name or id
    NO_SUBEVENTS=0           // number of subevents
    [/EVENT]
    [EVENT]
    ID=DISPATCHED_FPU
    NO_SUBEVENTS=3
    [SUBEVENT]
    ID=OPS_ADD
    ID=OPS_ADD_PIPE_LOAD_OPS
    ID=OPS_MULTIPLY_PIPE_LOAD_OPS
    [/SUBEVENT]
    [/EVENT]
    [/FUNCTION]

Extensions (all optional, default to the paper's exhaustive behaviour):

* ``MULTIPLEX_PERIOD=<n>`` inside [FUNCTION] — cycle event sets every n calls
  (the paper's case study used 100).
* ``SET=<k>`` inside [EVENT] — assign the event to multiplex set k.  Without
  SET keys all events share set 0 (exhaustive monitoring).
* ``TENSOR=<name>`` inside [EVENT] — bind the event to a named probe tensor
  (equivalently write ``ID=ACT_RMS:x``).

A config names the *monitored subset*; the compile-time set (MonitorSpec) may
be larger.  ``apply_config`` folds a config into (spec, params): scopes in the
config are enabled, all others disabled — reloading a config at runtime is a
mask/period swap, no re-trace (paper §3.3, SIGUSR1 reload).
"""
from __future__ import annotations

import dataclasses

from .context import EventSpec, MonitorSpec, ScopeContext
from .counters import MonitorParams


@dataclasses.dataclass
class EventConfig:
    spec: EventSpec
    set_index: int = 0


@dataclasses.dataclass
class FunctionConfig:
    name: str
    events: list[EventConfig] = dataclasses.field(default_factory=list)
    multiplex_period: int = 1

    def to_scope_context(self) -> ScopeContext:
        if not self.events:
            return ScopeContext.exhaustive(self.name, [])
        n_sets = max(e.set_index for e in self.events) + 1
        sets: list[list[EventSpec]] = [[] for _ in range(n_sets)]
        for e in self.events:
            sets[e.set_index].append(e.spec)
        sets = [s for s in sets if s]  # drop empty sets
        if len(sets) == 1:
            ctx = ScopeContext.exhaustive(self.name, sets[0])
            return dataclasses.replace(
                ctx, default_period=max(1, self.multiplex_period)
            )
        return ScopeContext.multiplexed(
            self.name, sets, period=max(1, self.multiplex_period)
        )


@dataclasses.dataclass
class ScalpelConfig:
    binary: str = "a.out"
    functions: list[FunctionConfig] = dataclasses.field(default_factory=list)

    @property
    def scope_names(self) -> list[str]:
        return [f.name for f in self.functions]

    def to_spec(self) -> MonitorSpec:
        return MonitorSpec.of([f.to_scope_context() for f in self.functions])


class ConfigError(ValueError):
    pass


def _strip(line: str) -> str:
    # '//' starts a comment (paper style); tolerate '#' too.
    for marker in ("//", "#"):
        if marker in line:
            line = line[: line.index(marker)]
    return line.strip()


def parse(text: str) -> ScalpelConfig:
    cfg = ScalpelConfig()
    fn: FunctionConfig | None = None
    ev: EventConfig | None = None
    in_sub = False
    declared_functions = declared_events = declared_subs = None

    for ln, raw in enumerate(text.splitlines(), 1):
        line = _strip(raw)
        if not line:
            continue

        def err(msg):
            raise ConfigError(f"line {ln}: {msg} ({raw.strip()!r})")

        if line == "[FUNCTION]":
            if fn is not None:
                err("nested [FUNCTION]")
            fn = FunctionConfig(name="")
            continue
        if line == "[/FUNCTION]":
            if fn is None:
                err("[/FUNCTION] without [FUNCTION]")
            if not fn.name:
                err("FUNCTION block missing FUNC_NAME")
            if declared_events is not None and len(fn.events) != declared_events:
                err(
                    f"NO_EVENTS={declared_events} but {len(fn.events)} "
                    "[EVENT] blocks found"
                )
            declared_events = None
            cfg.functions.append(fn)
            fn = None
            continue
        if line == "[EVENT]":
            if fn is None:
                err("[EVENT] outside [FUNCTION]")
            if ev is not None:
                err("nested [EVENT]")
            ev = EventConfig(spec=EventSpec(event=""))
            continue
        if line == "[/EVENT]":
            if ev is None:
                err("[/EVENT] without [EVENT]")
            if not ev.spec.event:
                err("EVENT block missing ID")
            base = ev.spec
            subs = getattr(ev, "_subs", [])
            if declared_subs not in (None, len(subs)):
                err(f"NO_SUBEVENTS={declared_subs} but {len(subs)} subevent IDs")
            declared_subs = None
            if subs:
                for s in subs:
                    fn.events.append(
                        EventConfig(
                            spec=dataclasses.replace(base, subevent=s),
                            set_index=ev.set_index,
                        )
                    )
            else:
                fn.events.append(ev)
            ev = None
            continue
        if line == "[SUBEVENT]":
            if ev is None:
                err("[SUBEVENT] outside [EVENT]")
            in_sub = True
            continue
        if line == "[/SUBEVENT]":
            in_sub = False
            continue

        if "=" not in line:
            err("expected KEY=VALUE")
        key, val = (p.strip() for p in line.split("=", 1))

        if in_sub:
            if key != "ID":
                err("only ID= allowed inside [SUBEVENT]")
            if not hasattr(ev, "_subs"):
                ev._subs = []  # type: ignore[attr-defined]
            ev._subs.append(val)  # type: ignore[attr-defined]
            continue

        if ev is not None:
            if key == "ID":
                parsed = EventSpec.parse(val)
                ev.spec = dataclasses.replace(
                    parsed, subevent=ev.spec.subevent or parsed.subevent
                )
            elif key == "NO_SUBEVENTS":
                declared_subs = int(val) or None
            elif key == "SET":
                ev.set_index = int(val)
            elif key == "TENSOR":
                ev.spec = dataclasses.replace(ev.spec, tensor=val)
            else:
                err(f"unknown [EVENT] key {key}")
            continue

        if fn is not None:
            if key == "FUNC_NAME":
                fn.name = val
            elif key == "NO_EVENTS":
                declared_events = int(val)
            elif key == "MULTIPLEX_PERIOD":
                fn.multiplex_period = int(val)
            else:
                err(f"unknown [FUNCTION] key {key}")
            continue

        if key == "BINARY":
            cfg.binary = val
        elif key == "NO_FUNCTIONS":
            declared_functions = int(val)
        else:
            err(f"unknown top-level key {key}")

    if fn is not None:
        raise ConfigError("unterminated [FUNCTION] block")
    if declared_functions is not None and declared_functions != len(cfg.functions):
        raise ConfigError(
            f"NO_FUNCTIONS={declared_functions} but "
            f"{len(cfg.functions)} [FUNCTION] blocks found"
        )
    return cfg


def parse_file(path: str) -> ScalpelConfig:
    with open(path) as f:
        return parse(f.read())


def serialize(cfg: ScalpelConfig) -> str:
    out = [f"BINARY={cfg.binary}", f"NO_FUNCTIONS={len(cfg.functions)}"]
    for fn in cfg.functions:
        out.append("[FUNCTION]")
        out.append(f"FUNC_NAME={fn.name}")
        if fn.multiplex_period != 1:
            out.append(f"MULTIPLEX_PERIOD={fn.multiplex_period}")
        out.append(f"NO_EVENTS={len(fn.events)}")
        for e in fn.events:
            out.append("[EVENT]")
            sid = e.spec.event
            if e.spec.tensor:
                sid += f":{e.spec.tensor}"
            if e.spec.subevent:
                sid += f"/{e.spec.subevent}"
            out.append(f"ID={sid}")
            if e.set_index:
                out.append(f"SET={e.set_index}")
            out.append("NO_SUBEVENTS=0")
            out.append("[/EVENT]")
        out.append("[/FUNCTION]")
    return "\n".join(out) + "\n"


# --------------------------------------------------------------------------
# Folding a config into a live (spec, params) pair.
# --------------------------------------------------------------------------

def apply_config(
    spec: MonitorSpec, cfg: ScalpelConfig, strict: bool = False
) -> tuple[MonitorParams, list[str]]:
    """Derive MonitorParams from a config against the compile-time ``spec``.

    Scopes named in the config are enabled with their period; all other
    scopes are masked off (interception only).  Config events that are not in
    the scope's compiled context cannot be added without a re-trace — they
    are reported back (and raise if ``strict``), mirroring the paper's rule
    that runtime additions must come from the compile-time set.
    """
    params = MonitorParams.all_off(spec)
    unsatisfiable: list[str] = []
    import numpy as np

    scope_mask = np.zeros((spec.n_scopes,), np.float32)
    slot_mask = np.zeros((spec.n_scopes, spec.max_slots), np.float32)
    period = np.asarray(params.period).copy()

    for fn in cfg.functions:
        if fn.name not in spec:
            unsatisfiable.append(f"scope:{fn.name}")
            continue
        si = spec.scope_index(fn.name)
        scope_mask[si] = 1.0
        period[si] = max(1, fn.multiplex_period)
        ctx = spec.context(fn.name)
        for e in fn.events:
            sid = e.spec.slot_id
            if sid in ctx.slot_ids:
                slot_mask[si, ctx.slot_ids.index(sid)] = 1.0
            else:
                unsatisfiable.append(f"slot:{fn.name}:{sid}")
        if not fn.events:  # bare FUNC block: enable all compiled slots
            slot_mask[si, : len(ctx.slots)] = 1.0

    if strict and unsatisfiable:
        raise ConfigError(
            "config requests monitoring outside the compile-time set "
            f"(re-trace required): {unsatisfiable}"
        )
    import jax.numpy as jnp

    return (
        MonitorParams(
            scope_mask=jnp.asarray(scope_mask),
            slot_mask=jnp.asarray(slot_mask),
            period=jnp.asarray(period),
        ),
        unsatisfiable,
    )
