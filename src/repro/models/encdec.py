"""Encoder–decoder transformer (family 'encdec' — seamless-m4t backbone).

The [audio] modality frontend is a STUB per the assignment: ``input_specs``
provides precomputed speech-frame embeddings [b, s_src, d_model]; the
encoder is a bidirectional transformer over those frames, the decoder is a
causal transformer with cross-attention.  n_layers applies to each stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.dist.partition import shard
from . import layers as L
from .params import stacked
from .spec import ModelConfig


def cross_attention_specs(cfg: ModelConfig) -> dict:
    return L.attention_specs(cfg)


def enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.rms_norm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.rms_norm_spec(cfg.d_model),
        "ffn": L.mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.rms_norm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln_x": L.rms_norm_spec(cfg.d_model),
        "xattn": cross_attention_specs(cfg),
        "ln2": L.rms_norm_spec(cfg.d_model),
        "ffn": L.mlp_specs(cfg),
    }


def specs(cfg: ModelConfig) -> dict:
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": L.embed_specs(cfg),
        "frame_norm": L.rms_norm_spec(cfg.d_model),
        "encoder": stacked(lambda: enc_layer_specs(cfg), n_enc),
        "enc_norm": L.rms_norm_spec(cfg.d_model),
        "decoder": stacked(lambda: dec_layer_specs(cfg), cfg.n_layers),
        "final_norm": L.rms_norm_spec(cfg.d_model),
    }


def _cross_attend(cfg: ModelConfig, p, x, enc_kv, positions_q):
    """Cross-attention: q from decoder x, k/v precomputed from encoder."""
    with scalpel.function("xattn"):
        k, v = enc_kv
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        q = L.rope(q, positions_q, cfg.rope_theta)
        q = shard(q, "batch", None, "heads", None)
        if q.shape[1] * k.shape[1] <= 256 * 256 or cfg.attn_impl == "reference":
            out = L.reference_attention(cfg, q, k, v, causal=False)
        else:
            out = L.flash_attention_xla(cfg, q, k, v, causal=False)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        scalpel.probe(out=y)
        return y


def _enc_kv(cfg: ModelConfig, p, enc_out):
    b, s, _ = enc_out.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    k = L.rope(k, pos, cfg.rope_theta)
    return k, v


def encode(cfg: ModelConfig, params, frames):
    """frames: [b, s_src, d] precomputed frontend embeddings."""
    with scalpel.function("encoder"):
        x = L.rms_norm(frames.astype(L.dt(cfg)), params["frame_norm"])
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(carry, lp):
            xx = carry
            with scalpel.function("layer"):
                h = L.rms_norm(xx, lp["ln1"])
                xx = xx + L.attention(cfg, lp["attn"], h, positions,
                                      causal=False)
                h = L.rms_norm(xx, lp["ln2"])
                xx = xx + L.mlp(cfg, lp["ffn"], h)
            return xx, None

        x, _ = scalpel.scan_with_counters(body, x, params["encoder"],
                                          remat=L.remat_policy(cfg))
        x = L.rms_norm(x, params["enc_norm"])
        scalpel.probe(out=x)
        return x


def decode(cfg: ModelConfig, params, enc_out, tokens):
    x = L.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        xx = carry
        with scalpel.function("layer"):
            h = L.rms_norm(xx, lp["ln1"])
            xx = xx + L.attention(cfg, lp["attn"], h, positions)
            h = L.rms_norm(xx, lp["ln_x"])
            xx = xx + _cross_attend(cfg, lp["xattn"], h,
                                    _enc_kv(cfg, lp["xattn"], enc_out),
                                    positions)
            h = L.rms_norm(xx, lp["ln2"])
            xx = xx + L.mlp(cfg, lp["ffn"], h)
        return xx, None

    x, _ = scalpel.scan_with_counters(body, x, params["decoder"],
                                      remat=L.remat_policy(cfg))
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed(cfg, params["embed"], x)


def forward(cfg: ModelConfig, params, tokens, prefix_embeds=None,
            frames=None):
    enc_out = encode(cfg, params, frames)
    return decode(cfg, params, enc_out, tokens)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch["tokens"],
                     frames=batch["enc_frames"])
    return L.cross_entropy(logits, batch["targets"], batch.get("mask"))


# -- serving ---------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False, src_len: int | None = None):
    kvd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    src_len = src_len or cache_len
    kv = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd)
    xkv = (cfg.n_layers, batch, src_len, cfg.n_kv_heads, hd)
    cache = {
        "k": jax.ShapeDtypeStruct(kv, kvd),
        "v": jax.ShapeDtypeStruct(kv, kvd),
        "xk": jax.ShapeDtypeStruct(xkv, kvd),
        "xv": jax.ShapeDtypeStruct(xkv, kvd),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if abstract:
        return cache
    return jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype), cache,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def cache_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", None, None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": ()}


def prefill(cfg: ModelConfig, params, tokens, cache_len: int,
            prefix_embeds=None, frames=None):
    """Encode source; run decoder prompt; build self+cross KV caches."""
    enc_out = encode(cfg, params, frames)
    x = L.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kvd = jnp.dtype(cfg.compute_dtype)

    def body(carry, lp):
        xx = carry
        with scalpel.function("layer"):
            h = L.rms_norm(xx, lp["ln1"])
            with scalpel.function("attn"):
                q, k, v = L._qkv(cfg, lp["attn"], h, positions)
                if s <= 256 or cfg.attn_impl == "reference":
                    a = L.reference_attention(cfg, q, k, v, True)
                else:
                    a = L.flash_attention_xla(cfg, q, k, v, True)
                y = jnp.einsum("bshk,hkd->bsd", a,
                               lp["attn"]["wo"].astype(xx.dtype))
            xx = xx + y
            h = L.rms_norm(xx, lp["ln_x"])
            xk, xv = _enc_kv(cfg, lp["xattn"], enc_out)
            xx = xx + _cross_attend(cfg, lp["xattn"], h, (xk, xv), positions)
            h = L.rms_norm(xx, lp["ln2"])
            xx = xx + L.mlp(cfg, lp["ffn"], h)
        pad = cache_len - s
        kc = jnp.pad(k.astype(kvd), ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(kvd), ((0, 0), (0, pad), (0, 0), (0, 0)))
        return xx, {"k": kc, "v": vc, "xk": xk.astype(kvd),
                    "xv": xv.astype(kvd)}

    x, kvs = scalpel.scan_with_counters(body, x, params["decoder"])
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x[:, -1:, :])
    cache = {"k": kvs["k"], "v": kvs["v"], "xk": kvs["xk"],
             "xv": kvs["xv"], "pos": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(cfg: ModelConfig, params, cache, tokens):
    x = L.embed(cfg, params["embed"], tokens)
    pos = cache["pos"]
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    def body(carry, layer_in):
        lp, kc, vc, xk, xv = layer_in
        xx = carry
        with scalpel.function("layer"):
            h = L.rms_norm(xx, lp["ln1"])
            y, kc, vc = L.decode_attention(cfg, lp["attn"], h, kc, vc, pos)
            xx = xx + y
            h = L.rms_norm(xx, lp["ln_x"])
            xx = xx + _cross_attend(cfg, lp["xattn"], h,
                                    (xk.astype(xx.dtype),
                                     xv.astype(xx.dtype)), positions)
            h = L.rms_norm(xx, lp["ln2"])
            xx = xx + L.mlp(cfg, lp["ffn"], h)
        return xx, {"k": kc, "v": vc}

    x, kvs = scalpel.scan_with_counters(
        body, x,
        (params["decoder"], cache["k"], cache["v"], cache["xk"],
         cache["xv"]),
    )
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    new_cache = dict(cache, k=kvs["k"], v=kvs["v"], pos=pos + 1)
    return logits, new_cache
