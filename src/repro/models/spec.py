"""Model configuration — one dataclass covering all assigned families.

Families:
  dense   — decoder-only transformer (GQA, optional qk_norm, no-bias)
  moe     — dense backbone with MoE FFN (top-k, optional dense residual)
  ssm     — xLSTM (alternating mLSTM / sLSTM blocks)
  hybrid  — Zamba2 (Mamba2 backbone + shared attention block)
  encdec  — encoder-decoder (seamless: audio frontend stub + text decoder)
  vlm     — pixtral (ViT frontend stub + dense decoder backbone)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_ff: int = 0             # width of the parallel dense FFN (0: = d_ff)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64        # recurrent state per head/channel
    d_conv: int = 4          # depthwise conv width (mamba)
    expand: int = 2          # d_inner = expand * d_model
    head_dim: int = 64       # mamba2 head dim
    chunk: int = 256         # chunked-scan block length
    slstm_every: int = 2     # xlstm: every k-th block is sLSTM (rest mLSTM)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6      # shared attention block applied every k layers
    concat_embedding: bool = True  # zamba: shared block sees [x, embed] concat


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    head_dim: int = 0        # 0 -> d_model // n_heads
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 1e6
    sliding_window: int = 0  # 0: full attention
    # family extensions
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    hybrid: HybridConfig = HybridConfig()
    n_encoder_layers: int = 0   # encdec only
    tie_embeddings: bool = False
    # numerics / execution policy (overridable per run)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none
    attn_impl: str = "flash_xla" # flash_xla | pallas | reference
    flash_block_q: int = 512
    flash_block_kv: int = 1024
    # sub-quadratic? (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1    # gradient-accumulation splits (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
