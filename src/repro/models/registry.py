"""Model registry: family -> module, plus the uniform Arch facade used by
train/serve/launch code.

Every architecture supports:
  specs/init/abstract_params — parameter tree (concrete or ShapeDtypeStruct)
  loss_fn(params, batch)     — training loss
  prefill(params, tokens, cache_len, **extras) -> (cache, logits)
  decode_step(params, cache, tokens) -> (logits, cache)
  input_specs(shape)         — ShapeDtypeStruct stand-ins per assigned shape
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import encdec, hybrid, transformer, xlstm
from . import params as params_lib
from .spec import ModelConfig, ShapeConfig, SHAPES

FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": xlstm,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclasses.dataclass(frozen=True)
class Arch:
    cfg: ModelConfig

    @property
    def module(self):
        return FAMILY_MODULES[self.cfg.family]

    # -- params ----------------------------------------------------------
    def param_specs(self):
        return self.module.specs(self.cfg)

    def init(self, rng):
        return params_lib.init_tree(
            self.param_specs(), rng, jnp.dtype(self.cfg.param_dtype)
        )

    def abstract_params(self):
        return params_lib.abstract_tree(
            self.param_specs(), jnp.dtype(self.cfg.param_dtype)
        )

    def param_axes(self):
        return params_lib.axes_tree(self.param_specs())

    def n_params(self) -> int:
        return params_lib.count_params(self.param_specs())

    # -- steps -----------------------------------------------------------
    def loss_fn(self, params, batch):
        return self.module.loss_fn(self.cfg, params, batch)

    def forward(self, params, batch):
        kw = {}
        if self.cfg.family == "encdec":
            kw["frames"] = batch["enc_frames"]
        prefix = batch.get("img_embeds") if self.cfg.family == "vlm" else None
        return self.module.forward(self.cfg, params, batch["tokens"],
                                   prefix_embeds=prefix, **kw)

    def prefill(self, params, batch, cache_len: int):
        kw = {}
        if self.cfg.family == "encdec":
            kw["frames"] = batch["enc_frames"]
        prefix = batch.get("img_embeds") if self.cfg.family == "vlm" else None
        return self.module.prefill(self.cfg, params, batch["tokens"],
                                   cache_len, prefix_embeds=prefix, **kw)

    def decode_step(self, params, cache, tokens):
        return self.module.decode_step(self.cfg, params, cache, tokens)

    def init_cache(self, batch: int, cache_len: int, abstract: bool = False):
        return self.module.init_cache(self.cfg, batch, cache_len,
                                      abstract=abstract)

    def cache_axes(self):
        return self.module.cache_axes(self.cfg)

    # -- shapes ----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig | str,
                    abstract: bool = True) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        if isinstance(shape, str):
            shape = SHAPES[shape]
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        cdt = jnp.dtype(cfg.compute_dtype)

        def sd(shp, dtype=jnp.int32):
            if abstract:
                return jax.ShapeDtypeStruct(shp, dtype)
            if jnp.issubdtype(dtype, jnp.integer):
                return jnp.zeros(shp, dtype)
            return jnp.zeros(shp, dtype)

        if shape.kind == "decode":
            return {"tokens": sd((b, 1))}

        if cfg.family == "encdec":
            out = {
                "tokens": sd((b, s)),
                "enc_frames": sd((b, s, cfg.d_model), cdt),
            }
        elif cfg.family == "vlm":
            n_img = s // 4
            out = {
                "tokens": sd((b, s - n_img)),
                "img_embeds": sd((b, n_img, cfg.d_model), cdt),
            }
        else:
            out = {"tokens": sd((b, s))}
        if shape.kind == "train":
            out["targets"] = sd(out["tokens"].shape)
        return out

    def batch_axes(self, shape: ShapeConfig | str) -> dict[str, tuple]:
        if isinstance(shape, str):
            shape = SHAPES[shape]
        specs = self.input_specs(shape)
        return {
            k: ("batch",) + (None,) * (len(v.shape) - 1)
            for k, v in specs.items()
        }

    def supports(self, shape: ShapeConfig | str) -> tuple[bool, str]:
        """Cell applicability (long_500k needs sub-quadratic mixing)."""
        if isinstance(shape, str):
            shape = SHAPES[shape]
        if shape.name == "long_500k" and not self.cfg.subquadratic:
            return False, (
                "long_500k skipped: pure full-attention architecture "
                "(quadratic); see DESIGN.md"
            )
        return True, ""
