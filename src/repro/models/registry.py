"""Model registry: family -> module, plus the uniform Arch facade used by
train/serve/launch code.

Every architecture supports:
  specs/init/abstract_params — parameter tree (concrete or ShapeDtypeStruct)
  loss_fn(params, batch)     — training loss
  prefill(params, tokens, cache_len, **extras) -> (cache, logits)
  decode_step(params, cache, tokens) -> (logits, cache)
  input_specs(shape)         — ShapeDtypeStruct stand-ins per assigned shape
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import encdec, hybrid, transformer, xlstm
from . import params as params_lib
from .spec import ModelConfig, ShapeConfig, SHAPES

FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": xlstm,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclasses.dataclass(frozen=True)
class Arch:
    cfg: ModelConfig

    @property
    def module(self):
        return FAMILY_MODULES[self.cfg.family]

    # -- params ----------------------------------------------------------
    def param_specs(self):
        return self.module.specs(self.cfg)

    def init(self, rng):
        return params_lib.init_tree(
            self.param_specs(), rng, jnp.dtype(self.cfg.param_dtype)
        )

    def abstract_params(self):
        return params_lib.abstract_tree(
            self.param_specs(), jnp.dtype(self.cfg.param_dtype)
        )

    def param_axes(self):
        return params_lib.axes_tree(self.param_specs())

    def n_params(self) -> int:
        return params_lib.count_params(self.param_specs())

    # -- steps -----------------------------------------------------------
    def loss_fn(self, params, batch):
        return self.module.loss_fn(self.cfg, params, batch)

    def forward(self, params, batch):
        kw = {}
        if self.cfg.family == "encdec":
            kw["frames"] = batch["enc_frames"]
        prefix = batch.get("img_embeds") if self.cfg.family == "vlm" else None
        return self.module.forward(self.cfg, params, batch["tokens"],
                                   prefix_embeds=prefix, **kw)

    @property
    def supports_prefill_length(self) -> bool:
        """Whether this family's prefill takes a traced ``length`` over
        right-padded tokens (the serve engine's prompt-length bucketing)."""
        return bool(getattr(self.module, "SUPPORTS_PREFILL_LENGTH", False))

    def prefill(self, params, batch, cache_len: int, length=None):
        kw = {}
        if self.cfg.family == "encdec":
            kw["frames"] = batch["enc_frames"]
        prefix = batch.get("img_embeds") if self.cfg.family == "vlm" else None
        if length is not None:
            if not self.supports_prefill_length:
                raise ValueError(
                    f"family {self.cfg.family!r} has no length-masked "
                    f"prefill — disable prompt bucketing for it")
            kw["length"] = length
        return self.module.prefill(self.cfg, params, batch["tokens"],
                                   cache_len, prefix_embeds=prefix, **kw)

    def decode_step(self, params, cache, tokens):
        return self.module.decode_step(self.cfg, params, cache, tokens)

    def init_cache(self, batch: int, cache_len: int, abstract: bool = False):
        return self.module.init_cache(self.cfg, batch, cache_len,
                                      abstract=abstract)

    def init_lane_cache(self, n_lanes: int, cache_len: int,
                        abstract: bool = False, mesh=None,
                        lane_axis: str = "lanes"):
        """A lane SLAB: ``n_lanes`` stacked batch-1 decode caches.

        The continuous-batching serve engine vmaps ``decode_step`` over the
        leading lane axis (each lane is an independent request at its own
        position — the per-lane scalar ``pos`` batches into a ``[n_lanes]``
        leaf), and admission overwrites one lane's sub-cache in place via
        ``write_lane``.  Works for every family: KV caches and O(1)
        recurrent state alike are just pytrees of per-request leaves.

        ``mesh``: place every leaf with a ``NamedSharding`` split on the
        leading lane dimension over ``mesh``'s ``lane_axis`` (the sharded
        serve driver's slab layout — its shard_map programs then consume
        the slab without any resharding copy).
        """
        one = self.init_cache(1, cache_len, abstract=abstract)
        if abstract:
            return jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((n_lanes,) + x.shape,
                                               x.dtype),
                one,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
        slab = jax.tree.map(
            lambda x: jnp.zeros((n_lanes,) + jnp.shape(x),
                                jnp.asarray(x).dtype), one
        )
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            sh = NamedSharding(mesh, PartitionSpec(lane_axis))
            slab = jax.tree.map(lambda x: jax.device_put(x, sh), slab)
        return slab

    def cache_axes(self):
        return self.module.cache_axes(self.cfg)

    def lane_cache_axes(self):
        """Partition axes for the lane slab: lanes ride the batch axis."""
        return jax.tree.map(
            lambda axes: ("batch",) + tuple(axes),
            self.cache_axes(), is_leaf=lambda x: isinstance(x, tuple),
        )

    # -- shapes ----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig | str,
                    abstract: bool = True) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        if isinstance(shape, str):
            shape = SHAPES[shape]
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        cdt = jnp.dtype(cfg.compute_dtype)

        def sd(shp, dtype=jnp.int32):
            if abstract:
                return jax.ShapeDtypeStruct(shp, dtype)
            if jnp.issubdtype(dtype, jnp.integer):
                return jnp.zeros(shp, dtype)
            return jnp.zeros(shp, dtype)

        if shape.kind == "decode":
            return {"tokens": sd((b, 1))}

        if cfg.family == "encdec":
            out = {
                "tokens": sd((b, s)),
                "enc_frames": sd((b, s, cfg.d_model), cdt),
            }
        elif cfg.family == "vlm":
            n_img = s // 4
            out = {
                "tokens": sd((b, s - n_img)),
                "img_embeds": sd((b, n_img, cfg.d_model), cdt),
            }
        else:
            out = {"tokens": sd((b, s))}
        if shape.kind == "train":
            out["targets"] = sd(out["tokens"].shape)
        return out

    def batch_axes(self, shape: ShapeConfig | str) -> dict[str, tuple]:
        if isinstance(shape, str):
            shape = SHAPES[shape]
        specs = self.input_specs(shape)
        return {
            k: ("batch",) + (None,) * (len(v.shape) - 1)
            for k, v in specs.items()
        }

    def supports(self, shape: ShapeConfig | str) -> tuple[bool, str]:
        """Cell applicability (long_500k needs sub-quadratic mixing)."""
        if isinstance(shape, str):
            shape = SHAPES[shape]
        if shape.name == "long_500k" and not self.cfg.subquadratic:
            return False, (
                "long_500k skipped: pure full-attention architecture "
                "(quadratic); see DESIGN.md"
            )
        return True, ""


# -- lane-slab plumbing (continuous-batching serving) -----------------------

def write_lane(slab, lane, cache, owned=None):
    """Write one request's batch-1 cache into lane ``lane`` of a slab.

    ``lane`` may be a traced i32 scalar — one compiled update serves every
    lane (dynamic-index scatter), so admission never re-traces.

    ``owned`` (traced bool scalar, sharded admission): when False the
    write is a no-op — inside ``shard_map`` every shard runs the same
    admission program on its LOCAL slab block, but only the shard that
    owns the (clamped) local lane index actually takes the new cache.
    """

    def w(s, c):
        c = jnp.asarray(c).astype(s.dtype)
        if owned is not None:
            c = jnp.where(owned, c, s[lane])
        return s.at[lane].set(c)

    return jax.tree.map(w, slab, cache)


def read_lane(slab, lane):
    """One lane's batch-1 cache view of a slab."""
    return jax.tree.map(lambda s: s[lane], slab)
