"""Decoder-only transformer backbone (families: dense, moe, vlm).

Layer stack is a ``lax.scan`` over layer-stacked parameters (compile-time
O(1) in depth), with ScALPEL counters threaded through the scan carry
(core.scan_with_counters) and configurable activation rematerialization.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.dist.partition import shard
from . import layers as L
from . import moe as moe_lib
from .params import stacked
from .spec import ModelConfig


# bucketed serving: prefill accepts a traced ``length`` with right-padded
# tokens — causal attention already guarantees valid positions never read
# the pad tail, and the decode path masks cache slots past ``pos``
SUPPORTS_PREFILL_LENGTH = True


def layer_specs(cfg: ModelConfig) -> dict:
    sp = {
        "ln1": L.rms_norm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.rms_norm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        sp["ffn"] = moe_lib.moe_specs(cfg)
    else:
        sp["ffn"] = L.mlp_specs(cfg)
    return sp


def specs(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embed_specs(cfg),
        "layers": stacked(lambda: layer_specs(cfg), cfg.n_layers),
        "final_norm": L.rms_norm_spec(cfg.d_model),
    }


def _ffn(cfg: ModelConfig, lp, x):
    if cfg.family == "moe":
        return moe_lib.moe_ffn(cfg, lp["ffn"], x)
    return L.mlp(cfg, lp["ffn"], x)


def block(cfg: ModelConfig, lp, x, positions):
    with scalpel.function("layer"):
        h = L.rms_norm(x, lp["ln1"])
        x = x + L.attention(cfg, lp["attn"], h, positions,
                            window=cfg.sliding_window)
        h = L.rms_norm(x, lp["ln2"])
        x = x + _ffn(cfg, lp, h)
        x = shard(x, "batch", None, None)
        return x


def backbone(cfg: ModelConfig, params, x, positions):
    """Run the layer stack. x: [b,s,d] -> [b,s,d] (pre-final-norm)."""

    def body(carry, lp):
        return block(cfg, lp, carry, positions), None

    x, _ = scalpel.scan_with_counters(body, x, params["layers"],
                                      remat=L.remat_policy(cfg))
    return x


def forward(cfg: ModelConfig, params, tokens, prefix_embeds=None):
    """Training/prefill forward. tokens: [b,s] -> logits [b,s(,+p),V]."""
    x = L.embed(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]
    )
    x = backbone(cfg, params, x, positions)
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    prefix = batch.get("img_embeds")
    logits = forward(cfg, params, batch["tokens"], prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    mask = batch.get("mask")
    return L.cross_entropy(logits, batch["targets"], mask)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, cache_len: int,
            prefix_embeds=None, length=None):
    """Run the full prompt, build a KV cache of size ``cache_len``.

    Returns (cache, last_logits).  cache: {"k","v": [nL,b,S,kv,hd], "pos"}.

    ``length`` (traced i32, None => full width): tokens beyond it are
    right-pad.  Causal attention keeps valid positions exact (they never
    attend forward into the pad), the logits are read at ``length - 1``,
    and ``pos = length`` — decode overwrites the pad K/V slots one per
    step and masks everything past ``pos``, so they are never read.
    """
    x = L.embed(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kvd = jnp.dtype(cfg.compute_dtype)

    def body(carry, lp):
        xx = carry
        with scalpel.function("layer"):
            h = L.rms_norm(xx, lp["ln1"])
            with scalpel.function("attn"):
                q, k, v = L._qkv(cfg, lp["attn"], h, positions)
                a = L.run_attention(cfg, q, k, v, True, cfg.sliding_window)
                y = jnp.einsum("bshk,hkd->bsd", a,
                               lp["attn"]["wo"].astype(xx.dtype))
                if cfg.use_bias:
                    y = y + lp["attn"]["bo"].astype(xx.dtype)
            xx = xx + y
            h = L.rms_norm(xx, lp["ln2"])
            xx = xx + _ffn(cfg, lp, h)
        pad = cache_len - s
        kc = jnp.pad(k.astype(kvd), ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(kvd), ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc = shard(kc, "batch", "kv_seq", None, None)
        vc = shard(vc, "batch", "kv_seq", None, None)
        return xx, {"k": kc, "v": vc}

    x, kvs = scalpel.scan_with_counters(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"])
    if length is None:
        xl = x[:, -1:, :]
        pos = jnp.asarray(s, jnp.int32)
    else:
        xl = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        pos = jnp.asarray(length, jnp.int32)
    logits = L.unembed(cfg, params["embed"], xl)
    cache = {"k": kvs["k"], "v": kvs["v"], "pos": pos}
    return cache, logits


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False):
    kvd = jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, kvd)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        arr = jnp.zeros(shape, kvd)
        pos = jnp.asarray(0, jnp.int32)
    return {"k": arr, "v": arr, "pos": pos}


def cache_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", None, None)
    return {"k": kv, "v": kv, "pos": ()}


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One decode step for the whole batch. tokens: [b,1] int32."""
    x = L.embed(cfg, params["embed"], tokens)
    pos = cache["pos"]

    def body(carry, layer_in):
        lp, kc, vc = layer_in
        xx = carry
        with scalpel.function("layer"):
            h = L.rms_norm(xx, lp["ln1"])
            y, kc, vc = L.decode_attention(cfg, lp["attn"], h, kc, vc, pos)
            xx = xx + y
            h = L.rms_norm(xx, lp["ln2"])
            xx = xx + _ffn(cfg, lp, h)
        return xx, {"k": kc, "v": vc}

    x, kvs = scalpel.scan_with_counters(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    new_cache = {"k": kvs["k"], "v": kvs["v"], "pos": pos + 1}
    return logits, new_cache
