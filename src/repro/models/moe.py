"""Mixture-of-Experts FFN with sort-based dispatch and GSPMD expert
parallelism.

Dispatch is the modern sort-based formulation (not GShard one-hot einsums,
whose [G,N,E,C] combine tensors don't scale):

  1. router top-k -> (expert_id, gate) per token copy
  2. stable-sort token copies by expert id; rank-in-expert via a sorted scan
  3. scatter into a capacity-bounded buffer [groups, E, C, D]; copies past
     capacity are dropped (capacity_factor controls the drop rate; the
     MOE_LOAD ScALPEL events monitor imbalance + drops)
  4. resharding the buffer from group-sharded to expert-sharded is THE
     expert-parallel all-to-all — expressed as a sharding constraint, GSPMD
     emits the collective
  5. expert GEMMs, inverse constraint, un-permute, weighted combine.

Works on one CPU device (constraints no-op) and on the production meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.dist.partition import shard
from .params import P
from .spec import ModelConfig


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    sp = {
        "router": P((d, e), ("embed", "experts"), scale=0.02),
        "wi": P((e, d, f), ("experts", "e_embed", "mlp")),
        "wg": P((e, d, f), ("experts", "e_embed", "mlp")),
        "wo": P((e, f, d), ("experts", "mlp", "e_embed")),
    }
    if cfg.moe.dense_residual:
        from .layers import mlp_specs

        sp["dense"] = mlp_specs(cfg, cfg.moe.dense_ff or cfg.d_ff)
    return sp


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    e, k, cf = cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.capacity_factor
    c = int(tokens_per_group * k * cf / e) + 1
    # round to MXU-friendly multiple
    return max(8, -(-c // 8) * 8)


def moe_ffn(cfg: ModelConfig, p, x):
    """x: [b, s, d] -> [b, s, d]."""
    with scalpel.function("moe"):
        b, s, d = x.shape
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        # group = sequence: the [b,s,d]->[g,tpg,d] reshape is then the
        # identity, so GSPMD keeps the batch sharding through dispatch.
        # (A coarser g<b merged batch rows across shards and forced a full
        # re-materialization + activation-grad all-reduce — the dominant
        # collective in the arctic-480b baseline; EXPERIMENTS.md §Perf.)
        g, tpg = b, s
        cap = _capacity(cfg, tpg)

        xt = x.reshape(g, tpg, d)
        xt = shard(xt, "groups", None, None)

        logits = jnp.einsum(
            "gnd,de->gne", xt, p["router"].astype(jnp.float32).astype(x.dtype)
        ).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eid = jax.lax.top_k(probs, k)  # [g,n,k]
        gate = gate / jnp.maximum(
            jnp.sum(gate, axis=-1, keepdims=True), 1e-9
        )

        # ---- flatten copies and sort by expert ------------------------
        flat_e = eid.reshape(g, tpg * k)
        flat_g = gate.reshape(g, tpg * k).astype(x.dtype)
        src = jnp.arange(tpg * k, dtype=jnp.int32) // k  # copy -> token row
        order = jnp.argsort(flat_e, axis=-1, stable=True)  # [g, n*k]
        se = jnp.take_along_axis(flat_e, order, axis=-1)
        sg = jnp.take_along_axis(flat_g, order, axis=-1)
        st = jnp.take_along_axis(
            jnp.broadcast_to(src, flat_e.shape), order, axis=-1
        )
        # rank within expert among sorted copies
        same = se[:, 1:] == se[:, :-1]
        incr = jnp.concatenate(
            [jnp.zeros((g, 1), jnp.int32), same.astype(jnp.int32)], axis=-1
        )

        def seg_rank(carry, inc):
            r = jnp.where(inc == 1, carry + 1, 0)
            return r, r

        _, ranks = jax.lax.scan(seg_rank, jnp.zeros((g,), jnp.int32),
                                incr.T)
        rank = ranks.T  # [g, n*k]
        keep = rank < cap
        slot = se * cap + jnp.where(keep, rank, cap - 1)  # clamp; masked later

        # monitoring: expert load + drop fraction
        load_mask = jax.nn.one_hot(
            eid.reshape(g * tpg, k), e, dtype=jnp.float32
        ).sum(1)
        scalpel.probe(
            router_probs=probs.reshape(g * tpg, e),
            expert_mask=load_mask,
            dropped=1.0 - keep.astype(jnp.float32),
        )

        # ---- dispatch: build [g, E*C, d] buffer ------------------------
        toks = jnp.take_along_axis(xt, st[..., None], axis=1)  # [g,n*k,d]
        w = jnp.where(keep, sg, 0.0)[..., None]
        buf = jnp.zeros((g, e * cap, d), x.dtype)
        buf = jax.vmap(
            lambda bu, sl, tv: bu.at[sl].add(tv)
        )(buf, slot, toks * jnp.where(keep, 1.0, 0.0)[..., None].astype(x.dtype))
        buf = buf.reshape(g, e, cap, d)
        # THE all-to-all: group-sharded -> (group, expert)-sharded
        buf = shard(buf, "groups", "experts", None, None)

        # ---- expert FFN -------------------------------------------------
        hi = jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(x.dtype))
        hg = jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(x.dtype))
        h = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hi
        h = shard(h, "groups", "experts", None, "mlp")
        out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
        out = shard(out, "groups", "experts", None, None)

        # ---- combine: gather copies back, weight, sum over k ------------
        out = out.reshape(g, e * cap, d)
        out = shard(out, "groups", None, None)
        per_copy = jnp.take_along_axis(
            out, slot[..., None], axis=1
        ) * w.astype(x.dtype)
        # sum the k copies of each token: un-sort then segment-sum by token
        y = jnp.zeros((g, tpg, d), x.dtype)
        y = jax.vmap(lambda yy, tt, vv: yy.at[tt].add(vv))(y, st, per_copy)
        y = y.reshape(b, s, d)
        y = shard(y, "batch", None, None)

        if cfg.moe.dense_residual:
            from .layers import mlp

            y = y + mlp(cfg, p["dense"], x)
        scalpel.probe(out=y)
        return y
