"""Zamba2-style hybrid (family 'hybrid'): Mamba2 backbone + one *shared*
attention+MLP block applied every ``hybrid.attn_every`` layers.

Structure: ``n_sites = n_layers // attn_every`` groups, each = attn_every
Mamba2 layers followed by one application of the shared block; remaining
``n_layers % attn_every`` Mamba2 layers trail at the end.  The shared block
operates at 2*d_model on concat(hidden, original_embedding) (Zamba2's
global-skip concat) and projects back to d_model.

Sub-quadratic backbone -> runs long_500k; the shared block's KV caches (one
per application site) are sequence-sharded in decode.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.dist.partition import shard
from . import layers as L
from . import ssm
from .params import P, stacked
from .spec import ModelConfig


def _geometry(cfg: ModelConfig):
    every = cfg.hybrid.attn_every
    n_sites = cfg.n_layers // every
    trailing = cfg.n_layers - n_sites * every
    return every, n_sites, trailing


def _shared_cfg(cfg: ModelConfig) -> ModelConfig:
    """The shared block's attention runs at 2*d_model."""
    return cfg.replace(
        name=cfg.name + "-shared",
        d_model=2 * cfg.d_model,
        head_dim=2 * cfg.d_model // cfg.n_heads,
        family="dense",
    )


def shared_block_specs(cfg: ModelConfig) -> dict:
    scfg = _shared_cfg(cfg)
    d2 = scfg.d_model
    return {
        "ln1": L.rms_norm_spec(d2),
        "attn": L.attention_specs(scfg),
        "ln2": L.rms_norm_spec(d2),
        "mlp": L.mlp_specs(scfg, cfg.d_ff),
        "down": P((d2, cfg.d_model), ("heads", "embed")),
    }


def specs(cfg: ModelConfig) -> dict:
    every, n_sites, trailing = _geometry(cfg)
    sp = {
        "embed": L.embed_specs(cfg),
        "groups": stacked(
            lambda: {
                "mamba": stacked(
                    lambda: {
                        "ln": L.rms_norm_spec(cfg.d_model),
                        "mix": ssm.mamba2_specs(cfg),
                    },
                    every,
                )
            },
            n_sites,
        ),
        "shared": shared_block_specs(cfg),
        "final_norm": L.rms_norm_spec(cfg.d_model),
    }
    if trailing:
        sp["trailing"] = stacked(
            lambda: {
                "ln": L.rms_norm_spec(cfg.d_model),
                "mix": ssm.mamba2_specs(cfg),
            },
            trailing,
        )
    return sp


def _mamba_layer(cfg: ModelConfig, lp, x, state=None):
    with scalpel.function("layer"):
        h = L.rms_norm(x, lp["ln"])
        if state is None:
            y, st = ssm.mamba2(cfg, lp["mix"], h)
        else:
            y, st = ssm.mamba2_decode(cfg, lp["mix"], h, *state)
        return x + y, st


def _apply_shared(cfg: ModelConfig, sp, x, x0, positions):
    """Shared attention block at 2d on concat(x, x0)."""
    scfg = _shared_cfg(cfg)
    with scalpel.function("shared_attn"):
        cat = jnp.concatenate([x, x0], axis=-1)
        h = L.rms_norm(cat, sp["ln1"])
        a = L.attention(scfg, sp["attn"], h, positions)
        cat = cat + a
        h = L.rms_norm(cat, sp["ln2"])
        cat = cat + L.mlp(scfg, sp["mlp"], h)
        y = jnp.einsum("bse,ed->bsd", cat, sp["down"].astype(x.dtype))
        y = shard(y, "batch", None, None)
        scalpel.probe(out=y)
        return x + y


def _apply_shared_decode(cfg: ModelConfig, sp, x, x0, kc, vc, pos):
    scfg = _shared_cfg(cfg)
    with scalpel.function("shared_attn"):
        cat = jnp.concatenate([x, x0], axis=-1)
        h = L.rms_norm(cat, sp["ln1"])
        a, kc, vc = L.decode_attention(scfg, sp["attn"], h, kc, vc, pos)
        cat = cat + a
        h = L.rms_norm(cat, sp["ln2"])
        cat = cat + L.mlp(scfg, sp["mlp"], h)
        y = jnp.einsum("bse,ed->bsd", cat, sp["down"].astype(x.dtype))
        scalpel.probe(out=y)
        return x + y, kc, vc


def forward(cfg: ModelConfig, params, tokens, prefix_embeds=None):
    every, n_sites, trailing = _geometry(cfg)
    x = L.embed(cfg, params["embed"], tokens)
    x0 = x
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]
    )

    def group(carry, gp):
        xx = carry

        def inner(c, lp):
            out, _ = _mamba_layer(cfg, lp, c)
            return out, None

        xx, _ = scalpel.scan_with_counters(inner, xx, gp["mamba"])
        xx = _apply_shared(cfg, params["shared"], xx, x0, positions)
        return xx, None

    x, _ = scalpel.scan_with_counters(group, x, params["groups"],
                                      remat=L.remat_policy(cfg))
    if trailing:
        def inner(c, lp):
            out, _ = _mamba_layer(cfg, lp, c)
            return out, None

        x, _ = scalpel.scan_with_counters(inner, x, params["trailing"])
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch["tokens"])
    return L.cross_entropy(logits, batch["targets"], batch.get("mask"))


# -- serving ---------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False):
    every, n_sites, trailing = _geometry(cfg)
    scfg = _shared_cfg(cfg)
    kvd = jnp.dtype(cfg.compute_dtype)
    m = ssm.mamba2_state_specs(cfg, batch)

    def stack_n(sd, n):
        return jax.ShapeDtypeStruct((n,) + sd.shape, sd.dtype)

    cache = {
        "mamba_ssm": stack_n(m["ssm"], n_sites * every + trailing),
        "mamba_conv": stack_n(m["conv"], n_sites * every + trailing),
        "shared_k": jax.ShapeDtypeStruct(
            (n_sites, batch, cache_len, scfg.n_kv_heads,
             scfg.resolved_head_dim), kvd
        ),
        "shared_v": jax.ShapeDtypeStruct(
            (n_sites, batch, cache_len, scfg.n_kv_heads,
             scfg.resolved_head_dim), kvd
        ),
        "x0": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), kvd),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if abstract:
        return cache
    return jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype), cache,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def cache_axes(cfg: ModelConfig):
    return {
        "mamba_ssm": ("layers", "batch", "heads", None, None),
        "mamba_conv": ("layers", "batch", None, None),
        "shared_k": ("layers", "batch", "kv_seq", None, None),
        "shared_v": ("layers", "batch", "kv_seq", None, None),
        "x0": ("batch", None, None),
        "pos": (),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens):
    every, n_sites, trailing = _geometry(cfg)
    x = L.embed(cfg, params["embed"], tokens)
    # zamba's global skip uses the *current token's* embedding in decode
    x0 = x
    pos = cache["pos"]
    m_ssm, m_conv = cache["mamba_ssm"], cache["mamba_conv"]

    def group(carry, inp):
        xx = carry
        gp, states_ssm, states_conv, kc, vc = inp

        def inner(c, lp_state):
            lp, s_ssm, s_conv = lp_state
            out, (s2, c2) = _mamba_layer(cfg, lp, c, (s_ssm, s_conv))
            return out, (s2, c2)

        xx, (s2, c2) = scalpel.scan_with_counters(
            inner, xx, (gp["mamba"], states_ssm, states_conv)
        )
        xx, kc, vc = _apply_shared_decode(cfg, params["shared"], xx, x0,
                                          kc, vc, pos)
        return xx, (s2, c2, kc, vc)

    gs = n_sites * every
    x, (s2, c2, k2, v2) = scalpel.scan_with_counters(
        group, x,
        (
            params["groups"],
            m_ssm[:gs].reshape((n_sites, every) + m_ssm.shape[1:]),
            m_conv[:gs].reshape((n_sites, every) + m_conv.shape[1:]),
            cache["shared_k"], cache["shared_v"],
        ),
    )
    new_ssm = s2.reshape((gs,) + m_ssm.shape[1:])
    new_conv = c2.reshape((gs,) + m_conv.shape[1:])
    if trailing:
        def inner(c, lp_state):
            lp, s_ssm, s_conv = lp_state
            out, (s2t, c2t) = _mamba_layer(cfg, lp, c, (s_ssm, s_conv))
            return out, (s2t, c2t)

        x, (st, ct) = scalpel.scan_with_counters(
            inner, x, (params["trailing"], m_ssm[gs:], m_conv[gs:])
        )
        new_ssm = jnp.concatenate([new_ssm, st], axis=0)
        new_conv = jnp.concatenate([new_conv, ct], axis=0)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    new_cache = {
        "mamba_ssm": new_ssm, "mamba_conv": new_conv,
        "shared_k": k2, "shared_v": v2, "x0": cache["x0"],
        "pos": pos + 1,
    }
    return logits, new_cache


def prefill(cfg: ModelConfig, params, tokens, cache_len: int,
            prefix_embeds=None):
    """Prompt pass building both mamba states and shared-attn KV caches."""
    every, n_sites, trailing = _geometry(cfg)
    scfg = _shared_cfg(cfg)
    x = L.embed(cfg, params["embed"], tokens)
    x0 = x
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kvd = jnp.dtype(cfg.compute_dtype)

    def group(carry, gp):
        xx = carry

        def inner(c, lp):
            out, st = _mamba_layer(cfg, lp, c)
            return out, st

        xx, (s_ssm, s_conv) = scalpel.scan_with_counters(inner, xx,
                                                         gp["mamba"])
        # shared block with KV capture
        with scalpel.function("shared_attn"):
            cat = jnp.concatenate([xx, x0], axis=-1)
            h = L.rms_norm(cat, params["shared"]["ln1"])
            q, k, v = L._qkv(scfg, params["shared"]["attn"], h, positions)
            if s <= 256 or cfg.attn_impl == "reference":
                a = L.reference_attention(scfg, q, k, v, True)
            else:
                a = L.flash_attention_xla(scfg, q, k, v, True)
            y = jnp.einsum("bshk,hkd->bsd", a,
                           params["shared"]["attn"]["wo"].astype(xx.dtype))
            cat = cat + y
            h = L.rms_norm(cat, params["shared"]["ln2"])
            cat = cat + L.mlp(scfg, params["shared"]["mlp"], h)
            y = jnp.einsum("bse,ed->bsd", cat,
                           params["shared"]["down"].astype(xx.dtype))
            xx = xx + y
        pad = cache_len - s
        kc = jnp.pad(k.astype(kvd), ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(kvd), ((0, 0), (0, pad), (0, 0), (0, 0)))
        return xx, (s_ssm, s_conv, kc, vc)

    x, (s_ssm, s_conv, kcs, vcs) = scalpel.scan_with_counters(
        group, x, params["groups"]
    )
    new_ssm = s_ssm.reshape((n_sites * every,) + s_ssm.shape[2:])
    new_conv = s_conv.reshape((n_sites * every,) + s_conv.shape[2:])
    if trailing:
        def inner(c, lp):
            out, st = _mamba_layer(cfg, lp, c)
            return out, st

        x, (st, ct) = scalpel.scan_with_counters(inner, x,
                                                 params["trailing"])
        new_ssm = jnp.concatenate([new_ssm, st], axis=0)
        new_conv = jnp.concatenate([new_conv, ct], axis=0)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x[:, -1:, :])
    cache = {
        "mamba_ssm": new_ssm, "mamba_conv": new_conv,
        "shared_k": kcs, "shared_v": vcs,
        "x0": x0[:, -1:, :].astype(kvd),
        "pos": jnp.asarray(s, jnp.int32),
    }
    return cache, logits
