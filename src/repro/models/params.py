"""Parameter trees: declarative specs -> init / abstract shapes / shardings.

Params are plain nested dicts of arrays.  Every leaf is declared as a
``P(shape, axes)`` where ``axes`` names one *logical* axis per dimension
("embed", "mlp", "heads", "vocab", "layers", ...).  dist/partition.py maps
logical axes -> mesh axes; the same spec tree therefore drives CPU smoke
tests (no mesh), the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Param leaf spec: shape + logical axes + init scale."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float | None = None    # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self}")


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: last dim is fan-out, everything before contributes fan-in
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def init_leaf(spec: P, key, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(
        max(1, _fan_in(spec.shape))
    )
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def is_spec(x: Any) -> bool:
    return isinstance(x, P)


def init_tree(specs: Any, key, dtype) -> Any:
    """Materialize a param tree from a spec tree (smoke tests / training)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(specs: Any, dtype) -> Any:
    """ShapeDtypeStruct tree — dry-run stand-ins, no allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=is_spec,
    )


def axes_tree(specs: Any) -> Any:
    """Logical-axes tree with the same structure as the param tree."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def count_params(specs: Any) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(specs, is_leaf=is_spec)
    )


def stacked(spec_fn: Callable[[], dict], n: int, axis_name: str = "layers") -> dict:
    """Stack a per-layer spec dict along a leading 'layers' dim (for scan)."""
    layer = spec_fn()
    return jax.tree.map(
        lambda s: P((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        layer,
        is_leaf=is_spec,
    )
