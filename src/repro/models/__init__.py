from .registry import Arch, FAMILY_MODULES  # noqa: F401
from .spec import (  # noqa: F401
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
)
